"""Falcon-Mamba-7B [arXiv:2410.05355] — attention-free mamba-1.

Opt-GQA inapplicable (no attention); the paged-pool insight survives as a
slot-indexed O(1) SSM state cache. GPTQ applies to all projections.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=65024, head_dim=64,
    pos_emb="none", ssm_state=16, ssm_conv=4, ssm_expand=2,
    tie_embeddings=True,
)
