"""Kimi-K2 1T-A32B [arXiv:2501.kimi2, paper-table] — MoE 384 routed top-8, GQA kv=8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    head_dim=112, d_ff=2048, vocab_size=163840,
    qkv_bias=False, pos_emb="rope", act="silu",
    num_experts=384, num_shared_experts=1, moe_top_k=8, moe_d_ff=2048,
)
