"""Model / quantization / parallelism configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``; the shape
sets (train_4k / prefill_32k / decode_32k / long_500k) are ``ShapeConfig``
instances attached per-arch in the registry.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class QuantConfig:
    """GPTQ weight-quantization settings (paper §III, the 'GPTQ' in Opt-GPTQ)."""
    bits: int = 4
    group_size: int = 128          # one (scale, zero) per group of in-features
    sym: bool = False              # asymmetric by default (zero-points kept)
    damp_frac: float = 0.01        # Hessian dampening lambda = damp_frac * mean(diag H)
    act_order: bool = True         # quantize columns in decreasing-Hessian order
    block_size: int = 128          # OBQ lazy-update block width


@dataclass(frozen=True)
class PagingConfig:
    """Paged KV-cache settings (paper §III.A 'paging memory management')."""
    block_size: int = 16           # tokens per KV block
    num_blocks: int = 0            # 0 => derived from max_seqs * max_seq_len
    enable_prefix_reuse: bool = True
    watermark_frac: float = 0.01   # free-block watermark before admission
    cache_dtype: str = "bfloat16"  # "float8_e4m3fn" halves pool bytes/traffic


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


# The four assigned LM shape cells.
TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 => d_model // num_heads
    qkv_bias: bool = False
    # --- attention layout ---
    attn_pattern: Tuple[str, ...] = ("full",)   # cycled over layers: full|sliding|recurrent
    sliding_window: int = 0
    pos_emb: str = "rope"          # rope | alibi | none
    rope_theta: float = 10000.0
    is_encoder: bool = False       # bidirectional attention, no KV cache / decode
    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0              # per-expert hidden; dense layers use d_ff
    # --- SSM (mamba-1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    # --- hybrid (RG-LRU) ---
    lru_width: int = 0
    # --- misc ---
    act: str = "silu"
    norm: str = "rmsnorm"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # --- modality frontend stubs ---
    frontend: str = "none"         # none | audio_frames | vision_patches
    num_prefix_embeds: int = 0     # vlm: patch embeds prepended to the text seq
    # --- paper technique knobs ---
    quant: Optional[QuantConfig] = None
    paging: PagingConfig = field(default_factory=PagingConfig)
    use_alibi_serving: bool = False  # serve-time ALiBi bias (paper default on)

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_subquadratic(self) -> bool:
        """Whether long_500k decode is admissible (no full-attention layer)."""
        if self.family == "ssm":
            return True
        pats = set(self.attn_pattern)
        return "full" not in pats

    def layer_kind(self, i: int) -> str:
        """Kind of mixer at layer ``i`` (cycles attn_pattern)."""
        if self.family == "ssm":
            return "ssm"
        return self.attn_pattern[i % len(self.attn_pattern)]

    def num_params(self) -> int:
        """Analytic parameter count (embeddings included once)."""
        d, h = self.d_model, self.resolved_head_dim
        n = self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d                  # lm head
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            if kind == "ssm":
                d_in = self.ssm_expand * d
                dt_rank = (d + self.ssm_state - 1) // self.ssm_state
                n += d * 2 * d_in                      # in_proj
                n += d_in * self.ssm_conv              # conv
                n += d_in * (dt_rank + 2 * self.ssm_state)  # x_proj
                n += dt_rank * d_in + d_in             # dt_proj
                n += d_in * self.ssm_state + 2 * d_in  # A_log, D, etc
                n += d_in * d                          # out_proj
            elif kind == "recurrent":
                w = self.lru_width or d
                n += d * w * 2 + w * d                 # linear in (x2) + out
                n += 3 * w                             # RG-LRU params (a, gates simplified)
                n += 2 * w * 4                         # conv1d-ish temporal mix
            else:  # attention
                n += d * self.num_heads * h            # Wq
                n += 2 * d * self.num_kv_heads * h     # Wk, Wv
                n += self.num_heads * h * d            # Wo
                if self.qkv_bias:
                    n += (self.num_heads + 2 * self.num_kv_heads) * h
            # MLP / MoE
            if kind != "ssm":
                if self.num_experts:
                    n += self.num_experts * 3 * d * self.moe_d_ff
                    n += self.num_shared_experts * 3 * d * self.moe_d_ff
                    n += d * self.num_experts          # router
                else:
                    mult = 3 if self.act in ("silu", "swiglu") else 2
                    n += mult * d * self.d_ff
            n += 2 * d                                 # norms
        return n

    def num_active_params(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if not self.num_experts:
            return self.num_params()
        full = self.num_params()
        routed_all = self.num_layers * self.num_experts * 3 * self.d_model * self.moe_d_ff
        routed_active = self.num_layers * self.moe_top_k * 3 * self.d_model * self.moe_d_ff
        return full - routed_all + routed_active

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    kw = dict(
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 4) or 1,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
    )
    if cfg.num_experts:
        kw.update(num_experts=4, num_shared_experts=min(cfg.num_shared_experts, 1),
                  moe_top_k=2, moe_d_ff=32)
    if cfg.family == "ssm":
        kw.update(num_heads=1, num_kv_heads=1, ssm_state=4, d_ff=0)
    if cfg.family == "hybrid":
        kw.update(lru_width=64, num_kv_heads=1)
    if cfg.sliding_window:
        kw.update(sliding_window=32)
    if cfg.num_prefix_embeds:
        kw.update(num_prefix_embeds=8)
    kw.update(overrides)
    return cfg.replace(**kw)
