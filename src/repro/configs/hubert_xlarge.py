"""HuBERT-XLarge [arXiv:2106.07447] — encoder-only audio backbone (w2v2 arch), MHA kv=16.

Modality frontend is a STUB: input_specs() provides precomputed frame
embeddings (batch, seq, d_model). Encoder-only -> no decode shapes.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    head_dim=80, d_ff=5120, vocab_size=504,
    is_encoder=True, pos_emb="alibi", act="gelu", norm="layernorm",
    frontend="audio_frames",
)
