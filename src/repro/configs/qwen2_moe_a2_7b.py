"""Qwen1.5/2-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B] — MoE 60 routed top-4 + 4 shared, MHA kv=16."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
    head_dim=128, d_ff=1408, vocab_size=151936,
    qkv_bias=True, pos_emb="rope", act="silu",
    num_experts=60, num_shared_experts=4, moe_top_k=4, moe_d_ff=1408,
)
