"""H2O-Danube-3-4B [arXiv:2401.16818] — dense llama+mistral mix, GQA kv=8, SWA.

All layers use a sliding window (mistral style) -> sub-quadratic, so the
long_500k decode cell is admissible.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    num_layers=24, d_model=3840, num_heads=32, num_kv_heads=8,
    head_dim=120, d_ff=10240, vocab_size=32000,
    attn_pattern=("sliding",), sliding_window=8192,
    pos_emb="rope", act="silu",
)
