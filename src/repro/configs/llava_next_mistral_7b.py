"""LLaVA-NeXT (mistral-7b backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf] — VLM, GQA kv=8.

Backbone only; anyres vision tiling is a STUB — input_specs() provides
precomputed patch embeddings (batch, num_patches, d_model) prepended to text.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=32000,
    pos_emb="rope", act="silu", frontend="vision_patches",
    num_prefix_embeds=2880,  # anyres 4+1 tiles x 576 patches
)
