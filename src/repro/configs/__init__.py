"""Configs for the assigned architectures."""
