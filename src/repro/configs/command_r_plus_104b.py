"""Command-R+ 104B [hf:CohereForAI/c4ai-command-r-v01 family] — dense, GQA kv=8, no bias."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8,
    head_dim=128, d_ff=33792, vocab_size=256000,
    qkv_bias=False, pos_emb="rope", rope_theta=75e6, act="silu",
    norm="layernorm", tie_embeddings=True,
)
