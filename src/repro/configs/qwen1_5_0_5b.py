"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B] — dense, MHA (kv=16), QKV bias.

kv_heads == num_heads: this is the arch on which the Opt-GQA *conversion*
(activation-similarity dynamic grouping, core/grouping.py) is demonstrated.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    head_dim=64, d_ff=2816, vocab_size=151936,
    qkv_bias=True, pos_emb="rope", act="silu", tie_embeddings=True,
)
