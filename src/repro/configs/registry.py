"""Architecture registry: ``--arch <id>`` resolution + per-arch shape cells."""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.configs.base import (ALL_SHAPES, LONG_500K, ModelConfig,
                                ShapeConfig, reduced)
from repro.configs import (qwen2_1_5b, qwen1_5_0_5b, h2o_danube_3_4b,
                           command_r_plus_104b, qwen2_moe_a2_7b,
                           kimi_k2_1t_a32b, falcon_mamba_7b,
                           recurrentgemma_2b, hubert_xlarge,
                           llava_next_mistral_7b)

_MODULES = (qwen2_1_5b, qwen1_5_0_5b, h2o_danube_3_4b, command_r_plus_104b,
            qwen2_moe_a2_7b, kimi_k2_1t_a32b, falcon_mamba_7b,
            recurrentgemma_2b, hubert_xlarge, llava_next_mistral_7b)

ARCHS: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_reduced(name: str, **overrides) -> ModelConfig:
    return reduced(get_config(name), **overrides)


def shapes_for(cfg: ModelConfig) -> List[Tuple[ShapeConfig, str]]:
    """All 4 shape cells with admissibility: (shape, "run"|"skip: reason")."""
    out = []
    for s in ALL_SHAPES:
        if s.kind == "decode" and cfg.is_encoder:
            out.append((s, "skip: encoder-only arch has no decode step"))
        elif s is LONG_500K and not cfg.is_subquadratic:
            out.append((s, "skip: full-attention arch, 512k decode is quadratic"))
        else:
            out.append((s, "run"))
    return out


def all_cells() -> List[Tuple[str, str, str]]:
    """(arch, shape, status) for all 40 cells."""
    cells = []
    for name, cfg in ARCHS.items():
        for s, status in shapes_for(cfg):
            cells.append((name, s.name, status))
    return cells
