"""RecurrentGemma-2B [arXiv:2402.19427; hf] — RG-LRU + local attention, 1:2 pattern, MQA kv=1."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
    head_dim=256, d_ff=7680, vocab_size=256000,
    attn_pattern=("recurrent", "recurrent", "sliding"), sliding_window=2048,
    pos_emb="rope", act="gelu", lru_width=2560, tie_embeddings=True,
)
