"""AdamW + schedules, pure JAX, with optimizer-state dtype control
(bf16 moments make the kimi-1T optimizer fit the pod — DESIGN.md §4)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    moment_dtype: str = "float32"      # "bfloat16" for 1T-scale state sharding


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def init_opt_state(params: Any, cfg: AdamWConfig) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)
    z = lambda p: jnp.zeros(p.shape, dt)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(z, params),
                    nu=jax.tree.map(z, params))


def lr_at(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = cfg.lr * jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(params: Any, grads: Any, st: OptState,
                  cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = st.step + 1
    lr = lr_at(cfg, st.step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mh, vh = m32 / bc1, v32 / bc2
        u = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * u).astype(p.dtype),
                m32.astype(mdt), v32.astype(mdt))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(st.mu)
    flat_v = jax.tree.leaves(st.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
