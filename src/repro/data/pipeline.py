"""Deterministic synthetic data pipeline, sharded per-host, checkpointable.

Real deployments swap `SyntheticLM` for a tokenized corpus reader; the
interface (``state`` / ``restore`` / global-array placement) is what the
fault-tolerance layer relies on.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class SyntheticLM:
    """Zipf-ish synthetic LM token stream; step-indexed => resumable."""
    cfg: ModelConfig
    shape: ShapeConfig
    seed: int = 0
    step: int = 0

    def state(self) -> Dict[str, int]:
        return {"step": self.step, "seed": self.seed}

    def restore(self, st: Dict[str, int]) -> None:
        self.step = int(st["step"])
        self.seed = int(st["seed"])

    def _host_batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg, sh = self.cfg, self.shape
        rng = np.random.default_rng((self.seed, step))
        B, S = sh.global_batch, sh.seq_len
        if cfg.is_encoder:
            return {
                "frames": rng.standard_normal((B, S, cfg.d_model),
                                              dtype=np.float32) * 0.1,
                "labels": rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32),
            }
        # zipf-like marginal + local repetition (gives a learnable signal)
        ranks = rng.zipf(1.3, size=(B, S + 1))
        toks = np.clip(ranks, 1, cfg.vocab_size - 1).astype(np.int32)
        rep = rng.random((B, S + 1)) < 0.3
        toks[:, 1:] = np.where(rep[:, 1:], toks[:, :-1], toks[:, 1:])
        out = {"tokens": toks}
        if cfg.frontend == "vision_patches":
            out["vision_embeds"] = rng.standard_normal(
                (B, cfg.num_prefix_embeds, cfg.d_model), dtype=np.float32) * 0.1
        return out

    def next_batch(self, mesh=None) -> Dict[str, jnp.ndarray]:
        host = self._host_batch(self.step)
        self.step += 1
        if mesh is None:
            return {k: jnp.asarray(v) for k, v in host.items()}
        dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        out = {}
        for k, v in host.items():
            sharding = NamedSharding(mesh, P(dp, *([None] * (v.ndim - 1))))
            out[k] = jax.device_put(jnp.asarray(v), sharding)
        return out

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        while True:
            yield self.next_batch()
