"""Low-overhead span tracing for the serving hot loop.

The tracer answers the question the aggregate counters cannot: *where*
does a steady-state engine step spend its milliseconds?  Every section
of interest — plan, each device dispatch, the token-readback sync
boundary, detokenization — is wrapped in a :class:`SpanTracer.span`
context manager; completed spans land in a bounded ring buffer of
``(name, cat, ts_ns, dur_ns, depth, args)`` records and can be exported
as Chrome-trace-event JSON (``chrome://tracing`` / Perfetto's
``ui.perfetto.dev`` open it directly).

Hot-path contract (enforced by the R1 rule in ``repro.analysis``):

* **no jax imports** — this module must be loadable and zero-cost in
  processes that never touch a device, and nothing here may ever block
  on a device stream;
* **no host syncs** — span bodies only read ``time.perf_counter_ns``
  (one monotonic clock call on enter, one on exit) and append one
  record to a ``deque``; span ``args`` must be plain host values
  (ints / floats / strings), never device arrays;
* **zero work when disabled** — ``span()`` returns a preallocated
  no-op singleton and ``instant()`` returns immediately, so a
  telemetry-off engine traces nothing and allocates nothing per step
  (``table_telemetry`` in ``benchmarks/bench_serving.py`` gates the
  telemetry-ON overhead at <= 2%; off is free by construction).

``attribute_steps`` post-processes the ring into the per-step
host-vs-device wall-time split (``engine.attribution()``): device time
is the sum of ``cat="device"`` spans inside each step span — dispatch
issue plus the readback sync — and host time is the remainder (plan,
absorb, detokenize, bookkeeping).
"""
from __future__ import annotations

import json
from collections import deque
from time import perf_counter_ns
from typing import Dict, Iterable, List, Optional

__all__ = ["Span", "SpanTracer", "NULL_TRACER", "attribute_steps",
           "validate_chrome_trace"]


class Span:
    """One completed (or instant) trace event.

    ``ts`` / ``dur`` are integer nanoseconds from ``perf_counter_ns``
    (monotonic; comparable across spans of one process, not across
    processes).  ``dur is None`` marks an instant event (a point in
    time with no extent — request lifecycle marks use these).
    """
    __slots__ = ("name", "cat", "ts", "dur", "depth", "args")

    def __init__(self, name: str, cat: str, ts: int, dur: Optional[int],
                 depth: int, args: Optional[dict]):
        self.name = name
        self.cat = cat
        self.ts = ts
        self.dur = dur
        self.depth = depth
        self.args = args

    def __repr__(self) -> str:  # debugging aid only
        dur = "instant" if self.dur is None else f"{self.dur / 1e3:.1f}us"
        return f"Span({self.name!r}, cat={self.cat!r}, {dur}, " \
               f"depth={self.depth})"


class _SpanCtx:
    """Context manager for one open span (allocated per span when the
    tracer is enabled; the disabled path never reaches here)."""
    __slots__ = ("_tracer", "name", "cat", "args", "_t0")

    def __init__(self, tracer: "SpanTracer", name: str, cat: str,
                 args: Optional[dict]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def set(self, **kw) -> "_SpanCtx":
        """Attach args discovered mid-span (host values only)."""
        if self.args is None:
            self.args = {}
        self.args.update(kw)
        return self

    def __enter__(self) -> "_SpanCtx":
        self._tracer._depth += 1
        self._t0 = perf_counter_ns()
        return self

    def __exit__(self, *exc) -> None:
        t1 = perf_counter_ns()
        tr = self._tracer
        tr._depth -= 1
        tr._total += 1
        tr._ring.append(Span(self.name, self.cat, self._t0, t1 - self._t0,
                             tr._depth, self.args))


class _NullSpanCtx:
    """The shared no-op span: what a disabled tracer hands out.  One
    instance for the whole process — entering it does nothing, so the
    disabled fast path costs one attribute check and zero allocations."""
    __slots__ = ()

    def set(self, **kw) -> "_NullSpanCtx":
        return self

    def __enter__(self) -> "_NullSpanCtx":
        return self

    def __exit__(self, *exc) -> None:
        return None


_NULL_CTX = _NullSpanCtx()


class SpanTracer:
    """Bounded-ring span recorder with Chrome-trace JSON export.

    capacity: ring size in completed spans/events; the oldest are
              dropped first (``dropped`` counts them), so a long-lived
              server holds the most recent window — exactly what
              steady-state attribution wants.
    enabled:  False hands out the no-op singleton (zero work, empty
              ring); flip with ``enable()`` / ``disable()`` at a step
              boundary (open spans of the old mode finish recording).
    """

    def __init__(self, capacity: int = 65536, enabled: bool = True):
        self.capacity = int(capacity)
        self.enabled = bool(enabled)
        self._ring: deque = deque(maxlen=self.capacity)
        self._depth = 0
        self._total = 0

    # ------------------------------------------------------------ record
    def span(self, name: str, cat: str = "host",
             args: Optional[dict] = None):
        """Open a nested span: ``with tracer.span("plan"): ...``."""
        if not self.enabled:
            return _NULL_CTX
        return _SpanCtx(self, name, cat, args)

    def instant(self, name: str, cat: str = "event",
                args: Optional[dict] = None) -> None:
        """Record a zero-duration lifecycle mark (e.g. ``req.arrival``)."""
        if not self.enabled:
            return
        self._total += 1
        self._ring.append(Span(name, cat, perf_counter_ns(), None,
                               self._depth, args))

    # ------------------------------------------------------------ control
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        """Drop recorded spans (e.g. to scope attribution to a
        steady-state window); the dropped count resets too."""
        self._ring.clear()
        self._total = 0

    # ------------------------------------------------------------ read
    @property
    def dropped(self) -> int:
        """Events evicted by ring truncation since the last ``clear``."""
        return max(0, self._total - len(self._ring))

    def spans(self) -> List[Span]:
        """Snapshot of the ring, oldest first (completion order)."""
        return list(self._ring)

    # ------------------------------------------------------------ export
    def to_chrome_trace(self, *, pid: int = 1, tid: int = 1) -> Dict:
        """The ring as a Chrome trace-event document (Perfetto-loadable).

        Complete spans become ``ph: "X"`` events with microsecond
        ``ts``/``dur``; instants become ``ph: "i"`` (thread scope).
        """
        events = []
        for s in self._ring:
            ev: Dict = {"name": s.name, "cat": s.cat, "pid": pid,
                        "tid": tid, "ts": s.ts / 1e3}
            if s.dur is None:
                ev["ph"] = "i"
                ev["s"] = "t"
            else:
                ev["ph"] = "X"
                ev["dur"] = s.dur / 1e3
            if s.args:
                ev["args"] = dict(s.args)
            events.append(ev)
        return {"traceEvents": events,
                "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def save(self, path: str) -> None:
        """Write the Chrome trace JSON (open in Perfetto / about:tracing)."""
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, indent=1)


#: process-wide disabled tracer: the default for components (e.g.
#: ``ModelRunner``) that are constructed without an engine-owned tracer.
NULL_TRACER = SpanTracer(capacity=1, enabled=False)


def validate_chrome_trace(doc: Dict) -> List[str]:
    """Best-effort trace-event schema check; returns a list of problems
    (empty = valid).  Used by the obs tests and the CI artifact smoke."""
    problems: List[str] = []
    if not isinstance(doc.get("traceEvents"), list):
        return ["missing traceEvents list"]
    for i, ev in enumerate(doc["traceEvents"]):
        for key in ("name", "ph", "ts", "pid", "tid"):
            if key not in ev:
                problems.append(f"event {i}: missing {key!r}")
        ph = ev.get("ph")
        if ph not in ("X", "i", "B", "E", "M", "C"):
            problems.append(f"event {i}: unknown ph {ph!r}")
        if ph == "X" and not isinstance(ev.get("dur"), (int, float)):
            problems.append(f"event {i}: X event without numeric dur")
        if not isinstance(ev.get("ts"), (int, float)):
            problems.append(f"event {i}: non-numeric ts")
    try:
        json.dumps(doc)
    except (TypeError, ValueError) as e:
        problems.append(f"not JSON-serializable: {e}")
    return problems


def attribute_steps(spans: Iterable[Span], window: Optional[int] = None,
                    step_name: str = "engine.step",
                    device_cat: str = "device") -> Dict[str, float]:
    """Host-vs-device wall-time attribution over the last ``window``
    *work* steps (steps that issued at least one device-cat span).

    For each ``step_name`` span, device time is the sum of top-level
    ``device_cat`` spans it contains — dispatch issue plus the readback
    sync boundary — and host time is the remainder (plan, absorb,
    detokenize, scheduler bookkeeping).  Returns per-step means in
    milliseconds plus the host share; all-NaN when no step qualifies
    (e.g. the tracer was disabled).
    """
    spans = list(spans)
    steps = [s for s in spans if s.name == step_name and s.dur is not None]
    device = [s for s in spans if s.cat == device_cat and s.dur is not None]
    # guard against double counting if a device span ever nests inside
    # another (today they are siblings; keep the invariant cheap to hold)
    top = [d for d in device
           if not any(o is not d and o.ts <= d.ts
                      and d.ts + d.dur <= o.ts + o.dur for o in device)]
    rows: List[tuple] = []
    for st in steps:
        end = st.ts + st.dur
        dev = sum(d.dur for d in top if st.ts <= d.ts and d.ts + d.dur <= end)
        if dev > 0:                       # work steps only
            rows.append((st.dur, dev))
    if window is not None:
        rows = rows[-int(window):]
    if not rows:
        nan = float("nan")
        return {"steps": 0.0, "step_ms": nan, "host_ms": nan,
                "device_ms": nan, "host_frac": nan, "device_frac": nan}
    n = len(rows)
    step_ms = sum(r[0] for r in rows) / n / 1e6
    device_ms = sum(r[1] for r in rows) / n / 1e6
    host_ms = step_ms - device_ms
    return {"steps": float(n), "step_ms": step_ms, "host_ms": host_ms,
            "device_ms": device_ms, "host_frac": host_ms / step_ms,
            "device_frac": device_ms / step_ms}
