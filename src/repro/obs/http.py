"""Stdlib-HTTP exposition of the obs layer (no third-party server).

``start_obs_server(port, registry=..., health_fn=..., tracer=...)``
spins up a daemon-threaded ``ThreadingHTTPServer`` serving

* ``/metrics`` — Prometheus text exposition of the registry;
* ``/health``  — JSON snapshot of ``engine.health()`` (O(1), never
  dispatches — safe for load-balancer probes every second);
* ``/trace``   — the current span ring as Chrome-trace JSON (load in
  Perfetto), when a tracer is attached.

Reads race benignly with the engine thread: every exposed value is a
plain Python float guarded by the GIL, so a scrape sees a consistent-
enough point-in-time view without ever blocking the serving loop.
Port 0 binds an ephemeral port (tests); ``server.server_address[1]``
reports the bound port either way.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Optional

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import SpanTracer

__all__ = ["start_obs_server"]


def _make_handler(registry: Optional[MetricsRegistry],
                  health_fn: Optional[Callable[[], dict]],
                  tracer: Optional[SpanTracer]):
    class ObsHandler(BaseHTTPRequestHandler):
        def _send(self, code: int, body: bytes, ctype: str) -> None:
            self.send_response(code)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def do_GET(self) -> None:            # noqa: N802 (stdlib API name)
            path = self.path.split("?", 1)[0]
            if path == "/metrics" and registry is not None:
                self._send(200, registry.to_prometheus().encode(),
                           "text/plain; version=0.0.4; charset=utf-8")
            elif path == "/health" and health_fn is not None:
                snap = {k: (v if v == v and abs(v) != float("inf")
                            else None)              # NaN/inf -> JSON null
                        for k, v in health_fn().items()}
                self._send(200, json.dumps(snap).encode(),
                           "application/json")
            elif path == "/trace" and tracer is not None:
                self._send(200,
                           json.dumps(tracer.to_chrome_trace()).encode(),
                           "application/json")
            else:
                self._send(404, b"not found\n", "text/plain")

        def log_message(self, *a) -> None:   # keep the serving stdout clean
            pass

    return ObsHandler


def start_obs_server(port: int, *,
                     registry: Optional[MetricsRegistry] = None,
                     health_fn: Optional[Callable[[], dict]] = None,
                     tracer: Optional[SpanTracer] = None,
                     host: str = "127.0.0.1") -> ThreadingHTTPServer:
    """Bind and start the obs endpoint in a daemon thread; returns the
    server (``.server_address[1]`` is the bound port, ``.shutdown()``
    stops it)."""
    server = ThreadingHTTPServer(
        (host, port), _make_handler(registry, health_fn, tracer))
    server.daemon_threads = True
    t = threading.Thread(target=server.serve_forever,
                         name="repro-obs-http", daemon=True)
    t.start()
    return server
