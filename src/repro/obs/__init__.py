"""``repro.obs`` — serving-wide observability (see docs/OBSERVABILITY.md).

Three stdlib-only pieces (no jax anywhere in this package):

* ``obs.trace``   — ``SpanTracer``: nested spans on a bounded ring with
  Chrome-trace-event export, plus ``attribute_steps`` (the per-step
  host-vs-device wall-time split behind ``engine.attribution()``);
* ``obs.metrics`` — ``MetricsRegistry``: counters / gauges /
  fixed-bucket histograms with Prometheus text exposition and a JSON
  snapshot; ``MetricsDict`` keeps the engine's historical metrics-dict
  idiom backed by the registry;
* ``obs.http``    — ``start_obs_server``: ``/metrics`` + ``/health``
  (+ ``/trace``) on a daemon-threaded stdlib HTTP server.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsDict,
                               MetricsRegistry)
from repro.obs.trace import (NULL_TRACER, Span, SpanTracer,
                             attribute_steps, validate_chrome_trace)

__all__ = ["Counter", "Gauge", "Histogram", "MetricsDict",
           "MetricsRegistry", "NULL_TRACER", "Span", "SpanTracer",
           "attribute_steps", "validate_chrome_trace"]
