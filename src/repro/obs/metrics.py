"""Serving metrics registry: counters / gauges / fixed-bucket histograms.

One :class:`MetricsRegistry` per engine is the single source of truth
for every number ``report()`` and ``health()`` expose.  The engine's
historical ``self.metrics`` dict survives as :class:`MetricsDict`, a
``MutableMapping`` facade whose items are registry counters — so every
existing call site (``self.metrics["gen_tokens"] += 1`` in the engine,
``metrics.setdefault(...)`` in the scheduler) keeps working unchanged
while the values live in exactly one place.

Exposition formats:

* ``to_prometheus()`` — the text format scrape endpoints speak
  (``# TYPE`` lines, ``_bucket{le=...}`` cumulative histograms);
  served by ``repro.obs.http`` under ``/metrics``;
* ``snapshot()`` — a NaN-free JSON-ready dict (the CI trace-artifact
  smoke uploads one next to the span timeline).

Like ``obs.trace`` this module imports no jax and must never block on a
device: every recorded value is a plain host float.
"""
from __future__ import annotations

from collections import deque
from collections.abc import MutableMapping
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "MetricsDict", "LATENCY_BUCKETS_MS"]

#: default latency buckets (milliseconds): wide enough for queue waits
#: on a loaded server, fine enough to place a 2-40ms ITL.
LATENCY_BUCKETS_MS: Tuple[float, ...] = (
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0,
    250.0, 500.0, 1000.0, 2500.0, 5000.0, 10000.0)


def _valid_name(name: str) -> str:
    ok = all(c.isalnum() or c in "_:" for c in name) and name \
        and not name[0].isdigit()
    if not ok:
        raise ValueError(f"invalid metric name {name!r} "
                         "(expected [a-zA-Z_:][a-zA-Z0-9_:]*)")
    return name


class Counter:
    """Monotonic-by-convention scalar.  ``set`` exists because the
    engine's windowed figures (``reset_dispatch_window``) rewind their
    counters to scope a measurement — our registry allows it and the
    Prometheus scraper sees it as a counter reset, which scrape-side
    ``rate()`` already handles."""
    __slots__ = ("name", "help", "_value")
    prom_type = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = _valid_name(name)
        self.help = help
        self._value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self._value += v

    def set(self, v: float) -> None:
        self._value = float(v)

    def get(self) -> float:
        return self._value


class Gauge(Counter):
    """A scalar that goes both ways (queue depth, EMA, pool pressure)."""
    __slots__ = ()
    prom_type = "gauge"


class Histogram:
    """Fixed-bucket cumulative histogram with an optional bounded
    raw-sample window.

    Buckets are upper bounds (``value <= bound`` lands in the bucket,
    Prometheus ``le`` semantics) plus an implicit ``+Inf``.  The bucket
    counts / sum / count are cumulative forever (what ``/metrics``
    exports); the raw-sample deque — bounded at ``sample_maxlen`` — is
    the *percentile window*: ``percentile()`` reads it exactly, and
    ``clear_samples()`` re-scopes it (``engine.reset_itl_window``)
    without disturbing the cumulative series.
    """
    __slots__ = ("name", "help", "buckets", "counts", "sum", "count",
                 "_samples")
    prom_type = "histogram"

    def __init__(self, name: str, buckets: Sequence[float],
                 help: str = "", sample_maxlen: int = 8192):
        if not buckets or list(buckets) != sorted(buckets):
            raise ValueError(f"buckets must be ascending, got {buckets!r}")
        self.name = _valid_name(name)
        self.help = help
        self.buckets: Tuple[float, ...] = tuple(float(b) for b in buckets)
        self.counts = [0] * (len(self.buckets) + 1)   # +1: the +Inf bucket
        self.sum = 0.0
        self.count = 0
        self._samples: Optional[deque] = \
            deque(maxlen=int(sample_maxlen)) if sample_maxlen else None

    def observe(self, v: float) -> None:
        v = float(v)
        i = 0
        for b in self.buckets:                 # tiny fixed loop; no deps
            if v <= b:
                break
            i += 1
        self.counts[i] += 1
        self.sum += v
        self.count += 1
        if self._samples is not None:
            self._samples.append(v)

    # ------------------------------------------------------------ reads
    def samples(self) -> List[float]:
        return list(self._samples or ())

    def clear_samples(self) -> None:
        """Re-scope the percentile window (cumulative series untouched)."""
        if self._samples is not None:
            self._samples.clear()

    def percentile(self, p: float) -> float:
        """Exact percentile over the bounded sample window (NaN when
        empty) — linear interpolation, matching ``numpy.percentile``."""
        xs = sorted(self._samples or ())
        if not xs:
            return float("nan")
        if len(xs) == 1:
            return xs[0]
        rank = (p / 100.0) * (len(xs) - 1)
        lo = int(rank)
        frac = rank - lo
        hi = min(lo + 1, len(xs) - 1)
        return xs[lo] * (1.0 - frac) + xs[hi] * frac

    def cumulative(self) -> List[Tuple[str, int]]:
        """``(le, cumulative_count)`` pairs, ``+Inf`` last."""
        out = []
        acc = 0
        for b, c in zip(self.buckets, self.counts):
            acc += c
            out.append((format(b, "g"), acc))
        out.append(("+Inf", acc + self.counts[-1]))
        return out


class MetricsRegistry:
    """Named metrics, one namespace; get-or-create accessors."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get_or_create(self, cls, name: str, help: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help=help, **kw)
            self._metrics[name] = m
            return m
        if not isinstance(m, cls) or type(m) is not cls:
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, buckets: Sequence[float]
                  = LATENCY_BUCKETS_MS, help: str = "",
                  sample_maxlen: int = 8192) -> Histogram:
        return self._get_or_create(Histogram, name, help, buckets=buckets,
                                   sample_maxlen=sample_maxlen)

    def get(self, name: str):
        return self._metrics.get(name)

    def remove(self, name: str) -> None:
        self._metrics.pop(name, None)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    # ------------------------------------------------------------ export
    def to_prometheus(self) -> str:
        """Text exposition format (version 0.0.4): what ``/metrics``
        serves and what ``promtool check metrics`` accepts."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.prom_type}")
            if isinstance(m, Histogram):
                for le, acc in m.cumulative():
                    lines.append(f'{name}_bucket{{le="{le}"}} {acc}')
                lines.append(f"{name}_sum {m.sum:g}")
                lines.append(f"{name}_count {m.count}")
            else:
                lines.append(f"{name} {m.get():g}")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> Dict:
        """NaN-free JSON-ready snapshot of every registered metric."""
        out: Dict = {"counters": {}, "gauges": {}, "histograms": {}}
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, Histogram):
                out["histograms"][name] = {
                    "count": m.count, "sum": m.sum,
                    "buckets": {le: acc for le, acc in m.cumulative()}}
            elif isinstance(m, Gauge):
                v = m.get()
                out["gauges"][name] = v if v == v else None   # NaN -> null
            else:
                out["counters"][name] = m.get()
        return out


class MetricsDict(MutableMapping):
    """Dict-shaped facade over registry counters.

    ``m["gen_tokens"] += 1`` reads and writes the registry counter
    ``<prefix>gen_tokens`` — the engine and scheduler keep their
    historical dict idiom (including ``setdefault``) while the registry
    stays the single source of truth.  Keys are the bare historical
    names; the prefix only namespaces the Prometheus exposition.
    """

    def __init__(self, registry: MetricsRegistry, prefix: str = "repro_",
                 initial: Optional[Dict[str, float]] = None):
        self._reg = registry
        self._prefix = prefix
        self._by_key: Dict[str, Counter] = {}
        for k, v in (initial or {}).items():
            self[k] = v

    def metric(self, key: str) -> Counter:
        """The backing registry counter (creating it if needed)."""
        m = self._by_key.get(key)
        if m is None:
            m = self._reg.counter(self._prefix + key)
            self._by_key[key] = m
        return m

    def __getitem__(self, key: str) -> float:
        if key not in self._by_key:
            raise KeyError(key)
        return self._by_key[key].get()

    def __setitem__(self, key: str, value: float) -> None:
        self.metric(key).set(float(value))

    def __delitem__(self, key: str) -> None:
        m = self._by_key.pop(key)
        self._reg.remove(m.name)

    def __iter__(self) -> Iterator[str]:
        return iter(self._by_key)

    def __len__(self) -> int:
        return len(self._by_key)
