"""Distributed training step: pjit + FSDP/TP shardings, microbatch
accumulation, remat policy, and optional int8 error-feedback gradient
compression (on-the-wire all-to-all reduce — DESIGN.md §4)."""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, OptState, apply_updates, init_opt_state
from repro.runtime.sharding import (ParallelCtx, param_shardings,
                                    shard_map)


# --------------------------------------------------------------------------
# Plain pjit train step
# --------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    ctx: Optional[ParallelCtx] = None,
                    rt: Optional[dict] = None,
                    num_microbatches: int = 1) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    rt = dict(rt or {})
    if "remat_policy" not in rt:
        # save matmul outputs that feed collectives; recompute the rest
        rt["remat_policy"] = jax.checkpoint_policies.nothing_saveable

    def loss_of(params, batch):
        return T.loss_fn(cfg, params, batch, ctx, rt)

    def step(params, opt_state: OptState, batch):
        if num_microbatches > 1:
            def micro(carry, mb):
                acc, = carry
                l, g = jax.value_and_grad(loss_of)(params, mb)
                acc = jax.tree.map(lambda a, b: a + b, acc,
                                   {"loss": l, "grads": g})
                return (acc,), None

            zeros = {"loss": jnp.zeros(()),
                     "grads": jax.tree.map(lambda p: jnp.zeros(p.shape,
                                                               jnp.float32),
                                           params)}
            mbs = jax.tree.map(
                lambda x: x.reshape(num_microbatches,
                                    x.shape[0] // num_microbatches,
                                    *x.shape[1:]), batch)
            (acc,), _ = jax.lax.scan(micro, (zeros,), mbs)
            loss = acc["loss"] / num_microbatches
            grads = jax.tree.map(lambda g: g / num_microbatches, acc["grads"])
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        new_p, new_s, m = apply_updates(params, grads, opt_state, opt_cfg)
        return new_p, new_s, {"loss": loss, **m}

    return step


def jit_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                   ctx: Optional[ParallelCtx], params_tmpl: Any,
                   rt: Optional[dict] = None, num_microbatches: int = 1):
    """jit with explicit in/out shardings + donated state."""
    step = make_train_step(cfg, opt_cfg, ctx, rt, num_microbatches)
    if ctx is None:
        return jax.jit(step, donate_argnums=(0, 1))
    p_sh = param_shardings(ctx, params_tmpl, cfg)
    o_tmpl = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), params_tmpl)
    rep = NamedSharding(ctx.mesh, P())
    o_sh = OptState(step=rep, mu=param_shardings(ctx, o_tmpl.mu, cfg),
                    nu=param_shardings(ctx, o_tmpl.nu, cfg))
    return jax.jit(step, in_shardings=(p_sh, o_sh, None),
                   out_shardings=(p_sh, o_sh, None),
                   donate_argnums=(0, 1))


# --------------------------------------------------------------------------
# int8 error-feedback compressed gradient reduction
# --------------------------------------------------------------------------
#
# For DP/TP (fsdp=False) regimes: gradients cross the wire as int8.
# Per dp-shard: q_i = round((g_i + e_i)/s_i); an all_to_all exchanges int8
# chunks (each shard dequantizes and sums its 1/N of the vector in f32),
# the chunk-sums are re-quantized and all_gathered back as int8. Error
# feedback keeps the quantization noise from biasing convergence.

def _flatten_f32(tree):
    leaves = jax.tree.leaves(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    return flat, leaves


def _unflatten_like(flat, tree):
    leaves, treedef = jax.tree.flatten(tree)
    out, o = [], 0
    for l in leaves:
        n = int(np.prod(l.shape))
        out.append(flat[o:o + n].reshape(l.shape))
        o += n
    return jax.tree.unflatten(treedef, out)


def make_compressed_grad_fn(cfg: ModelConfig, ctx: ParallelCtx,
                            rt: Optional[dict] = None):
    """Returns f(params, batch, err) -> (loss, grads, new_err).

    Requires fsdp=False (params replicated over dp). err: f32 flat vector
    sharded over dp on a leading axis [dp, M].
    """
    assert not ctx.fsdp, "int8-EF compression requires fsdp=False (DESIGN §4)"
    rt = dict(rt or {})
    dp = ctx.dp_axes
    N = ctx.dp_size

    def loss_of(params, batch):
        return T.loss_fn(cfg, params, batch, None, rt)

    def local(params, batch, err):
        loss, g = jax.value_and_grad(loss_of)(params, batch)
        flat, _ = _flatten_f32(g)
        M = flat.shape[0]
        pad = (-M) % N
        flat = jnp.pad(flat, (0, pad))
        x = flat + err[0]
        scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-20
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        new_err = x - q.astype(jnp.float32) * scale
        # exchange int8 chunks; shard j receives chunk j from everyone
        chunks = q.reshape(N, -1)
        recv = jax.lax.all_to_all(chunks, dp, split_axis=0, concat_axis=0,
                                  tiled=True)                   # [N, M/N] int8
        scales = jax.lax.all_gather(scale, dp, tiled=False)     # [N]
        part = (recv.astype(jnp.float32)
                * scales.reshape(N, 1)).sum(0) / N              # [M/N]
        s2 = jnp.max(jnp.abs(part)) / 127.0 + 1e-20
        q2 = jnp.clip(jnp.round(part / s2), -127, 127).astype(jnp.int8)
        s2g = jax.lax.all_gather(s2, dp, tiled=False)           # [N]
        qg = jax.lax.all_gather(q2, dp, tiled=True)             # [M]
        deq = qg.astype(jnp.float32) * jnp.repeat(s2g, qg.shape[0] // N)
        loss = jax.lax.pmean(loss, dp)
        g_avg = _unflatten_like(deq[:M], g)
        return loss, g_avg, new_err[None]

    def f(params, batch, err):
        return shard_map(
            local, mesh=ctx.mesh,
            in_specs=(P(), P(dp), P(dp)),
            out_specs=(P(), P(), P(dp)),
            check_vma=False,
        )(params, batch, err)

    return f


def init_error_buffer(ctx: ParallelCtx, params) -> jnp.ndarray:
    """Per-dp-shard error-feedback state: [N, M_pad], sharded over dp."""
    M = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    N = ctx.dp_size
    M_pad = M + ((-M) % N)
    return jnp.zeros((N, M_pad), jnp.float32)
