"""Fault tolerance: checkpoint/restart supervision, straggler mitigation,
elastic re-meshing. The mechanisms are real (and unit-tested); the failure
*signals* on a single-host CPU box are injected (see tests) — on a cluster
they come from the coordinator's heartbeat service.
"""
from __future__ import annotations

import logging
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional

import jax

log = logging.getLogger("repro.fault")


@dataclass
class StragglerDetector:
    """EMA step-time monitor. A step slower than ``threshold``× the EMA is
    flagged; after ``patience`` consecutive flags the runner is told to
    re-slot (on TPU pods: evict + reschedule the slow host's shard)."""
    threshold: float = 3.0
    patience: int = 3
    ema: Optional[float] = None
    alpha: float = 0.1
    _strikes: int = 0
    #: most recent straggler flags only — a long-lived serving engine
    #: observes every step forever, so an unbounded list is a slow leak
    events: Deque[Dict[str, float]] = field(
        default_factory=lambda: deque(maxlen=256))

    def observe(self, step: int, dt: float) -> str:
        if self.ema is None:
            self.ema = dt
            return "ok"
        verdict = "ok"
        if dt > self.threshold * self.ema:
            self._strikes += 1
            self.events.append({"step": step, "dt": dt, "ema": self.ema})
            verdict = "straggler" if self._strikes < self.patience \
                else "reslot"
            if verdict == "reslot":
                self._strikes = 0
        else:
            self._strikes = 0
            self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
        return verdict


class PreemptionError(RuntimeError):
    """Raised by the (injected or real) failure signal mid-training."""


@dataclass
class Supervisor:
    """Checkpoint-restart training supervision.

    ``run`` drives ``step_fn`` for ``total_steps``; any exception triggers a
    restore from the latest checkpoint and a bounded number of retries —
    the node-failure story. State is (params, opt_state, data_state).
    """
    checkpointer: Any                      # Checkpointer
    save_every: int = 50
    max_restarts: int = 3
    straggler: StragglerDetector = field(default_factory=StragglerDetector)
    restarts: int = 0
    history: List[Dict[str, Any]] = field(default_factory=list)

    def run(self, *, total_steps: int, state: Dict[str, Any],
            step_fn: Callable[[int, Dict[str, Any]], Dict[str, Any]],
            restore_fn: Callable[[int], Dict[str, Any]],
            fail_hook: Optional[Callable[[int], None]] = None
            ) -> Dict[str, Any]:
        step = int(state.get("step", 0))
        while step < total_steps:
            try:
                if fail_hook is not None:
                    fail_hook(step)
                t0 = time.perf_counter()
                state = step_fn(step, state)
                dt = time.perf_counter() - t0
                verdict = self.straggler.observe(step, dt)
                if verdict == "reslot":
                    log.warning("straggler at step %d (%.3fs vs ema %.3fs): "
                                "re-slotting", step, dt, self.straggler.ema)
                step += 1
                state["step"] = step
                if step % self.save_every == 0 or step == total_steps:
                    self.checkpointer.save(step, state["trees"],
                                           extra=state.get("extra", {}))
                    self.history.append({"event": "save", "step": step})
            except Exception as e:          # node failure / preemption
                self.restarts += 1
                self.history.append({"event": "restart", "step": step,
                                     "error": repr(e)})
                if self.restarts > self.max_restarts:
                    raise
                last = self.checkpointer.latest_step()
                log.warning("failure at step %d (%r); restoring step %s "
                            "(restart %d/%d)", step, e, last, self.restarts,
                            self.max_restarts)
                if last is None:
                    step = 0
                    continue
                state = restore_fn(last)
                step = int(state["step"])
        return state


def elastic_remesh(trees: Dict[str, Any], make_shardings: Callable[[Any], Any],
                   ) -> Dict[str, Any]:
    """Re-place a (restored) state on the *current* device topology.

    Checkpoints are topology-independent (plain arrays + logical sharding
    rules), so elastic up/down-scaling is just device_put with shardings
    recomputed for the new mesh.
    """
    out = {}
    for name, tree in trees.items():
        sh = make_shardings(tree)
        out[name] = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else x,
            tree, sh)
    return out
