"""Sharding rules: DP/FSDP/TP/EP over the (pod, data, model) mesh.

``ParallelCtx`` is threaded through model code; ``None`` means single-device
(smoke tests). Rules are conditional on divisibility: dimensions that do not
divide the axis size are replicated (e.g. 12 q-heads or 2 kv-heads on a
16-way model axis) — see DESIGN.md §4 and the hillclimb log for the cost.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ParallelCtx:
    mesh: Mesh
    dp_axes: Tuple[str, ...] = ("data",)     # ("pod","data") multi-pod
    tp_axis: Optional[str] = "model"         # None => dp_only policy
    fsdp: bool = True                        # shard params/opt over dp too
    # serving: paged pools + block tables are manual (shard_map) over dp
    # so decode attention is collective-free (DESIGN.md §4).

    @property
    def dp_size(self) -> int:
        n = 1
        for a in self.dp_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp_axis] if self.tp_axis else 1

    def axis_size(self, axes) -> int:
        if isinstance(axes, str):
            return self.mesh.shape[axes]
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n


def make_ctx(mesh: Optional[Mesh], policy: str = "2d") -> Optional[ParallelCtx]:
    """policy: "2d" = DP/FSDP x TP (default); "dp_only" = the model axis
    joins data parallelism (no TP) — the right call for small dense models
    whose TP all-reduces dominate the roofline (EXPERIMENTS.md §Perf)."""
    if mesh is None:
        return None
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    if policy == "dp_only":
        return ParallelCtx(mesh=mesh, dp_axes=dp + ("model",), tp_axis=None)
    return ParallelCtx(mesh=mesh, dp_axes=dp)


def _div(n: int, size: int) -> bool:
    return n % size == 0 and n >= size


def shard_map(f, mesh, in_specs, out_specs, axis_names=None,
              check_vma=True):
    """``jax.shard_map`` across jax versions.

    Newer jax exposes it at the top level with ``axis_names`` (the axes
    made Manual) and ``check_vma``; 0.4.x has only
    ``jax.experimental.shard_map.shard_map`` where the same intent is the
    complementary ``auto`` set and ``check_rep``.  Model code calls this
    shim so the 512-device dry-run lowers on the pinned CPU jax too.
    ``check_vma`` defaults to True like upstream — the island call sites
    that opt out of replication checking say so explicitly.
    """
    if hasattr(jax, "shard_map"):
        kw = {} if axis_names is None else {"axis_names": axis_names}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    auto = (frozenset(mesh.axis_names) - frozenset(axis_names)
            if axis_names is not None else frozenset())
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=bool(check_vma), auto=auto)


def shard(ctx: Optional[ParallelCtx], x: jnp.ndarray, spec: P) -> jnp.ndarray:
    """with_sharding_constraint if a mesh is present, else identity."""
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def act_spec(ctx: ParallelCtx, *rest) -> P:
    """[B, ...] activation spec: batch over dp."""
    return P(ctx.dp_axes, *rest)


# --------------------------------------------------------------------------
# Parameter partition specs, keyed by param-tree path.
# --------------------------------------------------------------------------

def param_spec(ctx: ParallelCtx, path: str, shape: Tuple[int, ...],
               cfg) -> P:
    """PartitionSpec for one parameter, by path name + shape.

    Layer-stacked params have a leading L dim (never sharded). TP shards
    head/ffn/expert/vocab dims over `model` when divisible; FSDP shards the
    largest remaining dim over dp when divisible.
    """
    tp, dp = ctx.tp_axis, ctx.dp_axes
    tpn = ctx.tp_size
    dpn = ctx.dp_size
    name = path.split("/")[-1]
    stacked = path.startswith("layers") or "_layers" in path.split("/")[0]
    off = 1 if stacked else 0                  # leading L dim
    dims: list = [None] * len(shape)

    def fsdp_on(i):
        if ctx.fsdp and dims[i] is None and _div(shape[i], dpn):
            dims[i] = dp

    if name in ("w", "b", "A_log", "D", "a_param"):       # norms / small vecs
        pass
    elif name == "embed" or name == "head":
        # [V, d] / [d, V]
        v_dim = off + (0 if name == "embed" else 1)
        d_dim = off + (1 if name == "embed" else 0)
        if _div(shape[v_dim], tpn):
            dims[v_dim] = tp
        fsdp_on(d_dim)
    elif name in ("wq",):                                  # [L, d, H, Dh]
        if _div(shape[off + 1], tpn):
            dims[off + 1] = tp
        fsdp_on(off)
    elif name in ("wk", "wv"):                             # [L, d, KV, Dh]
        if _div(shape[off + 1], tpn):
            dims[off + 1] = tp
        fsdp_on(off)
    elif name == "wo":                                     # [L, H, Dh, d]
        if _div(shape[off], tpn):
            dims[off] = tp
        fsdp_on(off + 2)
    elif name in ("bq",):                                  # [L, H, Dh]
        if _div(shape[off], tpn):
            dims[off] = tp
    elif name in ("bk", "bv"):
        if _div(shape[off], tpn):
            dims[off] = tp
    elif name in ("w_gate", "w_up"):                       # [L, d, f]
        if _div(shape[off + 1], tpn):
            dims[off + 1] = tp
        fsdp_on(off)
    elif name == "w_down":                                 # [L, f, d]
        if _div(shape[off], tpn):
            dims[off] = tp
        fsdp_on(off + 1)
    elif name == "router":                                 # [L, d, E]
        pass                                               # small, replicated
    elif name in ("we_gate", "we_up"):                     # [L, E, d, f] routed
        if _div(shape[off], tpn):
            dims[off] = tp                                 # EP over experts
        fsdp_on(off + 1)
    elif name == "we_down":                                # [L, E, f, d]
        if _div(shape[off], tpn):
            dims[off] = tp
        fsdp_on(off + 2)
    elif name in ("ws_gate", "ws_up"):                     # [L, d, fs] shared
        if _div(shape[off + 1], tpn):
            dims[off + 1] = tp
        fsdp_on(off)
    elif name == "ws_down":
        if _div(shape[off], tpn):
            dims[off] = tp
        fsdp_on(off + 1)
    elif name in ("in_proj",):                             # [L, d, 2*din] ssm
        if _div(shape[off + 1], tpn):
            dims[off + 1] = tp
        fsdp_on(off)
    elif name in ("out_proj",):                            # [L, din, d]
        if _div(shape[off], tpn):
            dims[off] = tp
        fsdp_on(off + 1)
    elif name in ("x_proj", "dt_proj"):                    # [L, din, *], [L, R, din]
        i = off if name == "x_proj" else off + 1
        if _div(shape[i], tpn):
            dims[i] = tp
    elif name in ("conv_w",):                              # [L, din, W]
        if _div(shape[off], tpn):
            dims[off] = tp
    elif name in ("dt_bias", "conv_b"):
        if _div(shape[off], tpn):
            dims[off] = tp
    elif name in ("w_in", "w_gate_rec"):                   # [L, d, w] rg-lru
        if _div(shape[off + 1], tpn):
            dims[off + 1] = tp
        fsdp_on(off)
    elif name == "w_out_rec":                              # [L, w, d]
        if _div(shape[off], tpn):
            dims[off] = tp
        fsdp_on(off + 1)
    elif name in ("wr", "wi"):                             # [L, w, w] lru gates
        if _div(shape[off + 1], tpn):
            dims[off + 1] = tp
    # quantized artifacts mirror their float parents via path suffix
    elif name in ("qweight", "scales", "zeros"):
        # [*, K', N]: shard N over tp when divisible
        if _div(shape[-1], tpn):
            dims[-1] = tp
    elif name == "g_idx":
        pass
    return P(*dims)


def batch_shardings(ctx: Optional[ParallelCtx], batch: Any) -> Any:
    """Data batch: leading (batch) dim over dp when divisible."""
    if ctx is None:
        return jax.tree.map(lambda _: None, batch)

    def one(x):
        shape = x.shape
        dp = ctx.dp_axes if shape and shape[0] % ctx.dp_size == 0 else None
        return NamedSharding(ctx.mesh,
                             P(dp, *([None] * (len(shape) - 1))))

    return jax.tree.map(one, batch)


def state_shardings(ctx: Optional[ParallelCtx], state: Any, cfg) -> Any:
    """Decode-state shardings: pools over dp on the blocks/seq dim, KV heads
    over model when divisible (DESIGN.md §4)."""
    if ctx is None:
        return {k: None for k in state}
    tp, dp = ctx.tp_axis, ctx.dp_axes
    tpn, dpn = ctx.tp_size, ctx.dp_size

    def dp_if(n):
        return dp if n % dpn == 0 else None

    def tp_if(n):
        return tp if n % tpn == 0 else None

    out = {}
    for k, v in state.items():
        s = v.shape
        if k in ("k_pool", "v_pool"):            # [L, NB, BS, KV, D]
            spec = P(None, dp_if(s[1]), None, tp_if(s[3]), None)
        elif k in ("k_scales", "v_scales"):      # [L, NB, KV] (int8 KV mode)
            spec = P(None, dp_if(s[1]), tp_if(s[2]))
        elif k == "block_table":                 # [B, MB]
            spec = P(dp_if(s[0]), None)
        elif k == "seq_lens":                    # [B]
            spec = P(dp_if(s[0]))
        elif k in ("ssm_h", "ssm_conv"):         # [L, B, din, *]
            spec = P(None, dp_if(s[1]), tp_if(s[2]),
                     *([None] * (len(s) - 3)))
        elif k in ("lru_h", "rec_conv"):         # [nr, B, w, *]
            spec = P(None, dp_if(s[1]), tp_if(s[2]),
                     *([None] * (len(s) - 3)))
        else:
            spec = P()
        out[k] = NamedSharding(ctx.mesh, spec)
    return out


def param_shardings(ctx: Optional[ParallelCtx], params: Any, cfg) -> Any:
    """Pytree of NamedShardings (or None ctx -> None tree)."""
    if ctx is None:
        return jax.tree.map(lambda _: None, params)

    def walk(tree, prefix):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in tree.items()}
        shape = tree.shape if hasattr(tree, "shape") else ()
        return NamedSharding(ctx.mesh, param_spec(ctx, prefix, shape, cfg))

    return walk(params, "")
