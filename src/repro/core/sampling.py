"""Device-side token sampling: greedy / temperature / top-k.

Lives in core (pure jnp, no model or serving dependencies) so both the
serving layer and ``models.transformer.decode_megastep`` can use it
without a serving -> models -> serving import cycle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_device(logits: jnp.ndarray, key, temperatures: jnp.ndarray,
                  top_k: int = 0) -> jnp.ndarray:
    """logits: [B, V]; temperatures: [B] f32 (0 => greedy). Returns [B] i32.

    Pure jnp — safe to call inside jit / lax loops (the fused megastep).
    """
    t = temperatures[:, None]
    greedy = jnp.argmax(logits, axis=-1)
    scaled = logits / jnp.maximum(t, 1e-6)
    if top_k > 0:
        kth = jax.lax.top_k(scaled, top_k)[0][:, -1:]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    sampled = jax.random.categorical(key, scaled, axis=-1)
    return jnp.where(t[:, 0] <= 0.0, greedy, sampled).astype(jnp.int32)
