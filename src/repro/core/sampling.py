"""Device-side token sampling: greedy / temperature / top-k / top-p.

Lives in core (pure jnp, no model or serving dependencies) so both the
serving layer and ``models.transformer.decode_megastep`` can use it
without a serving -> models -> serving import cycle.

The per-slot entry point is ``sample_from_logits``: every slot carries its
own (temperature, top_k, top_p) and — crucially — its own PRNG stream.  A
slot's step key is ``fold_in(base_key, num_generated_tokens)``, i.e. the
stream is indexed by *position in the generation*, not by engine step.
That single choice buys three properties at once:

* the fused megastep (device ``fori_loop``) and the legacy host loop
  compute byte-identical keys, so their sampled tokens match bitwise;
* a request's tokens do not depend on batch composition (slots never
  share a key), so seeded requests reproduce across runs and schedules;
* recompute-style preemption resumes the stream where it left off
  (``counts`` = tokens generated so far survives the requeue).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _filter_top_k_top_p(scaled: jnp.ndarray, top_ks: jnp.ndarray,
                        top_ps: jnp.ndarray) -> jnp.ndarray:
    """Mask logits outside each row's top-k / nucleus (top-p) set to -inf.

    scaled: [B, V]; top_ks: [B] i32 (<= 0 disables); top_ps: [B] f32
    (>= 1.0 disables).  A single values-only descending sort serves both
    filters (XLA CPU sorts are the expensive primitive here — no argsort,
    no inverse-permutation scatter): the kept set reduces to one per-row
    *value threshold* (the smallest sorted logit still inside both the
    top-k prefix and the nucleus), because nucleus-kept entries are a
    prefix of the top-k prefix.  Logits tied with the threshold are all
    kept — deterministic, and identical on every path that calls this.
    """
    V = scaled.shape[-1]
    svals = -jnp.sort(-scaled, axis=-1)                         # [B, V] desc
    rank = jnp.arange(V)[None, :]
    k_eff = jnp.where(top_ks <= 0, V, jnp.clip(top_ks, 1, V))[:, None]
    in_k = rank < k_eff
    # nucleus over the top-k-filtered distribution: keep the smallest
    # prefix whose mass reaches top_p (the top-1 token is always kept —
    # its preceding cumulative mass is 0).
    probs = jax.nn.softmax(jnp.where(in_k, svals, -jnp.inf), axis=-1)
    prior_mass = jnp.cumsum(probs, axis=-1) - probs
    # top_p >= 1.0 must keep the row's whole top-k set even though f32
    # cumsum rounds tail prior_mass up to exactly 1.0 on peaked rows —
    # otherwise a filter-disabled row would be truncated whenever some
    # *other* slot's params force the filter to run, making its sample
    # depend on batch composition.
    keep_sorted = in_k & ((prior_mass < top_ps[:, None])
                          | (top_ps[:, None] >= 1.0))
    thr = jnp.min(jnp.where(keep_sorted, svals, jnp.inf), axis=-1,
                  keepdims=True)
    return jnp.where(scaled >= thr, scaled, -jnp.inf)


def sample_from_logits(logits: jnp.ndarray, base_keys: jnp.ndarray,
                       counts: jnp.ndarray, temps: jnp.ndarray,
                       top_ks: jnp.ndarray, top_ps: jnp.ndarray,
                       poison: jnp.ndarray = None,
                       guard: bool = False) -> jnp.ndarray:
    """Per-slot sampling. Returns [B] i32 token ids.

    logits:    [B, V]
    base_keys: [B, 2] uint32 — one PRNG stream per slot
    counts:    [B] i32 — tokens generated so far (the stream position)
    temps:     [B] f32 — <= 0 means greedy (argmax)
    top_ks:    [B] i32 — <= 0 disables top-k
    top_ps:    [B] f32 — >= 1.0 disables nucleus filtering
    poison:    optional [B] f32 bias added per row before sampling —
               the fault-injection hook (NaN rows exercise the guard end
               to end on device); None means not traced at all
    guard:     static flag — when True, a row whose logits contain any
               non-finite value samples token ``-1`` instead of
               propagating garbage (argmax over NaNs), so the engine can
               fail exactly the poisoned rows.  Rows with finite logits
               are untouched: guard on/off is sample-for-sample
               identical on healthy batches.

    Pure jnp — safe inside jit / lax loops (the fused megastep).  The
    expensive stages are gated on what the batch actually requests
    (``lax.cond`` runs one branch at runtime): an all-greedy batch pays
    only the argmax, and the sort-based top-k/top-p filter runs only
    when some slot asked for it — so the fused decode megastep's warm
    per-step latency is unchanged for the common greedy/temperature
    workloads.
    """
    if poison is not None:
        logits = logits + poison[:, None]
    greedy = jnp.argmax(logits, axis=-1)

    def _sampled(_):
        scaled = logits / jnp.maximum(temps[:, None], 1e-6)
        # only slots that actually sample can need the sort-based filter:
        # a greedy slot's top_k/top_p are irrelevant to its argmax
        needs_filter = jnp.any((temps > 0.0)
                               & ((top_ks > 0) | (top_ps < 1.0)))
        masked = jax.lax.cond(
            needs_filter,
            lambda s: _filter_top_k_top_p(s, top_ks, top_ps),
            lambda s: s, scaled)
        step_keys = jax.vmap(jax.random.fold_in)(base_keys, counts)
        return jax.vmap(lambda k, l: jax.random.categorical(k, l))(
            step_keys, masked)

    sampled = jax.lax.cond(jnp.any(temps > 0.0), _sampled,
                           lambda _: greedy, None)
    tok = jnp.where(temps <= 0.0, greedy, sampled).astype(jnp.int32)
    if guard:
        # one max-reduce instead of isfinite+all over [B, V]: NaN
        # propagates through max, +inf IS the max, and an all--inf row
        # maxes to -inf — while mask-legal -inf entries under a finite
        # max still pass.  Keeps the guarded trace within noise of the
        # unguarded one (the <2% acceptance gate in bench_serving).
        ok = jnp.isfinite(jnp.max(logits, axis=-1))
        tok = jnp.where(ok, tok, -1)
    return tok


def sample_device(logits: jnp.ndarray, key, temperatures: jnp.ndarray,
                  top_k: int = 0) -> jnp.ndarray:
    """Legacy single-key batch sampler (one shared key, uniform top_k).

    Kept for callers that predate per-slot ``SamplingParams``; new code
    should use ``sample_from_logits``.
    """
    t = temperatures[:, None]
    greedy = jnp.argmax(logits, axis=-1)
    scaled = logits / jnp.maximum(t, 1e-6)
    if top_k > 0:
        kth = jax.lax.top_k(scaled, top_k)[0][:, -1:]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    sampled = jax.random.categorical(key, scaled, axis=-1)
    return jnp.where(t[:, 0] <= 0.0, greedy, sampled).astype(jnp.int32)
