"""Quantized paged KV cache: int8 block pool + per-block-per-head scales.

The paper's thesis is that *combining* quantization with paging memory
management is what buys serving headroom; the GPTQ side only quantizes
weights.  This module quantizes the other big HBM consumer — the paged
KV pool — to symmetric per-block-per-head int8:

* values pool ``[L, NB, BS, KV, D]`` int8 (vs bf16/f32), and
* scales pool ``[L, NB, KV]`` f32 — ONE scale per (block, kv head),
  covering all ``BS`` tokens × ``D`` dims of that head's tile,

so KV bytes per cached token drop ~2x vs bf16 (~4x vs the f32 CPU pools)
with a ``2 * L * KV * 4 / BS`` bytes/token scales overhead.  Reads
dequantize in-register: the Pallas decode kernel
(``kernels/paged_attention_quant.py``) multiplies each int8 K/V tile by
its scale inside the online-softmax loop — the quantized cache is never
materialized densely (TurboAttention, arXiv 2412.08585; MILLION, arXiv
2504.03661).

Write discipline (what keeps one scale per block sound):

* a *fresh* block is quantized from exactly the tokens written into it,
  junk slots masked to zero so stale garbage can never inflate the scale;
* an *appending* write (decode, or a chunked-prefill boundary block)
  dequantizes the block's live prefix, merges the new tokens, and
  requantizes the whole block with the recomputed amax.  When the scale
  is unchanged this is exact (``round(q) == q``); when it grows, existing
  values pick up at most half a quantization step — bounded drift, and
  bit-identical between the fused megastep and the legacy loop because
  both run this same op;
* copy-on-write (``copy_blocks_quant``) copies the scale row with the
  value block, so forks keep decoding correctly.

Everything here is shape-compatible with ``core.paged_cache``: the same
``BlockAllocator`` / block tables drive both pool formats, and the bf16
ops remain the parity oracle.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.paged_cache import (copy_blocks, gather_kv,
                                    gather_kv_bounded, write_decode_kv,
                                    write_prefill_kv)

INT8_MAX = 127.0
# floor on amax before the /127: keeps all-zero blocks at scale ~1e-22
# (dequant exactly 0) without 0/0 in the quantize divide.
AMAX_FLOOR = 1e-20

KV_CACHE_DTYPES = ("bf16", "int8")


def normalize_kv_cache_dtype(kv_cache_dtype: Optional[str]) -> str:
    """Accept None / "bf16" / "bfloat16" as the unquantized pool (its
    element dtype stays whatever the runner picks) and "int8" as the
    quantized one."""
    if kv_cache_dtype in (None, "bf16", "bfloat16"):
        return "bf16"
    if kv_cache_dtype == "int8":
        return "int8"
    raise ValueError(f"unknown kv_cache_dtype {kv_cache_dtype!r}; "
                     f"expected one of {KV_CACHE_DTYPES}")


# --------------------------------------------------------------------------
# The cache carried through the layer loops
# --------------------------------------------------------------------------


class KVCache(NamedTuple):
    """K/V pools plus (optionally) their scale pools, as one pytree.

    ``k``/``v``: [L, NB, BS, KV, D] — bf16/f32/fp8 in the unquantized
    mode, int8 in the quantized one.  ``k_scale``/``v_scale``: [L, NB, KV]
    f32 in int8 mode, ``None`` otherwise (None is an empty pytree, so the
    same scan/shard_map plumbing carries both modes).
    """
    k: jnp.ndarray
    v: jnp.ndarray
    k_scale: Optional[jnp.ndarray] = None
    v_scale: Optional[jnp.ndarray] = None

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    @property
    def block_size(self) -> int:
        return self.k.shape[2]

    def nbytes(self) -> int:
        return sum(int(a.size) * a.dtype.itemsize
                   for a in (self.k, self.v, self.k_scale, self.v_scale)
                   if a is not None)


def cache_from_state(state) -> KVCache:
    return KVCache(state["k_pool"], state["v_pool"],
                   state.get("k_scales"), state.get("v_scales"))


def cache_to_state(cache: KVCache) -> dict:
    st = {"k_pool": cache.k, "v_pool": cache.v}
    if cache.quantized:
        st["k_scales"] = cache.k_scale
        st["v_scales"] = cache.v_scale
    return st


def make_kv_pool_quant(num_layers: int, num_blocks: int, block_size: int,
                       num_kv_heads: int, head_dim: int
                       ) -> Tuple[jnp.ndarray, jnp.ndarray,
                                  jnp.ndarray, jnp.ndarray]:
    """(k_values, v_values [L,NB,BS,KV,D] int8, k_scales, v_scales
    [L,NB,KV] f32)."""
    vshape = (num_layers, num_blocks, block_size, num_kv_heads, head_dim)
    sshape = (num_layers, num_blocks, num_kv_heads)
    return (jnp.zeros(vshape, jnp.int8), jnp.zeros(vshape, jnp.int8),
            jnp.zeros(sshape, jnp.float32), jnp.zeros(sshape, jnp.float32))


# --------------------------------------------------------------------------
# Quantize / dequantize primitives
# --------------------------------------------------------------------------


def quantize_blocks(x: jnp.ndarray, live: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-block-per-head int8 quantization.

    x: [..., BS, KV, D] float; live: [..., BS] bool — slots outside the
    mask are zeroed *before* the amax so junk can never inflate the scale
    (and they quantize to exactly 0).  Returns (q int8 like x,
    scales [..., KV] f32) with ``scale = amax / 127`` so the roundtrip
    error of any live value is <= scale / 2.
    """
    xf = jnp.where(live[..., None, None], x.astype(jnp.float32), 0.0)
    amax = jnp.max(jnp.abs(xf), axis=(-3, -1))                 # [..., KV]
    scales = jnp.maximum(amax, AMAX_FLOOR) / INT8_MAX
    q = jnp.round(xf / scales[..., None, :, None])
    q = jnp.clip(q, -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scales


def dequantize_blocks(q: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """q: [..., BS, KV, D] int8, scales: [..., KV] -> f32 values."""
    return q.astype(jnp.float32) * scales[..., None, :, None]


# --------------------------------------------------------------------------
# Quantize-on-write pool ops (int8 counterparts of core.paged_cache)
# --------------------------------------------------------------------------


def write_prefill_kv_quant(values: jnp.ndarray, scales: jnp.ndarray,
                           layer, k: jnp.ndarray, block_table: jnp.ndarray,
                           ctx_lens: jnp.ndarray, pos_offset=0
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize a prompt (or prompt chunk) into the int8 pool.

    values: [L, NB, BS, KV, D] int8; scales: [L, NB, KV] f32;
    k: [B, S, KV, D] holding positions ``pos_offset + i``; only absolute
    positions < ctx_lens are live.  Each touched block is quantized whole:
    blocks starting at/after ``pos_offset`` are fresh (scale overwritten);
    the one boundary block a chunked prefill appends into merges the
    dequantized live prefix first (``lead == 0`` degenerates to the
    fresh write).

    ``pos_offset`` may be a Python int or a *traced* scalar: the serving
    chunk-prefill executable compiles once for a fixed ``[B, S]`` chunk
    shape and feeds the chunk's start position as a device scalar, so
    all block arithmetic (pad widths, table slices) uses dynamic-slice
    forms — which constant-fold when the offset is static.
    """
    B, S, KV, D = k.shape
    NB, bs = values.shape[1], values.shape[2]
    nb = -(-S // bs) + 1                       # static max touched blocks
    j0 = pos_offset // bs                      # first touched block (traced)
    lead = pos_offset - j0 * bs                # live prefix rows in block j0

    buf = jnp.zeros((B, nb * bs, KV, D), jnp.float32)
    buf = jax.lax.dynamic_update_slice(buf, k.astype(jnp.float32),
                                       (0, lead, 0, 0))
    buf = buf.reshape(B, nb, bs, KV, D)
    pos = (j0 * bs + jnp.arange(nb * bs)).reshape(nb, bs)
    live = ((pos[None] >= pos_offset)
            & (pos[None] < ctx_lens[:, None, None]))           # [B, nb, bs]

    lp = values[layer]                                         # [NB,BS,KV,D]
    ls = scales[layer]                                         # [NB,KV]
    # pad the table with the OOB sentinel so the dynamic slice never
    # clamps (a clamped start would misalign every block of the chunk);
    # sentinel rows are dead (live is False past the capacity) anyway.
    btp = jnp.concatenate(
        [block_table, jnp.full((B, nb), NB, block_table.dtype)], axis=1)
    blk = jax.lax.dynamic_slice_in_dim(btp, j0, nb, axis=1)    # [B, nb]
    # chunk boundary: block j0 may already hold this sequence's tokens at
    # slots [0, lead) — dequantize and merge them before requantizing.
    safe0 = jnp.minimum(blk[:, 0], NB - 1)
    old = dequantize_blocks(lp[safe0], ls[safe0])              # [B,bs,KV,D]
    old_live = ((jnp.arange(bs)[None] < lead)
                & (pos[0][None] < ctx_lens[:, None]))          # [B, bs]
    buf = buf.at[:, 0].add(jnp.where(old_live[..., None, None], old, 0.0))
    live = live.at[:, 0].set(live[:, 0] | old_live)

    q, sc = quantize_blocks(buf, live)
    tgt = jnp.where(live.any(-1), blk, NB)                     # [B, nb]
    lp = lp.at[tgt].set(q, mode="drop")
    ls = ls.at[tgt].set(sc, mode="drop")
    return values.at[layer].set(lp), scales.at[layer].set(ls)


def write_decode_kv_quant(values: jnp.ndarray, scales: jnp.ndarray,
                          layer, k_new: jnp.ndarray,
                          block_table: jnp.ndarray, positions: jnp.ndarray
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Append one token per sequence to its (private, CoW-guaranteed) tail
    block: dequantize the live prefix, insert the token, requantize the
    block with the recomputed amax.  positions: [B] absolute position of
    the new token; negative => inactive slot, write dropped.
    """
    NB, bs = values.shape[1], values.shape[2]
    valid = positions >= 0
    pos = jnp.maximum(positions, 0)
    blk = jnp.take_along_axis(block_table, (pos // bs)[:, None],
                              axis=1)[:, 0]                    # [B]
    off = pos % bs                                             # [B]

    lp = values[layer]
    ls = scales[layer]
    old = dequantize_blocks(lp[blk], ls[blk])                  # [B,bs,KV,D]
    slot = jnp.arange(bs)[None, :]                             # [1, bs]
    buf = jnp.where((slot < off[:, None])[..., None, None], old, 0.0)
    buf = jnp.where((slot == off[:, None])[..., None, None],
                    k_new[:, None].astype(jnp.float32), buf)
    live = slot <= off[:, None]                                # [B, bs]
    q, sc = quantize_blocks(buf, live)

    tgt = jnp.where(valid, blk, NB)                            # OOB -> dropped
    lp = lp.at[tgt].set(q, mode="drop")
    ls = ls.at[tgt].set(sc, mode="drop")
    return values.at[layer].set(lp), scales.at[layer].set(ls)


def gather_kv_quant(values: jnp.ndarray, scales: jnp.ndarray, layer,
                    block_table: jnp.ndarray, max_len: int,
                    dtype=jnp.float32) -> jnp.ndarray:
    """Dequantizing counterpart of ``gather_kv`` (reference / chunked
    prefill path): [B, max_len, KV, D] in ``dtype``."""
    bs = values.shape[2]
    nb = -(-max_len // bs)
    blk = block_table[:, :nb]                                  # [B, nb]
    x = dequantize_blocks(values[layer][blk], scales[layer][blk])
    return x.reshape(blk.shape[0], nb * bs,
                     *values.shape[3:])[:, :max_len].astype(dtype)


def gather_kv_quant_bounded(values: jnp.ndarray, scales: jnp.ndarray, layer,
                            block_table: jnp.ndarray, max_len: int,
                            num_live_blocks, dtype=jnp.float32
                            ) -> jnp.ndarray:
    """``gather_kv_quant`` bounded by a *traced* live-page count: only the
    first ``num_live_blocks`` table entries are read and dequantized (one
    page per ``fori_loop`` iteration), the rest of the static
    ``[B, max_len, KV, D]`` view stays zero — O(live) dequant work
    instead of O(capacity) per layer per chunk."""
    bs = values.shape[2]
    nb = -(-max_len // bs)
    B = block_table.shape[0]
    buf = jnp.zeros((B, nb, bs) + values.shape[3:], dtype)

    def body(j, buf):
        blk = block_table[:, j]                            # [B]
        page = dequantize_blocks(values[layer, blk],
                                 scales[layer, blk]).astype(dtype)
        return jax.lax.dynamic_update_slice_in_dim(buf, page[:, None], j,
                                                   axis=1)

    buf = jax.lax.fori_loop(
        0, jnp.minimum(jnp.asarray(num_live_blocks, jnp.int32), nb),
        body, buf)
    return buf.reshape(B, nb * bs, *values.shape[3:])[:, :max_len]


def copy_blocks_quant(values: jnp.ndarray, scales: jnp.ndarray,
                      src: jnp.ndarray, dst: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Copy-on-write for the quantized pool: the scale rows move with the
    value blocks (a fork that dropped them would dequantize its shared
    prefix with garbage)."""
    return copy_blocks(values, src, dst), copy_blocks(scales, src, dst)


# --------------------------------------------------------------------------
# Mode-dispatching writes/reads over a KVCache (what the model layers call)
# --------------------------------------------------------------------------


def kv_write_prefill(cache: KVCache, layer, k, v, block_table, ctx_lens,
                     pos_offset: int = 0) -> KVCache:
    if cache.quantized:
        kq, ks = write_prefill_kv_quant(cache.k, cache.k_scale, layer, k,
                                        block_table, ctx_lens, pos_offset)
        vq, vs = write_prefill_kv_quant(cache.v, cache.v_scale, layer, v,
                                        block_table, ctx_lens, pos_offset)
        return KVCache(kq, vq, ks, vs)
    return cache._replace(
        k=write_prefill_kv(cache.k, layer, k, block_table, ctx_lens,
                           pos_offset=pos_offset),
        v=write_prefill_kv(cache.v, layer, v, block_table, ctx_lens,
                           pos_offset=pos_offset))


def kv_write_decode(cache: KVCache, layer, k, v, block_table,
                    positions) -> KVCache:
    if cache.quantized:
        kq, ks = write_decode_kv_quant(cache.k, cache.k_scale, layer, k,
                                       block_table, positions)
        vq, vs = write_decode_kv_quant(cache.v, cache.v_scale, layer, v,
                                       block_table, positions)
        return KVCache(kq, vq, ks, vs)
    return cache._replace(
        k=write_decode_kv(cache.k, layer, k, block_table, positions),
        v=write_decode_kv(cache.v, layer, v, block_table, positions))


def kv_gather_bounded(cache: KVCache, layer, block_table, max_len: int,
                      num_live_blocks, dtype
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """``kv_gather`` whose page walk stops at ``num_live_blocks`` (traced):
    the serving chunk path's O(total_len) gather — see
    ``gather_kv_bounded``; positions past the live pages are zeros, which
    downstream causal masking makes indistinguishable from the
    full-capacity gather."""
    if cache.quantized:
        return (gather_kv_quant_bounded(cache.k, cache.k_scale, layer,
                                        block_table, max_len,
                                        num_live_blocks, dtype),
                gather_kv_quant_bounded(cache.v, cache.v_scale, layer,
                                        block_table, max_len,
                                        num_live_blocks, dtype))
    return (gather_kv_bounded(cache.k, layer, block_table, max_len,
                              num_live_blocks).astype(dtype),
            gather_kv_bounded(cache.v, layer, block_table, max_len,
                              num_live_blocks).astype(dtype))


def kv_gather(cache: KVCache, layer, block_table, max_len: int,
              dtype) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if cache.quantized:
        return (gather_kv_quant(cache.k, cache.k_scale, layer, block_table,
                                max_len, dtype),
                gather_kv_quant(cache.v, cache.v_scale, layer, block_table,
                                max_len, dtype))
    return (gather_kv(cache.k, layer, block_table, max_len).astype(dtype),
            gather_kv(cache.v, layer, block_table, max_len).astype(dtype))
