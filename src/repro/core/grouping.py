"""Dynamic grouping (paper §II.B 'Dynamic Grouping Optimization').

Assigns query heads to KV groups by *activation similarity*: cosine
similarity between per-head activation statistics, maximizing intra-group
similarity / minimizing inter-group similarity. Used to convert an MHA
checkpoint (kv == H, e.g. qwen1.5-0.5b, hubert) into an Opt-GQA model:

  1. run calibration batches, collect per-head key activations,
  2. cluster heads into ``num_groups`` by cosine similarity (greedy
     agglomerative — deterministic, dependency-free),
  3. permute Q heads so each group is contiguous (groups must be contiguous
     for the kernels' reshape-based sharing),
  4. merge each group's K/V projections (mean, optionally weighted by head
     norm — the 'weighted GQA' variant the paper cites).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np


def head_similarity(acts: jnp.ndarray) -> np.ndarray:
    """Cosine-similarity matrix between heads.

    acts: [H, N, D] per-head activations over N calibration tokens.
    Uses the mean activation direction per head (paper: cosine similarity of
    query heads / norm similarity of output activations).
    """
    m = np.asarray(jnp.mean(acts, axis=1), dtype=np.float64)        # [H, D]
    n = np.linalg.norm(m, axis=1, keepdims=True)
    m = m / np.maximum(n, 1e-12)
    return m @ m.T


def cluster_heads(sim: np.ndarray, num_groups: int,
                  group_size: int | None = None) -> List[List[int]]:
    """Greedy agglomerative clustering into equal-size groups.

    Equal group size is required so that the grouped reshape
    [H] -> [KV, q_per_kv] stays rectangular (kernel constraint).
    """
    H = sim.shape[0]
    gs = group_size or H // num_groups
    assert num_groups * gs == H, (H, num_groups, gs)
    unassigned = set(range(H))
    groups: List[List[int]] = []
    for _ in range(num_groups):
        # seed: the unassigned head least similar to already-grouped heads
        # (spreads groups apart -> minimizes inter-group similarity).
        if groups:
            placed = [h for g in groups for h in g]
            seed = min(unassigned, key=lambda h: sim[h, placed].max())
        else:
            seed = min(unassigned)
        g = [seed]
        unassigned.discard(seed)
        while len(g) < gs:
            # grow by max average similarity to the group (intra-group max).
            nxt = max(unassigned, key=lambda h: sim[h, g].mean())
            g.append(nxt)
            unassigned.discard(nxt)
        groups.append(sorted(g))
    return groups


def grouping_quality(sim: np.ndarray, groups: List[List[int]]) -> Tuple[float, float]:
    """(intra-group mean similarity, inter-group mean similarity)."""
    H = sim.shape[0]
    intra, inter, ni, no = 0.0, 0.0, 0, 0
    gid = np.empty(H, dtype=int)
    for i, g in enumerate(groups):
        for h in g:
            gid[h] = i
    for a in range(H):
        for b in range(a + 1, H):
            if gid[a] == gid[b]:
                intra += sim[a, b]; ni += 1
            else:
                inter += sim[a, b]; no += 1
    return intra / max(ni, 1), inter / max(no, 1)


@dataclass
class GQAConversion:
    """Result of converting MHA weights to Opt-GQA."""
    q_perm: np.ndarray            # [H] permutation applied to query heads
    groups: List[List[int]]       # head ids per group (pre-permutation)
    wk: jnp.ndarray               # merged [d_model, KV, D]
    wv: jnp.ndarray
    intra_sim: float
    inter_sim: float


def convert_mha_to_gqa(
    wq: jnp.ndarray,              # [d_model, H, D]
    wk: jnp.ndarray,              # [d_model, H, D]
    wv: jnp.ndarray,              # [d_model, H, D]
    key_acts: jnp.ndarray,        # [H, N, D] calibration key activations
    num_kv_heads: int,
    weighted: bool = True,
) -> GQAConversion:
    """MHA -> Opt-GQA: cluster by activation similarity, merge K/V per group.

    ``weighted=True`` uses per-head activation norms as merge weights (the
    'weighted GQA' variant [11]); False is plain mean-pooling.
    """
    H = wq.shape[1]
    sim = head_similarity(key_acts)
    groups = cluster_heads(sim, num_kv_heads)
    intra, inter = grouping_quality(sim, groups)

    if weighted:
        w = np.asarray(jnp.linalg.norm(
            key_acts.reshape(H, -1).astype(jnp.float32), axis=1))
    else:
        w = np.ones(H)

    merged_k, merged_v, perm = [], [], []
    for g in groups:
        gw = jnp.asarray(w[g] / w[g].sum(), dtype=wk.dtype)
        merged_k.append(jnp.einsum("h,dhx->dx", gw, wk[:, g]))
        merged_v.append(jnp.einsum("h,dhx->dx", gw, wv[:, g]))
        perm.extend(g)
    return GQAConversion(
        q_perm=np.asarray(perm),
        groups=groups,
        wk=jnp.stack(merged_k, axis=1),
        wv=jnp.stack(merged_v, axis=1),
        intra_sim=float(intra),
        inter_sim=float(inter),
    )
