"""Opt-GQA attention math (paper §II).

H query heads are partitioned into ``num_kv_heads`` groups of
``q_per_kv = H // num_kv_heads`` heads; each group shares one K/V head.
The TPU-native form of the paper's "shared key-value" insight: Q is reshaped
to [B, kv, q_per_kv, S, D] so each K/V head is contracted against *all* of
its group's queries in one batched matmul — the K/V tile is loaded once and
reused q_per_kv times, multiplying arithmetic intensity by the group size.

This module is the XLA reference path; the Pallas kernels in
repro/kernels implement the same contraction with explicit VMEM tiling.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.alibi import alibi_bias

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def grouped_attention(
    q: jnp.ndarray,                     # [B, S_q, H, D]
    k: jnp.ndarray,                     # [B, S_k, KV, D]
    v: jnp.ndarray,                     # [B, S_k, KV, D]
    *,
    causal: bool = True,
    sliding_window: int = 0,
    alibi_slopes: Optional[jnp.ndarray] = None,   # [H] or None
    q_offset: int | jnp.ndarray = 0,    # absolute position of q[:, 0]
    logits_soft_cap: float = 0.0,
) -> jnp.ndarray:
    """Opt-GQA attention, O(S^2) reference. Returns [B, S_q, H, D]."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    assert H % KV == 0, (H, KV)
    G = H // KV                          # group size = q_per_kv
    scale = D ** -0.5

    qg = q.reshape(B, Sq, KV, G, D)
    # scores [B, KV, G, Sq, Sk] — one contraction per shared K/V head.
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if logits_soft_cap > 0:
        scores = logits_soft_cap * jnp.tanh(scores / logits_soft_cap)

    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(k.shape[1])
    if alibi_slopes is not None:
        bias = alibi_bias(alibi_slopes, q_pos, k_pos, causal=causal)   # [H,Sq,Sk]
        scores = scores + bias.reshape(KV, G, Sq, k.shape[1])[None]
    dist = q_pos[:, None] - k_pos[None, :]
    mask = jnp.ones_like(dist, dtype=bool)
    if causal:
        mask &= dist >= 0
    if sliding_window > 0:
        mask &= dist < sliding_window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)

    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,                     # [B, H, D] one new token per sequence
    k_cache: jnp.ndarray,               # [B, S_max, KV, D]
    v_cache: jnp.ndarray,               # [B, S_max, KV, D]
    seq_lens: jnp.ndarray,              # [B] valid lengths (inclusive of new tok)
    *,
    alibi_slopes: Optional[jnp.ndarray] = None,
    sliding_window: int = 0,
) -> jnp.ndarray:
    """Single-token decode against a (contiguous) cache. Returns [B, H, D]."""
    B, S, KV, D = k_cache.shape
    H = q.shape[1]
    G = H // KV
    scale = D ** -0.5
    qg = q.reshape(B, KV, G, D)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    k_pos = jnp.arange(S)
    q_pos = seq_lens[:, None] - 1                                  # [B,1]
    if alibi_slopes is not None:
        dist = jnp.maximum(q_pos - k_pos[None, :], 0)              # [B,S]
        bias = -alibi_slopes[None, :, None] * dist[:, None, :]     # [B,H,S]
        scores = scores + bias.reshape(B, KV, G, S)
    mask = k_pos[None, :] < seq_lens[:, None]                      # [B,S]
    if sliding_window > 0:
        mask &= k_pos[None, :] > (q_pos - sliding_window)
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v_cache.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)


def grouped_attention_chunked(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
    causal: bool = True, sliding_window: int = 0,
    alibi_slopes: Optional[jnp.ndarray] = None,
    q_offset: int | jnp.ndarray = 0,
    block_q: int = 512,
) -> jnp.ndarray:
    """Flash-structured XLA attention: q-block streaming, per-block remat.

    Same semantics as ``grouped_attention`` but scores never materialize at
    [S, S]; each q-block's [B, H, block_q, S_k] tile lives only inside a
    jax.checkpoint region (recomputed in backward). This is the lowering
    used by the dry-run, where the Pallas kernel cannot compile for the CPU
    backend but the memory/collective profile must stay kernel-like.
    """
    B, Sq, H, D = q.shape
    if Sq <= block_q:
        return grouped_attention(q, k, v, causal=causal,
                                 sliding_window=sliding_window,
                                 alibi_slopes=alibi_slopes, q_offset=q_offset)
    assert isinstance(q_offset, int), "chunked path needs a static offset"
    Sk = k.shape[1]
    outs = []
    for i in range(0, Sq, block_q):
        bq = min(block_q, Sq - i)
        off = q_offset + i
        # static K truncation: causal upper bound and window lower bound —
        # the XLA analogue of the Pallas kernel's masked-tile skipping.
        k_hi = min(Sk, off + bq) if causal else Sk
        k_lo = max(0, off + 1 - sliding_window) if sliding_window else 0
        k_lo = (k_lo // 128) * 128                # keep tiles aligned
        blk = jax.checkpoint(
            lambda qi, ks, vs, off=off, k_lo=k_lo: grouped_attention(
                qi, ks, vs, causal=causal, sliding_window=sliding_window,
                alibi_slopes=alibi_slopes, q_offset=off - k_lo))
        outs.append(blk(q[:, i:i + bq], k[:, k_lo:k_hi], v[:, k_lo:k_hi]))
    return jnp.concatenate(outs, axis=1)


def mha_attention(q, k, v, **kw):
    """Traditional MHA baseline (the paper's comparison point): KV == H."""
    assert q.shape[2] == k.shape[2], "MHA requires num_kv_heads == num_heads"
    return grouped_attention(q, k, v, **kw)
