"""GPTQ post-training quantization (the 'GPTQ' in Opt-GPTQ).

Hessian-based OBQ, exactly the GPTQ recipe: accumulate H = 2 Σ x xᵀ over
calibration activations, damp, Cholesky-invert, then quantize weight
columns one at a time with error feedback into the not-yet-quantized
columns, lazily batched in blocks of ``block_size`` columns.

This runs OFFLINE (host, numpy float64 for numerical stability) — the
online artifact is the packed int4 weights consumed by
``repro/kernels/gptq_matmul`` / ``repro/core/quant``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.configs.base import QuantConfig


@dataclass
class QuantizedTensor:
    """Group-wise int4 quantization artifact for one [in, out] weight."""
    q: np.ndarray          # [in, out] uint8 codes in [0, 2^bits)
    scales: np.ndarray     # [n_groups, out] float32
    zeros: np.ndarray      # [n_groups, out] float32 (zero-point in code space)
    g_idx: np.ndarray      # [in] int32 group id per input feature
    bits: int

    def dequant(self) -> np.ndarray:
        return ((self.q.astype(np.float32) - self.zeros[self.g_idx])
                * self.scales[self.g_idx])


class HessianAccumulator:
    """Streaming H = 2/N Σ xᵀx over calibration batches for one layer input."""

    def __init__(self, in_features: int):
        self.h = np.zeros((in_features, in_features), dtype=np.float64)
        self.n = 0

    def update(self, x: np.ndarray) -> None:
        """x: [..., in_features] activations feeding this weight."""
        x2 = np.asarray(x, dtype=np.float64).reshape(-1, self.h.shape[0])
        # running mean keeps H scale-stable across batch counts
        m = x2.shape[0]
        self.h *= self.n / max(self.n + m, 1)
        self.h += (2.0 / max(self.n + m, 1)) * (x2.T @ x2)
        self.n += m


def _group_params(w_col_block: np.ndarray, bits: int, sym: bool
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-output-channel (scale, zero) for one group of input features.

    w_col_block: [g, out]. Returns scale, zero each [out]."""
    maxq = 2 ** bits - 1
    wmax = w_col_block.max(axis=0)
    wmin = w_col_block.min(axis=0)
    if sym:
        mag = np.maximum(np.abs(wmax), np.abs(wmin))
        scale = np.where(mag > 0, 2 * mag / maxq, 1.0)
        zero = np.full_like(scale, (maxq + 1) / 2)
    else:
        wmax = np.maximum(wmax, 0)
        wmin = np.minimum(wmin, 0)
        rng = wmax - wmin
        scale = np.where(rng > 0, rng / maxq, 1.0)
        zero = np.round(-wmin / scale)
    return scale.astype(np.float32), zero.astype(np.float32)


def _quant_col(col: np.ndarray, scale: np.ndarray, zero: np.ndarray,
               maxq: int) -> Tuple[np.ndarray, np.ndarray]:
    q = np.clip(np.round(col / scale + zero), 0, maxq)
    return q, (q - zero) * scale


def gptq_quantize(w: np.ndarray, hessian: Optional[np.ndarray],
                  cfg: QuantConfig) -> QuantizedTensor:
    """Quantize one weight matrix ``w [in, out]`` given its input Hessian.

    hessian=None falls back to RTN (identity Hessian) — used as the
    baseline the paper's GPTQ improves on.
    """
    w = np.asarray(w, dtype=np.float64).copy()
    din, dout = w.shape
    maxq = 2 ** cfg.bits - 1
    gs = min(cfg.group_size, din)
    n_groups = (din + gs - 1) // gs

    h = (np.eye(din) if hessian is None else np.asarray(hessian, np.float64).copy())
    # dead inputs: no signal -> pin weight to 0, unit curvature
    dead = np.diag(h) == 0
    h[dead, dead] = 1.0
    w[dead, :] = 0.0

    perm = (np.argsort(-np.diag(h)) if cfg.act_order else np.arange(din))
    inv_perm = np.argsort(perm)
    w = w[perm]
    h = h[perm][:, perm]

    damp = cfg.damp_frac * np.mean(np.diag(h))
    h[np.diag_indices(din)] += damp
    # Upper Cholesky of H^-1 — the GPTQ trick: error propagation only needs
    # rows of chol(H^-1, upper).
    hinv = np.linalg.inv(h)
    hinv = np.linalg.cholesky((hinv + hinv.T) / 2).T   # upper-triangular

    # group params on the *original* column order so g_idx stays contiguous
    scales = np.empty((n_groups, dout), np.float32)
    zeros = np.empty((n_groups, dout), np.float32)
    g_idx_orig = (np.arange(din) // gs).astype(np.int32)
    w_orig = w[inv_perm]
    for g in range(n_groups):
        sel = g_idx_orig == g
        scales[g], zeros[g] = _group_params(w_orig[sel], cfg.bits, cfg.sym)

    q_codes = np.zeros((din, dout), np.uint8)
    bs = cfg.block_size
    for i0 in range(0, din, bs):
        i1 = min(i0 + bs, din)
        wb = w[i0:i1].copy()
        eb = np.zeros_like(wb)
        hb = hinv[i0:i1, i0:i1]
        for j in range(i1 - i0):
            col = wb[j]
            g = g_idx_orig[perm[i0 + j]]
            qc, dq = _quant_col(col, scales[g], zeros[g], maxq)
            q_codes[perm[i0 + j]] = qc.astype(np.uint8)
            err = (col - dq) / hb[j, j]
            if j + 1 < i1 - i0:                        # in-block error feedback
                wb[j + 1:] -= np.outer(hb[j, j + 1:], err)
            eb[j] = err
        if i1 < din:                                    # lazy batched update
            w[i1:] -= hinv[i0:i1, i1:].T @ eb

    return QuantizedTensor(q=q_codes, scales=scales, zeros=zeros,
                           g_idx=g_idx_orig, bits=cfg.bits)


def rtn_quantize(w: np.ndarray, cfg: QuantConfig) -> QuantizedTensor:
    """Round-to-nearest baseline (no Hessian, no error feedback)."""
    return gptq_quantize(w, None, cfg.__class__(**{**cfg.__dict__, "act_order": False}))


def quant_error(w: np.ndarray, qt: QuantizedTensor,
                hessian: Optional[np.ndarray] = None) -> float:
    """Proxy loss: tr((W-Ŵ)ᵀ H (W-Ŵ)) / numel — the objective GPTQ minimizes."""
    d = np.asarray(w, np.float64) - qt.dequant().astype(np.float64)
    if hessian is None:
        return float((d * d).mean())
    return float(np.einsum("io,ij,jo->", d, np.asarray(hessian, np.float64), d)
                 / d.size)
