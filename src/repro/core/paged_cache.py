"""Paged KV-cache (paper §III.A 'Management of Shared Key-Value Vectors').

Two halves, mirroring vLLM on TPU:

* **Host side** — ``BlockAllocator``: pre-allocated fixed pool of block ids,
  free-list allocation, ref-counted blocks, prefix-hash reuse
  (copy-on-write), watermark admission. Pure Python, drives the scheduler.

* **Device side** — the pool itself is ONE dense array per layer
  ``[num_blocks, block_size, kv_heads, head_dim]`` (pre-allocated: the
  paper's "pre-allocate memory pools to minimize allocation overhead"),
  plus an int32 ``block_table [max_seqs, max_blocks_per_seq]``. Jitted
  scatter/gather ops below; the Pallas decode kernel consumes the pool +
  table directly.
"""
from __future__ import annotations

import functools
import hashlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# Host-side allocator
# --------------------------------------------------------------------------


class OutOfBlocksError(RuntimeError):
    pass


@dataclass
class _Block:
    ref: int = 0
    token_hash: Optional[bytes] = None   # set only for full, immutable blocks


class BlockAllocator:
    """Ref-counted fixed-pool allocator with prefix reuse.

    Prefix reuse: a *full* block of a prompt is content-addressed by the
    hash of (all tokens up to and including the block). A new request whose
    prompt shares that prefix gets the same physical block with ref+1 —
    the paper's "cache reuse strategy based on request features".
    """

    def __init__(self, num_blocks: int, block_size: int,
                 enable_prefix_reuse: bool = True,
                 watermark_frac: float = 0.01):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.enable_prefix_reuse = enable_prefix_reuse
        self.watermark = max(1, int(num_blocks * watermark_frac))
        self._blocks = [_Block() for _ in range(num_blocks)]
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._hash_to_block: Dict[bytes, int] = {}
        self.stats = {"allocated": 0, "reused": 0, "freed": 0, "cow": 0}

    # -- basics ---------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    def can_allocate(self, n: int) -> bool:
        return self.num_free - n >= self.watermark

    def _alloc_raw(self) -> int:
        if not self._free:
            raise OutOfBlocksError("KV block pool exhausted")
        b = self._free.pop()
        self._blocks[b].ref = 1
        self._blocks[b].token_hash = None
        self.stats["allocated"] += 1
        return b

    def free(self, block_id: int) -> None:
        blk = self._blocks[block_id]
        assert blk.ref > 0, f"double free of block {block_id}"
        blk.ref -= 1
        if blk.ref == 0:
            if blk.token_hash is not None:
                self._hash_to_block.pop(blk.token_hash, None)
                blk.token_hash = None
            self._free.append(block_id)
            self.stats["freed"] += 1

    def free_sequence(self, block_ids: Sequence[int]) -> None:
        for b in block_ids:
            self.free(b)

    def fork_sequence(self, block_ids: Sequence[int]) -> List[int]:
        """Share a sequence's blocks with a fork (parallel sampling / beam
        candidates): every block's refcount is bumped, including a partial
        tail — the first divergent append on either fork triggers
        copy-on-write (``grow`` returns the source block for the device
        block-copy)."""
        for b in block_ids:
            assert self._blocks[b].ref > 0, f"fork of freed block {b}"
            self._blocks[b].ref += 1
        return list(block_ids)

    # -- prefix-aware prompt allocation ----------------------------------
    @staticmethod
    def _hash_prefix(tokens: Sequence[int]) -> bytes:
        return hashlib.blake2b(np.asarray(tokens, np.int32).tobytes(),
                               digest_size=16).digest()

    def allocate_prompt(self, tokens: Sequence[int],
                        register: bool = True) -> Tuple[List[int], int]:
        """Allocate blocks for a prompt. Returns (block_ids, num_reused_blocks).

        Full blocks are content-addressed and may be shared; the trailing
        partial block is always private.

        ``register=False`` still *looks up* (and shares) existing hashed
        blocks but does not content-address fresh ones — for callers that
        cannot guarantee the hashed content will ever land in the pool.
        The serving scheduler registers eagerly: a reusing prompt always
        rewrites the shared block bit-identically rather than trusting
        its contents, and ``free`` drops a block's hash entry the moment
        its refcount hits 0, so aborted or failed dispatches cannot leave
        stale prefix-cache entries behind.
        """
        n = len(tokens)
        n_full = n // self.block_size
        ids: List[int] = []
        reused = 0
        for i in range(n_full):
            h = self._hash_prefix(tokens[: (i + 1) * self.block_size])
            if self.enable_prefix_reuse and h in self._hash_to_block:
                b = self._hash_to_block[h]
                self._blocks[b].ref += 1
                ids.append(b)
                reused += 1
                continue
            b = self._alloc_raw()
            if register:
                self._blocks[b].token_hash = h
                self._hash_to_block[h] = b
            ids.append(b)
        if n % self.block_size or n == 0:
            ids.append(self._alloc_raw())
        self.stats["reused"] += reused
        return ids, reused

    def register_full_block(self, block_id: int,
                            tokens: Sequence[int]) -> None:
        """Content-address a block *after* allocation (register-on-write).

        ``allocate_prompt`` hashes only the full blocks of the tokens it
        is given — for a chunked admission, just the first chunk.  Blocks
        grown for continuation chunks become hashable only once the chunk
        that fills them has executed; the scheduler calls this with the
        prompt prefix through the block's last token.  No-ops when prefix
        reuse is off, when the block is already content-addressed (it was
        itself a reused prefix block), or when another live block owns
        the hash (first writer wins; we cannot retroactively dedupe a
        block that is already scattered into the pool).
        """
        if not self.enable_prefix_reuse:
            return
        blk = self._blocks[block_id]
        assert blk.ref > 0, f"register_full_block of freed block {block_id}"
        if blk.token_hash is not None:
            return
        h = self._hash_prefix(tokens)
        if h in self._hash_to_block:
            return
        blk.token_hash = h
        self._hash_to_block[h] = block_id

    def ref(self, block_id: int) -> int:
        """Current refcount of a block (0 == free)."""
        return self._blocks[block_id].ref

    def audit(self) -> Dict[str, int]:
        """Leak/consistency snapshot for tests and ``engine.health()``.

        live_blocks + num_free must equal num_blocks; every hash entry
        must map to a live block that owns that hash (a dangling entry
        would serve stale prefix-cache hits).  Raises AssertionError on
        inconsistency instead of returning a lie.
        """
        live = sum(1 for b in self._blocks if b.ref > 0)
        assert live + self.num_free == self.num_blocks, \
            f"block accounting broken: {live} live + {self.num_free} " \
            f"free != {self.num_blocks}"
        for h, bid in self._hash_to_block.items():
            blk = self._blocks[bid]
            assert blk.ref > 0, f"hash entry -> freed block {bid}"
            assert blk.token_hash == h, \
                f"hash entry -> block {bid} owning a different hash"
        return {"live_blocks": live, "free_blocks": self.num_free,
                "hash_entries": len(self._hash_to_block)}

    def grow_prefill(self, block_ids: List[int], start_pos: int,
                     num_tokens: int, tokens: Sequence[int]
                     ) -> Tuple[List[int], int]:
        """``grow`` for a prefill chunk, with content-addressed reuse.

        Any *new* block the chunk will completely cover (the chunk writes
        all ``block_size`` of its slots) may instead share an existing
        block whose registered hash matches ``tokens`` up to that block's
        end — the continuation-chunk counterpart of ``allocate_prompt``'s
        prefix reuse.  Safe because the chunk then rewrites the shared
        block with bit-identical content (same tokens, same absolute
        positions, deterministic projections — and a fully-covered block
        is always a *fresh* quantize in int8 mode, never a boundary
        merge).  Partially-covered blocks (the chunk's tail) stay
        private raw allocations.  Prefill chunks never CoW: ``start_pos``
        is this sequence's own computed length, so the current tail is
        private.  Returns (block_ids, num_reused_blocks).
        """
        assert not self._tail_needs_cow(block_ids, start_pos)
        if self.blocks_needed(block_ids, start_pos, num_tokens) \
                > self.num_free:
            raise OutOfBlocksError("KV block pool exhausted")
        block_ids = list(block_ids)
        end = start_pos + num_tokens
        reused = 0
        while len(block_ids) * self.block_size < end:
            i = len(block_ids)                       # next block index
            blk_end = (i + 1) * self.block_size
            if self.enable_prefix_reuse and blk_end <= end:
                h = self._hash_prefix(tokens[:blk_end])
                b = self._hash_to_block.get(h)
                if b is not None:
                    self._blocks[b].ref += 1
                    block_ids.append(b)
                    reused += 1
                    continue
            block_ids.append(self._alloc_raw())
        self.stats["reused"] += reused
        return block_ids, reused

    def append_slot(self, block_ids: List[int], seq_len: int) -> Tuple[List[int], Optional[int]]:
        """Ensure capacity for one more token at position seq_len.

        Returns (block_ids, copied_from): if the tail block is shared
        (ref > 1) it is copy-on-write'd; copied_from is the old block id the
        device must copy data out of, else None.
        """
        block_ids, cow = self.grow(block_ids, seq_len, 1)
        return block_ids, (cow[0] if cow else None)

    def _tail_needs_cow(self, block_ids: Sequence[int],
                        start_pos: int) -> bool:
        """A write at start_pos lands in the current tail block and that
        tail is shared — the single predicate both ``blocks_needed`` and
        ``grow`` must agree on (the fused planner budgets with the former
        and relies on the latter not raising)."""
        return bool(start_pos % self.block_size and block_ids
                    and self._blocks[block_ids[-1]].ref > 1)

    def blocks_needed(self, block_ids: Sequence[int], start_pos: int,
                      num_tokens: int) -> int:
        """New blocks ``grow`` would consume for writes at positions
        [start_pos, start_pos + num_tokens), including a CoW replacement."""
        end = start_pos + num_tokens
        n = max(0, -(-end // self.block_size) - len(block_ids))
        if self._tail_needs_cow(block_ids, start_pos):
            n += 1                                   # CoW'd tail is a new block
        return n

    def grow(self, block_ids: List[int], start_pos: int,
             num_tokens: int = 1
             ) -> Tuple[List[int], Optional[Tuple[int, int]]]:
        """Ensure capacity for ``num_tokens`` writes starting at start_pos.

        Bulk form of ``append_slot`` for the fused decode horizon: allocates
        every block the horizon will touch in one host pass. Returns
        (block_ids, cow): cow is a (src_block, dst_block) pair the device
        must copy (shared tail copy-on-write), else None. Only the current
        tail can need CoW: blocks past it are freshly allocated and private.

        Atomic: capacity is checked up front, so a raise leaves both the
        allocator and the caller's block list untouched.
        """
        if self.blocks_needed(block_ids, start_pos, num_tokens) \
                > self.num_free:
            raise OutOfBlocksError("KV block pool exhausted")
        cow = None
        if self._tail_needs_cow(block_ids, start_pos):
            tail = block_ids[-1]                    # CoW: shared full-prefix tail
            nb = self._alloc_raw()
            self.free(tail)
            block_ids = block_ids[:-1] + [nb]
            cow = (tail, nb)
            self.stats["cow"] += 1
        else:
            block_ids = list(block_ids)
        end = start_pos + num_tokens
        while len(block_ids) * self.block_size < end:
            block_ids.append(self._alloc_raw())
        return block_ids, cow

    def utilization(self) -> float:
        return 1.0 - self.num_free / self.num_blocks


# --------------------------------------------------------------------------
# Device-side pool ops (jit-friendly, used by serve_step and the ref path)
# --------------------------------------------------------------------------


def make_kv_pool(num_layers: int, num_blocks: int, block_size: int,
                 num_kv_heads: int, head_dim: int, dtype=jnp.bfloat16):
    """Pre-allocated pool: (k_pool, v_pool) each [L, num_blocks, bs, KV, D]."""
    shape = (num_layers, num_blocks, block_size, num_kv_heads, head_dim)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def write_decode_kv(pool: jnp.ndarray, layer: int, k_new: jnp.ndarray,
                    block_table: jnp.ndarray, positions: jnp.ndarray) -> jnp.ndarray:
    """Scatter one token's K (or V) per sequence into the paged pool.

    pool: [L, NB, BS, KV, D]; k_new: [B, KV, D]; block_table: [B, MB];
    positions: [B] absolute position of the new token. Negative positions
    (inactive decode slots, seq_len == 0) are dropped instead of wrapping
    around and corrupting a live block.
    """
    bs = pool.shape[2]
    valid = positions >= 0
    pos = jnp.maximum(positions, 0)
    blk = jnp.take_along_axis(block_table, (pos // bs)[:, None], axis=1)[:, 0]
    blk = jnp.where(valid, blk, pool.shape[1])                 # OOB -> dropped
    off = pos % bs
    return pool.at[layer, blk, off].set(k_new.astype(pool.dtype),
                                        mode="drop")


def write_prefill_kv(pool: jnp.ndarray, layer: int, k: jnp.ndarray,
                     block_table: jnp.ndarray, ctx_lens: jnp.ndarray,
                     pos_offset: int = 0) -> jnp.ndarray:
    """Scatter a prompt (or prompt chunk) K/V into the pool.

    k: [B, S, KV, D] (padded); k[:, i] holds position pos_offset + i; only
    absolute positions < ctx_lens are written.
    """
    B, S = k.shape[:2]
    bs = pool.shape[2]
    pos = pos_offset + jnp.arange(S)
    blk = block_table[:, pos // bs]                       # [B, S]
    off = pos % bs                                         # [S]
    valid = pos[None, :] < ctx_lens[:, None]               # [B, S]
    # route invalid tokens to a scratch (last) block offset that is then
    # overwritten by valid data — use mode='drop' semantics via clipping +
    # where on the payload.
    blk = jnp.where(valid, blk, pool.shape[1] - 1)
    k = jnp.where(valid[..., None, None], k, 0).astype(pool.dtype)
    flat_idx = (blk * bs + off[None, :]).reshape(-1)
    upd = k.reshape(B * S, *k.shape[2:])
    L, NB, BS = pool.shape[:3]
    lp = pool[layer].reshape(NB * BS, *pool.shape[3:])
    # guard scratch writes: drop invalid rows entirely
    flat_idx = jnp.where(valid.reshape(-1), flat_idx, NB * BS)   # OOB -> dropped
    lp = lp.at[flat_idx].set(upd, mode="drop")
    return pool.at[layer].set(lp.reshape(NB, BS, *pool.shape[3:]))


def gather_kv_bounded(pool: jnp.ndarray, layer, block_table: jnp.ndarray,
                      max_len: int, num_live_blocks) -> jnp.ndarray:
    """``gather_kv`` that only touches the first ``num_live_blocks``
    (a *traced* count) table entries: the returned ``[B, max_len, ...]``
    view has zeros past the live pages instead of stale pool contents.

    The output shape stays static (``max_len``) — what becomes bounded is
    the *work*: a ``fori_loop`` with a dynamic trip count copies one page
    per live table entry, so a chunk-prefill gather costs
    O(ceil(total_len / BS)) page reads instead of O(table capacity) per
    layer per chunk.  Downstream attention masks every position past the
    live length to -inf before the softmax max, so zeros vs stale data is
    invisible in the output — the full-capacity gather path and this one
    are bitwise interchangeable.
    """
    bs = pool.shape[2]
    nb = -(-max_len // bs)
    B = block_table.shape[0]
    buf = jnp.zeros((B, nb, bs) + pool.shape[3:], pool.dtype)

    def body(j, buf):
        page = pool[layer, block_table[:, j]]          # [B, bs, ...]
        return jax.lax.dynamic_update_slice_in_dim(buf, page[:, None], j,
                                                   axis=1)

    buf = jax.lax.fori_loop(
        0, jnp.minimum(jnp.asarray(num_live_blocks, jnp.int32), nb),
        body, buf)
    return buf.reshape(B, nb * bs, *pool.shape[3:])[:, :max_len]


def gather_kv(pool: jnp.ndarray, layer: int, block_table: jnp.ndarray,
              max_len: int) -> jnp.ndarray:
    """Gather a contiguous [B, max_len, KV, D] view (reference path only).

    ``max_len`` need not be a block multiple: the tail partial block is
    gathered too and the result sliced back to exactly max_len rows.
    """
    bs = pool.shape[2]
    nb = -(-max_len // bs)                                 # ceil: keep the tail
    blk = block_table[:, :nb]                              # [B, nb]
    g = pool[layer][blk]                                   # [B, nb, bs, KV, D]
    return g.reshape(blk.shape[0], nb * bs, *pool.shape[3:])[:, :max_len]


@functools.partial(jax.jit, donate_argnums=(0,))
def copy_blocks(pool: jnp.ndarray, src: jnp.ndarray,
                dst: jnp.ndarray) -> jnp.ndarray:
    """Device-side block copy for the allocator's copy-on-write path.

    pool: [L, NB, BS, KV, D]; src/dst: [n] int32 physical block ids. Copies
    pool[:, src[i]] -> pool[:, dst[i]] for every layer without the contents
    ever round-tripping through host numpy. Donated: updates in place.
    """
    return pool.at[:, dst].set(pool[:, src])


# --------------------------------------------------------------------------
# Attention-free (SSM) state pool — paper's memory-pool insight, degenerate
# block table (see DESIGN.md §5): one slot per sequence, O(1) state.
# --------------------------------------------------------------------------


def make_state_pool(num_layers: int, max_seqs: int, d_inner: int,
                    ssm_state: int, conv_width: int, dtype=jnp.float32):
    """(ssm_state_pool [L, B, d_inner, N], conv_state_pool [L, B, d_inner, W-1])."""
    return (jnp.zeros((num_layers, max_seqs, d_inner, ssm_state), dtype),
            jnp.zeros((num_layers, max_seqs, d_inner, conv_width - 1), dtype))
