"""Paged KV-cache (paper §III.A 'Management of Shared Key-Value Vectors').

Two halves, mirroring vLLM on TPU:

* **Host side** — ``BlockAllocator``: pre-allocated fixed pool of block ids,
  free-list allocation, ref-counted blocks, prefix-hash reuse
  (copy-on-write), watermark admission. Pure Python, drives the scheduler.

* **Device side** — the pool itself is ONE dense array per layer
  ``[num_blocks, block_size, kv_heads, head_dim]`` (pre-allocated: the
  paper's "pre-allocate memory pools to minimize allocation overhead"),
  plus an int32 ``block_table [max_seqs, max_blocks_per_seq]``. Jitted
  scatter/gather ops below; the Pallas decode kernel consumes the pool +
  table directly.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# Host-side allocator
# --------------------------------------------------------------------------


class OutOfBlocksError(RuntimeError):
    pass


@dataclass
class _Block:
    ref: int = 0
    token_hash: Optional[bytes] = None   # set only for full, immutable blocks


class BlockAllocator:
    """Ref-counted fixed-pool allocator with prefix reuse.

    Prefix reuse: a *full* block of a prompt is content-addressed by the
    hash of (all tokens up to and including the block). A new request whose
    prompt shares that prefix gets the same physical block with ref+1 —
    the paper's "cache reuse strategy based on request features".
    """

    def __init__(self, num_blocks: int, block_size: int,
                 enable_prefix_reuse: bool = True,
                 watermark_frac: float = 0.01):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.enable_prefix_reuse = enable_prefix_reuse
        self.watermark = max(1, int(num_blocks * watermark_frac))
        self._blocks = [_Block() for _ in range(num_blocks)]
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._hash_to_block: Dict[bytes, int] = {}
        self.stats = {"allocated": 0, "reused": 0, "freed": 0, "cow": 0}

    # -- basics ---------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    def can_allocate(self, n: int) -> bool:
        return self.num_free - n >= self.watermark

    def _alloc_raw(self) -> int:
        if not self._free:
            raise OutOfBlocksError("KV block pool exhausted")
        b = self._free.pop()
        self._blocks[b].ref = 1
        self._blocks[b].token_hash = None
        self.stats["allocated"] += 1
        return b

    def free(self, block_id: int) -> None:
        blk = self._blocks[block_id]
        assert blk.ref > 0, f"double free of block {block_id}"
        blk.ref -= 1
        if blk.ref == 0:
            if blk.token_hash is not None:
                self._hash_to_block.pop(blk.token_hash, None)
                blk.token_hash = None
            self._free.append(block_id)
            self.stats["freed"] += 1

    def free_sequence(self, block_ids: Sequence[int]) -> None:
        for b in block_ids:
            self.free(b)

    # -- prefix-aware prompt allocation ----------------------------------
    @staticmethod
    def _hash_prefix(tokens: Sequence[int]) -> bytes:
        return hashlib.blake2b(np.asarray(tokens, np.int32).tobytes(),
                               digest_size=16).digest()

    def allocate_prompt(self, tokens: Sequence[int]) -> Tuple[List[int], int]:
        """Allocate blocks for a prompt. Returns (block_ids, num_reused_blocks).

        Full blocks are content-addressed and may be shared; the trailing
        partial block is always private.
        """
        n = len(tokens)
        n_full = n // self.block_size
        ids: List[int] = []
        reused = 0
        for i in range(n_full):
            h = self._hash_prefix(tokens[: (i + 1) * self.block_size])
            if self.enable_prefix_reuse and h in self._hash_to_block:
                b = self._hash_to_block[h]
                self._blocks[b].ref += 1
                ids.append(b)
                reused += 1
                continue
            b = self._alloc_raw()
            self._blocks[b].token_hash = h
            self._hash_to_block[h] = b
            ids.append(b)
        if n % self.block_size or n == 0:
            ids.append(self._alloc_raw())
        self.stats["reused"] += reused
        return ids, reused

    def append_slot(self, block_ids: List[int], seq_len: int) -> Tuple[List[int], Optional[int]]:
        """Ensure capacity for one more token at position seq_len.

        Returns (block_ids, copied_from): if the tail block is shared
        (ref > 1) it is copy-on-write'd; copied_from is the old block id the
        device must copy data out of, else None.
        """
        copied_from = None
        if seq_len % self.block_size == 0:
            block_ids = block_ids + [self._alloc_raw()]
        else:
            tail = block_ids[-1]
            if self._blocks[tail].ref > 1:          # CoW: shared full-prefix tail
                nb = self._alloc_raw()
                self.free(tail)
                block_ids = block_ids[:-1] + [nb]
                copied_from = tail
                self.stats["cow"] += 1
        return block_ids, copied_from

    def utilization(self) -> float:
        return 1.0 - self.num_free / self.num_blocks


# --------------------------------------------------------------------------
# Device-side pool ops (jit-friendly, used by serve_step and the ref path)
# --------------------------------------------------------------------------


def make_kv_pool(num_layers: int, num_blocks: int, block_size: int,
                 num_kv_heads: int, head_dim: int, dtype=jnp.bfloat16):
    """Pre-allocated pool: (k_pool, v_pool) each [L, num_blocks, bs, KV, D]."""
    shape = (num_layers, num_blocks, block_size, num_kv_heads, head_dim)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def write_decode_kv(pool: jnp.ndarray, layer: int, k_new: jnp.ndarray,
                    block_table: jnp.ndarray, positions: jnp.ndarray) -> jnp.ndarray:
    """Scatter one token's K (or V) per sequence into the paged pool.

    pool: [L, NB, BS, KV, D]; k_new: [B, KV, D]; block_table: [B, MB];
    positions: [B] absolute position of the new token.
    """
    bs = pool.shape[2]
    blk = jnp.take_along_axis(block_table, (positions // bs)[:, None], axis=1)[:, 0]
    off = positions % bs
    return pool.at[layer, blk, off].set(k_new.astype(pool.dtype))


def write_prefill_kv(pool: jnp.ndarray, layer: int, k: jnp.ndarray,
                     block_table: jnp.ndarray, ctx_lens: jnp.ndarray,
                     pos_offset: int = 0) -> jnp.ndarray:
    """Scatter a prompt (or prompt chunk) K/V into the pool.

    k: [B, S, KV, D] (padded); k[:, i] holds position pos_offset + i; only
    absolute positions < ctx_lens are written.
    """
    B, S = k.shape[:2]
    bs = pool.shape[2]
    pos = pos_offset + jnp.arange(S)
    blk = block_table[:, pos // bs]                       # [B, S]
    off = pos % bs                                         # [S]
    valid = pos[None, :] < ctx_lens[:, None]               # [B, S]
    # route invalid tokens to a scratch (last) block offset that is then
    # overwritten by valid data — use mode='drop' semantics via clipping +
    # where on the payload.
    blk = jnp.where(valid, blk, pool.shape[1] - 1)
    k = jnp.where(valid[..., None, None], k, 0).astype(pool.dtype)
    flat_idx = (blk * bs + off[None, :]).reshape(-1)
    upd = k.reshape(B * S, *k.shape[2:])
    L, NB, BS = pool.shape[:3]
    lp = pool[layer].reshape(NB * BS, *pool.shape[3:])
    # guard scratch writes: drop invalid rows entirely
    flat_idx = jnp.where(valid.reshape(-1), flat_idx, NB * BS)   # OOB -> dropped
    lp = lp.at[flat_idx].set(upd, mode="drop")
    return pool.at[layer].set(lp.reshape(NB, BS, *pool.shape[3:]))


def gather_kv(pool: jnp.ndarray, layer: int, block_table: jnp.ndarray,
              max_len: int) -> jnp.ndarray:
    """Gather a contiguous [B, max_len, KV, D] view (reference path only)."""
    bs = pool.shape[2]
    nb = max_len // bs
    blk = block_table[:, :nb]                              # [B, nb]
    g = pool[layer][blk]                                   # [B, nb, bs, KV, D]
    return g.reshape(blk.shape[0], nb * bs, *pool.shape[3:])


# --------------------------------------------------------------------------
# Attention-free (SSM) state pool — paper's memory-pool insight, degenerate
# block table (see DESIGN.md §5): one slot per sequence, O(1) state.
# --------------------------------------------------------------------------


def make_state_pool(num_layers: int, max_seqs: int, d_inner: int,
                    ssm_state: int, conv_width: int, dtype=jnp.float32):
    """(ssm_state_pool [L, B, d_inner, N], conv_state_pool [L, B, d_inner, W-1])."""
    return (jnp.zeros((num_layers, max_seqs, d_inner, ssm_state), dtype),
            jnp.zeros((num_layers, max_seqs, d_inner, conv_width - 1), dtype))
