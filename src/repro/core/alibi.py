"""ALiBi (Attention with Linear Biases) — paper §III.A.

The paper's point: the bias is *added to the score tile*, never materialized
as a [S, S] mask matrix. Helpers here produce slopes and per-tile biases from
iota, so kernels and the XLA reference path both avoid the dense mask.
"""
from __future__ import annotations

import math

import jax.numpy as jnp


def alibi_slopes(num_heads: int) -> jnp.ndarray:
    """Standard ALiBi slope schedule: geometric in 2^(-8/n).

    Handles non-power-of-two head counts the way the ALiBi paper does
    (interleave the next power of two's odd slopes).
    """
    def pow2_slopes(n):
        start = 2.0 ** (-(2.0 ** -(math.log2(n) - 3)))
        return [start * (start ** i) for i in range(n)]

    if math.log2(num_heads).is_integer():
        s = pow2_slopes(num_heads)
    else:
        n = 2 ** math.floor(math.log2(num_heads))
        s = pow2_slopes(n)
        extra = pow2_slopes(2 * n)[0::2][: num_heads - n]
        s = s + extra
    return jnp.asarray(s, dtype=jnp.float32)


def alibi_bias(slopes: jnp.ndarray, q_pos: jnp.ndarray,
               k_pos: jnp.ndarray, causal: bool = True) -> jnp.ndarray:
    """Bias tile [H, Q, K] = -slope * |q_pos - k_pos| (causal: k<=q distance).

    q_pos: [Q] absolute query positions, k_pos: [K] absolute key positions.
    Pure arithmetic on iota — no [S, S] materialization at full length is
    needed by callers that tile (they pass tile-local position ranges).
    """
    dist = q_pos[:, None] - k_pos[None, :]                    # [Q, K]
    if causal:
        dist = jnp.maximum(dist, 0)
    else:
        dist = jnp.abs(dist)                                   # symmetric (encoder)
    return -slopes[:, None, None] * dist[None].astype(jnp.float32)
