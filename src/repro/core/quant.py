"""Packed-int4 weight representation + quantized linear (device side).

Pairs with core/gptq.py (which produces the codes offline). The layout is
TPU-friendly: codes are packed 8-per-int32 along the *in* dimension so the
Pallas kernel unpacks with shifts/masks in VREGs and feeds bf16 tiles to
the MXU.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.gptq import QuantizedTensor

PACK = 8  # int4 codes per int32 word


def pack_int4(q: np.ndarray) -> np.ndarray:
    """[in, out] uint8 codes (<16) -> [in//8, out] int32 (little-nibble-first)."""
    din, dout = q.shape
    pad = (-din) % PACK
    if pad:
        q = np.concatenate([q, np.zeros((pad, dout), q.dtype)], axis=0)
    q = q.reshape(-1, PACK, dout).astype(np.uint32)
    shifts = (4 * np.arange(PACK, dtype=np.uint32))[None, :, None]
    return (q << shifts).sum(axis=1).astype(np.int32)


def unpack_int4(packed: jnp.ndarray, din: int) -> jnp.ndarray:
    """[in//8, out] int32 -> [in, out] int32 codes in [0, 16)."""
    shifts = 4 * jnp.arange(PACK, dtype=jnp.int32)
    u = packed.astype(jnp.uint32)
    codes = (u[:, None, :] >> shifts[None, :, None].astype(jnp.uint32)) & 0xF
    return codes.reshape(-1, packed.shape[-1])[:din].astype(jnp.int32)


def make_quant_params(qt: QuantizedTensor, bias: Optional[np.ndarray] = None
                      ) -> Dict[str, jnp.ndarray]:
    """Device pytree for one quantized linear layer."""
    p = {
        "qweight": jnp.asarray(pack_int4(qt.q)),
        "scales": jnp.asarray(qt.scales, jnp.float32),
        "zeros": jnp.asarray(qt.zeros, jnp.float32),
        "g_idx": jnp.asarray(qt.g_idx, jnp.int32),
    }
    if bias is not None:
        p["bias"] = jnp.asarray(bias)
    return p


def dequantize(params: Dict[str, jnp.ndarray], din: int,
               dtype=jnp.bfloat16) -> jnp.ndarray:
    """Full dequant -> [in, out] (reference path / dry-run path)."""
    codes = unpack_int4(params["qweight"], din).astype(jnp.float32)
    s = params["scales"][params["g_idx"]]
    z = params["zeros"][params["g_idx"]]
    return ((codes - z) * s).astype(dtype)


def quant_matmul_ref(x: jnp.ndarray, params: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """y = x @ dequant(W) (+ bias). x: [..., in]."""
    w = dequantize(params, x.shape[-1], x.dtype)
    y = x @ w
    if "bias" in params:
        y = y + params["bias"].astype(y.dtype)
    return y
