"""Opt-GPTQ core: Opt-GQA attention, paged KV cache, GPTQ quantization,
ALiBi, and dynamic head grouping — the paper's contribution as composable
JAX modules."""
from repro.core.alibi import alibi_bias, alibi_slopes
from repro.core.gqa import decode_attention, grouped_attention, mha_attention
from repro.core.grouping import convert_mha_to_gqa, cluster_heads, head_similarity
from repro.core.gptq import HessianAccumulator, gptq_quantize, rtn_quantize, quant_error
from repro.core.kv_quant import (KVCache, copy_blocks_quant,
                                 dequantize_blocks, gather_kv_quant,
                                 make_kv_pool_quant, quantize_blocks,
                                 write_decode_kv_quant,
                                 write_prefill_kv_quant)
from repro.core.paged_cache import (BlockAllocator, OutOfBlocksError,
                                    gather_kv, make_kv_pool, make_state_pool,
                                    write_decode_kv, write_prefill_kv)
from repro.core.quant import (dequantize, make_quant_params, pack_int4,
                              quant_matmul_ref, unpack_int4)
