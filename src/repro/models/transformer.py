"""Model assembly for all families: init / forward / loss / prefill / decode.

Homogeneous stacks (dense, moe, ssm, encoder, vlm) scan over layer-stacked
params (fast compiles at 64+ layers); the heterogeneous hybrid
(recurrentgemma) python-loops over two per-kind stacks. Decode threads the
paged KV pool / SSM state pools through the layer loop.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.kv_quant import cache_from_state, cache_to_state
from repro.core.sampling import sample_from_logits
from repro.models import ssm as ssm_mod
from repro.models.attention import (attn_apply, attn_decode, attn_init,
                                    attn_prefill)
from repro.models.layers import (apply_norm, embed_init, linear, mlp_apply,
                                 mlp_init, norm_init, unembed)
from repro.models.moe import moe_apply, moe_init
from repro.runtime.sharding import ParallelCtx, shard, shard_map

Params = Dict[str, Any]


def _is_homogeneous(cfg: ModelConfig) -> bool:
    return len({cfg.layer_kind(i) for i in range(cfg.num_layers)}) == 1


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, kind: str, ep: int = 1) -> Params:
    ks = jax.random.split(key, 4)
    if kind == "ssm":
        return {"attn_norm": norm_init(cfg.d_model, cfg.norm),
                "ssm": ssm_mod.ssm_init(ks[0], cfg)}
    p: Params = {"attn_norm": norm_init(cfg.d_model, cfg.norm),
                 "mlp_norm": norm_init(cfg.d_model, cfg.norm)}
    if kind == "recurrent":
        p["rec"] = ssm_mod.rglru_init(ks[0], cfg)
    else:
        p["attn"] = attn_init(ks[0], cfg)
    if cfg.num_experts:
        p["moe"] = moe_init(ks[1], cfg, ep)
    else:
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act)
    return p


def init_params(cfg: ModelConfig, key, ep: int = 1) -> Params:
    ks = jax.random.split(key, 6)
    params: Params = {"embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model),
                      "final_norm": norm_init(cfg.d_model, cfg.norm)}
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(ks[1], (cfg.d_model, cfg.vocab_size))
                          * cfg.d_model ** -0.5)
    if cfg.frontend == "audio_frames":
        params["frontend_proj"] = (jax.random.normal(
            ks[2], (cfg.d_model, cfg.d_model)) * cfg.d_model ** -0.5)

    L = cfg.num_layers
    if _is_homogeneous(cfg):
        kind = cfg.layer_kind(0)
        lkeys = jax.random.split(ks[3], L)
        params["layers"] = jax.vmap(
            lambda k: init_layer(k, cfg, kind, ep))(lkeys)
    else:
        kinds = [cfg.layer_kind(i) for i in range(L)]
        for kset, name in ((("recurrent",), "rec_layers"),
                           (("full", "sliding"), "attn_layers")):
            idx = [i for i, k in enumerate(kinds) if k in kset]
            if idx:
                lkeys = jax.random.split(jax.random.fold_in(ks[3], hash(name) % 2**30),
                                         len(idx))
                params[name] = jax.vmap(
                    lambda k, kk=kinds[idx[0]]: init_layer(k, cfg, kk, ep))(lkeys)
    return params


# --------------------------------------------------------------------------
# Layer application (train / plain forward)
# --------------------------------------------------------------------------

def apply_layer(cfg: ModelConfig, lp: Params, x: jnp.ndarray, kind: str,
                ctx: Optional[ParallelCtx], rt: Optional[dict]) -> jnp.ndarray:
    h = apply_norm(lp["attn_norm"], x, cfg.norm, cfg.norm_eps)
    if kind == "ssm":
        return x + ssm_mod.ssm_apply(cfg, lp["ssm"], h, rt)
    if kind == "recurrent":
        mix = ssm_mod.rglru_apply(cfg, lp["rec"], h, rt)
    else:
        mix = attn_apply(cfg, lp["attn"], h, ctx, kind=kind, rt=rt)
    x = x + mix
    h = apply_norm(lp["mlp_norm"], x, cfg.norm, cfg.norm_eps)
    if cfg.num_experts:
        y = moe_apply(cfg, lp["moe"], h, ctx, rt)
    else:
        y = mlp_apply(lp["mlp"], h, cfg.act, rt)
    if ctx is not None:
        y = shard(ctx, y, P(ctx.dp_axes, None, None))
    return x + y


def _embed_inputs(cfg: ModelConfig, params: Params, batch: Dict[str, Any],
                  ctx, rt) -> jnp.ndarray:
    if cfg.frontend == "audio_frames":
        x = linear(batch["frames"], params["frontend_proj"], rt)
    else:
        x = params["embed"][batch["tokens"]]
        if cfg.frontend == "vision_patches" and "vision_embeds" in batch:
            x = jnp.concatenate(
                [batch["vision_embeds"].astype(x.dtype), x], axis=1)
    if ctx is not None:
        x = shard(ctx, x, P(ctx.dp_axes, None, None))
    return x.astype(jnp.dtype(cfg.dtype))


def forward(cfg: ModelConfig, params: Params, batch: Dict[str, Any],
            ctx: Optional[ParallelCtx] = None,
            rt: Optional[dict] = None) -> jnp.ndarray:
    """Full causal (or bidirectional-encoder) forward -> logits [B, S, V]."""
    rt = rt or {}
    x = _embed_inputs(cfg, params, batch, ctx, rt)
    L = cfg.num_layers

    if _is_homogeneous(cfg) and rt.get("scan_layers", True):
        kind = cfg.layer_kind(0)
        policy = rt.get("remat_policy")

        def body(h, lp):
            out = apply_layer(cfg, lp, h, kind, ctx, rt)
            return out, None

        body_r = jax.checkpoint(body, policy=policy)
        x, _ = jax.lax.scan(body_r, x, params["layers"])
    else:
        counters = {"rec_layers": 0, "attn_layers": 0, "layers": 0}
        for i in range(L):
            kind = cfg.layer_kind(i)
            if _is_homogeneous(cfg):
                stack, cname = params["layers"], "layers"
            elif kind == "recurrent":
                stack, cname = params["rec_layers"], "rec_layers"
            else:
                stack, cname = params["attn_layers"], "attn_layers"
            j = counters[cname]
            counters[cname] += 1
            lp = jax.tree.map(lambda a: a[j], stack)
            layer_fn = jax.checkpoint(
                lambda p_, x_, kind_=kind: apply_layer(cfg, p_, x_, kind_,
                                                       ctx, rt),
                policy=rt.get("remat_policy"))
            x = layer_fn(lp, x)

    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    logits = unembed(x, params["embed"], params.get("head"))
    if ctx is not None:
        tp = ctx.tp_axis if cfg.vocab_size % ctx.tp_size == 0 else None
        logits = shard(ctx, logits, P(ctx.dp_axes, None, tp))
    return logits


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, Any],
            ctx: Optional[ParallelCtx] = None,
            rt: Optional[dict] = None) -> jnp.ndarray:
    """Next-token (or frame-label) cross entropy, mean over valid tokens."""
    if cfg.is_encoder:
        logits = forward(cfg, params, batch, ctx, rt)
        labels = batch["labels"]
    else:
        tokens = batch["tokens"]
        inp = {**batch, "tokens": tokens[:, :-1]}
        logits = forward(cfg, params, inp, ctx, rt)
        labels = tokens[:, 1:]
        if cfg.frontend == "vision_patches" and "vision_embeds" in batch:
            logits = logits[:, batch["vision_embeds"].shape[1]:]
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = batch.get("loss_mask")
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


# --------------------------------------------------------------------------
# Serving: decode state + prefill + decode_step
# --------------------------------------------------------------------------

def attn_layer_count(cfg: ModelConfig) -> Tuple[int, int]:
    """(#attention layers, #recurrent/ssm layers)."""
    kinds = [cfg.layer_kind(i) for i in range(cfg.num_layers)]
    na = sum(k in ("full", "sliding") for k in kinds)
    return na, cfg.num_layers - na


def make_decode_state(cfg: ModelConfig, max_seqs: int, num_blocks: int,
                      max_blocks_per_seq: int,
                      dtype=None, kv_cache_dtype: Optional[str] = None
                      ) -> Dict[str, jnp.ndarray]:
    """``kv_cache_dtype="int8"`` builds the quantized pool format (int8
    values + per-block-per-head f32 scales); the default keeps the dense
    ``dtype`` pool (bf16/f32/fp8 via ``cfg.paging.cache_dtype``)."""
    from repro.core.kv_quant import (make_kv_pool_quant,
                                     normalize_kv_cache_dtype)
    from repro.core.paged_cache import make_kv_pool
    kv_mode = normalize_kv_cache_dtype(kv_cache_dtype)
    dtype = dtype if dtype is not None else jnp.dtype(cfg.paging.cache_dtype)
    na, nr = attn_layer_count(cfg)
    if kv_mode == "int8" and not na:
        raise ValueError(
            f"kv_cache_dtype='int8' requested but {cfg.name} has no "
            "attention KV cache to quantize (attention-free family "
            f"{cfg.family!r}); drop the flag — SSM/recurrent state pools "
            "are not paged KV")
    st: Dict[str, jnp.ndarray] = {
        "seq_lens": jnp.zeros((max_seqs,), jnp.int32),
    }
    if na:
        bs = cfg.paging.block_size
        if kv_mode == "int8":
            if any(cfg.layer_kind(i) == "sliding"
                   for i in range(cfg.num_layers)):
                raise ValueError(
                    "kv_cache_dtype='int8' does not support sliding-window "
                    f"(ring-cache) attention layers ({cfg.name}); the ring "
                    "overwrite pattern defeats per-block scale tracking")
            kp, vp, ks, vs = make_kv_pool_quant(
                na, num_blocks, bs, cfg.num_kv_heads, cfg.resolved_head_dim)
            st.update(k_scales=ks, v_scales=vs)
        else:
            kp, vp = make_kv_pool(na, num_blocks, bs, cfg.num_kv_heads,
                                  cfg.resolved_head_dim, dtype)
        st.update(k_pool=kp, v_pool=vp,
                  block_table=jnp.zeros((max_seqs, max_blocks_per_seq),
                                        jnp.int32))
    if cfg.family == "ssm":
        din = cfg.ssm_expand * cfg.d_model
        st["ssm_h"] = jnp.zeros((cfg.num_layers, max_seqs, din, cfg.ssm_state),
                                jnp.float32)
        st["ssm_conv"] = jnp.zeros((cfg.num_layers, max_seqs, din,
                                    cfg.ssm_conv - 1), dtype)
    if cfg.family == "hybrid" and nr:
        w = cfg.lru_width or cfg.d_model
        st["lru_h"] = jnp.zeros((nr, max_seqs, w), jnp.float32)
        st["rec_conv"] = jnp.zeros((nr, max_seqs, w, 3), dtype)
    return st


def decode_step(cfg: ModelConfig, params: Params,
                state: Dict[str, jnp.ndarray], tokens: jnp.ndarray,
                ctx: Optional[ParallelCtx] = None,
                rt: Optional[dict] = None
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One decode step for every active slot.

    tokens: [B] last generated token per slot. state["seq_lens"] must
    already count the new token. Returns (logits [B, V], new state).
    """
    rt = rt or {}
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))      # [B, d]
    state = dict(state)
    seq_lens = state["seq_lens"]
    L = cfg.num_layers
    homog = _is_homogeneous(cfg)
    kind0 = cfg.layer_kind(0)

    pool_spec = scale_spec = None
    if ctx is not None:
        kv_tp = (ctx.tp_axis if ctx.tp_axis and
                 cfg.num_kv_heads % ctx.tp_size == 0 else None)
        pool_spec = P(None, ctx.dp_axes, None, kv_tp, None)
        scale_spec = P(None, ctx.dp_axes, kv_tp)

    def _pin_cache(c):
        # keep the scan-carried pools sharded over dp between iterations —
        # without this GSPMD re-gathers the whole pool every layer.
        if pool_spec is None:
            return c
        c = c._replace(k=shard(ctx, c.k, pool_spec),
                       v=shard(ctx, c.v, pool_spec))
        if c.quantized:
            c = c._replace(k_scale=shard(ctx, c.k_scale, scale_spec),
                           v_scale=shard(ctx, c.v_scale, scale_spec))
        return c

    if homog and kind0 in ("full", "sliding") and rt.get("scan_layers", True):
        def body(carry, inp):
            h, cache = carry
            lp, li = inp
            hn = apply_norm(lp["attn_norm"], h, cfg.norm, cfg.norm_eps)
            mix, cache = attn_decode(
                cfg, lp["attn"], hn, ctx, kind=kind0, cache=cache,
                layer=li, block_table=state["block_table"],
                seq_lens=seq_lens, rt=rt)
            cache = _pin_cache(cache)
            h = h + mix
            hn = apply_norm(lp["mlp_norm"], h, cfg.norm, cfg.norm_eps)
            if cfg.num_experts:
                y = moe_apply(cfg, lp["moe"], hn[:, None, :], ctx, rt)[:, 0]
            else:
                y = mlp_apply(lp["mlp"], hn, cfg.act, rt)
            return (h + y, cache), None

        (x, cache), _ = jax.lax.scan(
            body, (x, cache_from_state(state)),
            (params["layers"], jnp.arange(L)))
        state.update(cache_to_state(cache))
    elif homog and kind0 == "ssm" and rt.get("scan_layers", True):
        def body(carry, inp):
            h, hp, cp = carry
            lp, li = inp
            hn = apply_norm(lp["attn_norm"], h, cfg.norm, cfg.norm_eps)
            y, hs, cs = ssm_mod.ssm_decode(cfg, lp["ssm"], hn,
                                           hp[li], cp[li])
            hp = jax.lax.dynamic_update_index_in_dim(hp, hs, li, 0)
            cp = jax.lax.dynamic_update_index_in_dim(cp, cs, li, 0)
            return (h + y, hp, cp), None

        (x, hp, cp), _ = jax.lax.scan(
            body, (x, state["ssm_h"], state["ssm_conv"]),
            (params["layers"], jnp.arange(L)))
        state["ssm_h"], state["ssm_conv"] = hp, cp
    else:
        ai = ri = 0
        for i in range(L):
            kind = cfg.layer_kind(i)
            if homog:
                lp = jax.tree.map(lambda a: a[i], params["layers"])
            elif kind == "recurrent":
                lp = jax.tree.map(lambda a: a[ri], params["rec_layers"])
            else:
                lp = jax.tree.map(lambda a: a[ai], params["attn_layers"])
            hn = apply_norm(lp["attn_norm"], x, cfg.norm, cfg.norm_eps)
            if kind == "ssm":
                y, hs, cs = ssm_mod.ssm_decode(cfg, lp["ssm"], hn,
                                               state["ssm_h"][i],
                                               state["ssm_conv"][i])
                state["ssm_h"] = state["ssm_h"].at[i].set(hs)
                state["ssm_conv"] = state["ssm_conv"].at[i].set(cs)
                x = x + y
                continue
            if kind == "recurrent":
                mix, hs, cs = ssm_mod.rglru_decode(cfg, lp["rec"], hn,
                                                   state["lru_h"][ri],
                                                   state["rec_conv"][ri])
                state["lru_h"] = state["lru_h"].at[ri].set(hs)
                state["rec_conv"] = state["rec_conv"].at[ri].set(cs)
                ri += 1
            else:
                mix, cache = attn_decode(
                    cfg, lp["attn"], hn, ctx, kind=kind,
                    cache=cache_from_state(state), layer=ai,
                    block_table=state["block_table"], seq_lens=seq_lens, rt=rt)
                state.update(cache_to_state(cache))
                ai += 1
            x = x + mix
            hn = apply_norm(lp["mlp_norm"], x, cfg.norm, cfg.norm_eps)
            if cfg.num_experts:
                y = moe_apply(cfg, lp["moe"], hn[:, None, :], ctx, rt)[:, 0]
            else:
                y = mlp_apply(lp["mlp"], hn, cfg.act, rt)
            x = x + y

    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    logits = unembed(x, params["embed"], params.get("head"))
    return logits.astype(jnp.float32), state


def decode_megastep(cfg: ModelConfig, params: Params,
                    state: Dict[str, jnp.ndarray], tokens: jnp.ndarray,
                    sampling: Dict[str, jnp.ndarray], active: jnp.ndarray,
                    n_steps: jnp.ndarray, *,
                    max_horizon: int,
                    ctx: Optional[ParallelCtx] = None,
                    rt: Optional[dict] = None
                    ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Fused decode fast path: up to ``max_horizon`` decode+sample steps in
    ONE device call — KV scatter, paged attention, logits and sampling all
    stay on device; the host only sees the final [max_horizon, B] token
    buffer (a single transfer per dispatched horizon).

    tokens: [B] last sampled token per slot (state["seq_lens"] counts it).
    sampling: padded per-slot ``SamplingParams`` arrays —
            keys [B, 2] uint32 (per-slot PRNG stream roots),
            counts [B] i32 (tokens generated so far: the stream position),
            temps [B] f32 (0 => greedy), top_ks [B] i32 (0 => off),
            top_ps [B] f32 (1.0 => off).  Step ``t`` of the horizon
            samples slot ``b`` with ``fold_in(keys[b], counts[b] + t)`` —
            exactly the key the legacy host loop derives, so fused and
            legacy outputs are bitwise identical per slot.
    active: [B] bool; inactive slots are carried through untouched (their
            KV writes are dropped, their seq_lens stay 0).
    n_steps: scalar int32 *dynamic* trip count <= max_horizon — the host
            dispatches exactly ``steps_until_boundary`` steps without a
            recompile (lax.fori_loop lowers to a while loop).

    Returns (out_tokens [max_horizon, B] i32 — rows >= n_steps are zero,
    new state). Jit with ``donate_argnums`` on ``state`` so the
    [L, NB, BS, KV, D] pools update in place instead of being copied
    every token.
    """
    rt = rt or {}
    B = tokens.shape[0]
    out = jnp.zeros((max_horizon, B), jnp.int32)
    active_i = active.astype(jnp.int32)
    # static sampling-guard flag (rt is a host dict closed over at trace
    # time): guarded rows sample -1 on non-finite logits — see
    # ``core.sampling.sample_from_logits``
    guard = bool(rt.get("sampling_guard"))

    def body(t, carry):
        state, toks, out = carry
        logits, state = decode_step(cfg, params, state, toks, ctx, rt)
        nxt = sample_from_logits(logits, sampling["keys"],
                                 sampling["counts"] + t, sampling["temps"],
                                 sampling["top_ks"], sampling["top_ps"],
                                 poison=sampling.get("poison"), guard=guard)
        nxt = jnp.where(active, nxt, toks)
        state = dict(state)
        state["seq_lens"] = state["seq_lens"] + active_i
        out = out.at[t].set(jnp.where(active, nxt, 0))
        # a guarded -1 must not feed back into the next step's embedding
        # lookup (the row is dead; the host quarantines it on readback)
        safe = jnp.maximum(nxt, 0) if guard else nxt
        return (state, safe, out)

    state, _, out = jax.lax.fori_loop(
        0, n_steps, body, (state, tokens, out))
    return out, state


def prefill(cfg: ModelConfig, params: Params, state: Dict[str, jnp.ndarray],
            batch: Dict[str, Any], ctx: Optional[ParallelCtx] = None,
            rt: Optional[dict] = None
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Prompt prefill: fills caches, returns last-token logits [B, V].

    batch: tokens [B, S] (right-padded), ctx_lens [B]. state["seq_lens"]
    is set to ctx_lens.
    """
    rt = rt or {}
    tokens, ctx_lens = batch["tokens"], batch["ctx_lens"]
    x = _embed_inputs(cfg, params, batch, ctx, rt)
    S = x.shape[1]
    if S != tokens.shape[1]:               # vlm: vision prefix counts as context
        ctx_lens = ctx_lens + (S - tokens.shape[1])
    state = dict(state)
    state["seq_lens"] = ctx_lens
    mask = (jnp.arange(S)[None, :] < ctx_lens[:, None])

    homog = _is_homogeneous(cfg)
    kind0 = cfg.layer_kind(0)
    if (rt.get("prefill_chunk") and homog and kind0 == "full"):
        return _prefill_chunked(cfg, params, state, x, ctx_lens, ctx, rt)
    if homog and rt.get("scan_layers", True) and kind0 != "recurrent":
        if kind0 in ("full", "sliding"):
            pf = attn_prefill_ring if kind0 == "sliding" else attn_prefill

            def body(carry, inp):
                h, cache = carry
                lp, li = inp
                hn = apply_norm(lp["attn_norm"], h, cfg.norm, cfg.norm_eps)
                mix, cache = pf(cfg, lp["attn"], hn, ctx, kind=kind0,
                                cache=cache, layer=li,
                                block_table=state["block_table"],
                                ctx_lens=ctx_lens, rt=rt)
                h = h + mix
                hn = apply_norm(lp["mlp_norm"], h, cfg.norm, cfg.norm_eps)
                if cfg.num_experts:
                    y = moe_apply(cfg, lp["moe"], hn, ctx, rt)
                else:
                    y = mlp_apply(lp["mlp"], hn, cfg.act, rt)
                return (h + y, cache), None

            body = jax.checkpoint(body, policy=rt.get("remat_policy"))
            (x, cache), _ = jax.lax.scan(
                body, (x, cache_from_state(state)),
                (params["layers"], jnp.arange(cfg.num_layers)))
            state.update(cache_to_state(cache))
        else:                                    # ssm
            def body(carry, inp):
                h, hp, cp = carry
                lp, li = inp
                hn = apply_norm(lp["attn_norm"], h, cfg.norm, cfg.norm_eps)
                y, hs, cs = ssm_mod.ssm_prefill(cfg, lp["ssm"], hn, mask,
                                                ctx_lens, rt)
                hp = jax.lax.dynamic_update_index_in_dim(hp, hs, li, 0)
                cp = jax.lax.dynamic_update_index_in_dim(
                    cp, cs.astype(cp.dtype), li, 0)
                return (h + y, hp, cp), None

            body = jax.checkpoint(body, policy=rt.get("remat_policy"))
            (x, hp, cp), _ = jax.lax.scan(
                body, (x, state["ssm_h"], state["ssm_conv"]),
                (params["layers"], jnp.arange(cfg.num_layers)))
            state["ssm_h"], state["ssm_conv"] = hp, cp
        x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
        last = jnp.take_along_axis(x, (ctx_lens - 1)[:, None, None],
                                   axis=1)[:, 0]
        logits = unembed(last, params["embed"], params.get("head"))
        return logits.astype(jnp.float32), state

    ai = ri = 0
    for i in range(cfg.num_layers):
        kind = cfg.layer_kind(i)
        if _is_homogeneous(cfg):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
        elif kind == "recurrent":
            lp = jax.tree.map(lambda a: a[ri], params["rec_layers"])
        else:
            lp = jax.tree.map(lambda a: a[ai], params["attn_layers"])
        hn = apply_norm(lp["attn_norm"], x, cfg.norm, cfg.norm_eps)
        if kind == "ssm":
            y, hs, cs = ssm_mod.ssm_prefill(cfg, lp["ssm"], hn, mask, ctx_lens, rt)
            state["ssm_h"] = state["ssm_h"].at[i].set(hs)
            state["ssm_conv"] = state["ssm_conv"].at[i].set(cs.astype(
                state["ssm_conv"].dtype))
            x = x + y
            continue
        if kind == "recurrent":
            mix, hs, cs = ssm_mod.rglru_prefill(cfg, lp["rec"], hn, mask,
                                                ctx_lens, rt)
            state["lru_h"] = state["lru_h"].at[ri].set(hs)
            state["rec_conv"] = state["rec_conv"].at[ri].set(cs.astype(
                state["rec_conv"].dtype))
            ri += 1
        else:
            pf = attn_prefill_ring if kind == "sliding" else attn_prefill
            mix, cache = pf(
                cfg, lp["attn"], hn, ctx, kind=kind,
                cache=cache_from_state(state), layer=ai,
                block_table=state["block_table"], ctx_lens=ctx_lens, rt=rt)
            state.update(cache_to_state(cache))
            ai += 1
        x = x + mix
        hn = apply_norm(lp["mlp_norm"], x, cfg.norm, cfg.norm_eps)
        if cfg.num_experts:
            y = moe_apply(cfg, lp["moe"], hn, ctx, rt)
        else:
            y = mlp_apply(lp["mlp"], hn, cfg.act, rt)
        x = x + y

    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    last = jnp.take_along_axis(x, (ctx_lens - 1)[:, None, None], axis=1)[:, 0]
    logits = unembed(last, params["embed"], params.get("head"))
    return logits.astype(jnp.float32), state


def _prefill_chunked(cfg: ModelConfig, params: Params, state, x, ctx_lens,
                     ctx, rt):
    """Chunked prefill (beyond-paper, vLLM-style): the prompt is processed
    in ``rt['prefill_chunk']``-token chunks; each chunk's attention reads
    the already-cached prefix back from the paged pool, so activation
    memory is O(chunk) instead of O(S). Full-attention homogeneous archs.
    """
    from repro.core.kv_quant import kv_gather, kv_write_prefill
    from repro.models.attention import _qkv, _slopes
    from repro.kernels import ops as kops
    B, S, d = x.shape
    c = min(rt["prefill_chunk"], S)
    state = dict(state)
    bt = state["block_table"]
    slopes = _slopes(cfg)
    cache_def = jax.tree.structure(cache_from_state(state))

    B_ = x.shape[0]
    use_island = (ctx is not None and ctx.dp_size > 1
                  and B_ % ctx.dp_size == 0)

    for off in range(0, S, c):
        ce = min(off + c, S)
        xc = x[:, off:ce]

        def cache_attend(q, k, v, bt_l, cl_l, li, *leaves, off=off, ce=ce):
            """Per-dp-shard: write chunk K/V, gather cached prefix, attend.
            Local block ids; collective-free (DESIGN.md §4)."""
            cache = jax.tree.unflatten(cache_def, leaves)
            cache = kv_write_prefill(cache, li, k, v, bt_l, cl_l,
                                     pos_offset=off)
            bs = cache.block_size
            ce_b = min(((ce + bs - 1) // bs) * bs, bt_l.shape[1] * bs)
            kc, vc = kv_gather(cache, li, bt_l, ce_b, q.dtype)
            kc, vc = kc[:, :ce], vc[:, :ce]
            if rt.get("skip_mixer_core"):
                o = q * (1 + 1e-30 * (kc.sum() + vc.sum()))
            else:
                o = kops.flash_attention(
                    q, kc, vc, slopes, causal=True, q_offset=off,
                    use_pallas=rt.get("use_pallas"),
                    interpret=rt.get("interpret"))
            return (o, *jax.tree.leaves(cache))

        def body(carry, inp, off=off, ce=ce):
            h, cache = carry
            lp, li = inp
            hn = apply_norm(lp["attn_norm"], h, cfg.norm, cfg.norm_eps)
            q, k, v = _qkv(cfg, lp["attn"], hn,
                           off + jnp.arange(ce - off), ctx, rt)
            leaves = jax.tree.leaves(cache)
            if use_island:
                dp = ctx.dp_axes
                leaf_specs = tuple(P(None, dp) for _ in leaves)
                o, *leaves = shard_map(
                    cache_attend, mesh=ctx.mesh,
                    in_specs=(P(dp), P(dp), P(dp), P(dp), P(dp), P(),
                              *leaf_specs),
                    out_specs=(P(dp), *leaf_specs),
                    axis_names=set(dp), check_vma=False,
                )(q, k, v, bt, ctx_lens, li, *leaves)
            else:
                o, *leaves = cache_attend(q, k, v, bt, ctx_lens, li, *leaves)
            cache = jax.tree.unflatten(cache_def, leaves)
            h = h + linear(o.reshape(*o.shape[:2], -1), lp["attn"]["wo"], rt)
            hn = apply_norm(lp["mlp_norm"], h, cfg.norm, cfg.norm_eps)
            if cfg.num_experts:
                y = moe_apply(cfg, lp["moe"], hn, ctx, rt)
            else:
                y = mlp_apply(lp["mlp"], hn, cfg.act, rt)
            return (h + y, cache), None

        body_r = jax.checkpoint(body, policy=rt.get("remat_policy"))
        if rt.get("scan_layers", True):
            (xc, cache), _ = jax.lax.scan(
                body_r, (xc, cache_from_state(state)),
                (params["layers"], jnp.arange(cfg.num_layers)))
        else:                    # unrolled (dry-run cost extrapolation)
            carry = (xc, cache_from_state(state))
            for li in range(cfg.num_layers):
                lp = jax.tree.map(lambda a: a[li], params["layers"])
                carry, _ = body_r(carry, (lp, jnp.int32(li)))
            xc, cache = carry
        state.update(cache_to_state(cache))
        x = x.at[:, off:ce].set(xc)        # final hidden states per chunk

    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    last = jnp.take_along_axis(x, (ctx_lens - 1)[:, None, None], axis=1)[:, 0]
    logits = unembed(last, params["embed"], params.get("head"))
    return logits.astype(jnp.float32), state


def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """Serving-side chunked prefill needs every layer to carry its state
    in the paged KV pool (homogeneous full-attention stacks): SSM /
    recurrent / sliding-ring layers hold per-slot recurrent state that is
    not yet re-enterable mid-prompt, so those archs keep the whole-prompt
    path.  Encoders are excluded too: bidirectional attention has no
    causal chunk decomposition (and no KV cache to chunk into)."""
    return _is_homogeneous(cfg) and cfg.layer_kind(0) == "full" \
        and not cfg.is_encoder


def prefill_chunk(cfg: ModelConfig, params: Params, cache,
                  tokens: jnp.ndarray, block_table: jnp.ndarray,
                  pos_offset: jnp.ndarray, total_len: jnp.ndarray,
                  ctx: Optional[ParallelCtx] = None,
                  rt: Optional[dict] = None):
    """One fixed-shape prefill chunk of ONE sequence (token-budget serving).

    Unlike ``prefill`` (whole padded prompt, one compile per ``[B, S]``)
    and ``_prefill_chunked`` (static per-offset chunks inside one call),
    this is the serving executable: ``tokens`` is always ``[1, W]``
    (W = the engine's chunk budget) and ``pos_offset`` / ``total_len``
    are *device scalars*, so every chunk of every prompt — first, middle,
    last, any length — runs from a single compiled executable.

    tokens: [1, W] right-padded chunk (positions pos_offset + i);
    block_table: [1, MB] this sequence's block row (chunk blocks already
    allocated); pos_offset: i32 scalar, absolute position of tokens[0, 0];
    total_len: i32 scalar, pos_offset + live chunk length.  Each layer
    writes the chunk's K/V into the paged pool at its absolute positions
    (int8 mode merges the boundary block via the dynamic-offset quant
    write), then attends over the pool's *live prefix* plus its own raw
    K/V through ``ops.chunk_prefill_attention`` — the dynamic-offset
    Pallas flash kernel on TPU (scalar-prefetch page walk clamped to the
    live length), the bounded-gather XLA oracle elsewhere; either way the
    per-layer pool traffic is O(total_len), not O(table capacity).
    Padded rows compute garbage that never escapes their row; the
    returned logits ``[1, V]`` are the *last live token's* — only
    meaningful on a prompt's final chunk.  Returns (logits, cache).
    """
    from repro.core.kv_quant import kv_write_prefill
    from repro.kernels import ops as kops
    from repro.models.attention import _qkv, _slopes
    rt = rt or {}
    assert supports_chunked_prefill(cfg), cfg.name
    W = tokens.shape[1]
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))   # [1, W, d]
    positions = pos_offset + jnp.arange(W)
    total_len = jnp.asarray(total_len, jnp.int32)
    ctx_lens = total_len[None] if total_len.ndim == 0 else total_len
    total_len = ctx_lens[0]                                    # scalar form
    slopes = _slopes(cfg)

    def body(carry, inp):
        h, cache = carry
        lp, li = inp
        hn = apply_norm(lp["attn_norm"], h, cfg.norm, cfg.norm_eps)
        q, k, v = _qkv(cfg, lp["attn"], hn, positions, ctx, rt)
        cache = kv_write_prefill(cache, li, k, v, block_table, ctx_lens,
                                 pos_offset=pos_offset)
        if rt.get("skip_mixer_core"):
            o = q * (1 + 1e-30 * (k.sum() + v.sum()))
        else:
            # the chunk attends its OWN tokens raw (exactly like whole-
            # prompt prefill), never pool-roundtripped, so int8
            # quantization noise only enters for *earlier* chunks'
            # positions; the traced q_offset drives the causal mask,
            # which also hides every not-yet-written pool position.
            o = kops.chunk_prefill_attention(
                q, cache.k, cache.v, cache.k_scale, cache.v_scale, li,
                block_table, pos_offset, total_len, k, v, slopes,
                use_pallas=rt.get("use_pallas"),
                interpret=rt.get("interpret"))
        h = h + linear(o.reshape(*o.shape[:2], -1), lp["attn"]["wo"], rt)
        hn = apply_norm(lp["mlp_norm"], h, cfg.norm, cfg.norm_eps)
        if cfg.num_experts:
            y = moe_apply(cfg, lp["moe"], hn, ctx, rt)
        else:
            y = mlp_apply(lp["mlp"], hn, cfg.act, rt)
        return (h + y, cache), None

    if rt.get("scan_layers", True):
        (x, cache), _ = jax.lax.scan(
            body, (x, cache), (params["layers"], jnp.arange(cfg.num_layers)))
    else:                        # unrolled (dry-run cost extrapolation)
        carry = (x, cache)
        for li in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[li], params["layers"])
            carry, _ = body(carry, (lp, jnp.int32(li)))
        x, cache = carry

    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    last_i = jnp.clip(total_len - pos_offset - 1, 0, W - 1)
    last = jnp.take_along_axis(x, last_i[None, None, None], axis=1)[:, 0]
    logits = unembed(last, params["embed"], params.get("head"))
    return logits.astype(jnp.float32), cache


def unified_step(cfg: ModelConfig, params: Params,
                 state: Dict[str, jnp.ndarray], tokens: jnp.ndarray,
                 sampling: Dict[str, jnp.ndarray], active: jnp.ndarray,
                 chunk_tokens: jnp.ndarray, chunk_block_table: jnp.ndarray,
                 pos_offset: jnp.ndarray, total_len: jnp.ndarray,
                 ctx: Optional[ParallelCtx] = None,
                 rt: Optional[dict] = None
                 ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One serving iteration in ONE device dispatch: a single decode step
    for every active slot, one prefill chunk, and per-row sampling —
    the unified prefill/decode batch (vLLM-style) over the paged pools.

    While a prompt is being chunk-prefilled the scheduler pins the decode
    horizon to 1, which previously cost two (plus a sampling) device
    calls per engine iteration; this executable runs the same
    computations under one ``jit``: shared KV pools (the decode scatter
    and the chunk scatter touch disjoint physical blocks), one paged-
    attention + chunk-flash kernel invocation pair, and ONE
    logits/sample readback per step.

    tokens: [B] last sampled token per decode slot (seq_lens counts it;
        slots the plan excluded carry seq_len 0, so their KV writes are
        dropped exactly like in ``decode_megastep``);
    sampling: padded per-row ``SamplingParams`` arrays of B + 1 rows —
        rows [0, B) are the decode slots, row B is the chunk's request
        (each row's key is ``fold_in(keys[r], counts[r])``, the same
        stream position the two-call path derives, so sampled tokens are
        bitwise identical to the megastep + batched-sample pair);
    active: [B] bool decode mask (row gating only — the host ignores
        inactive rows of the output);
    chunk_tokens / chunk_block_table / pos_offset / total_len: the
        fixed-shape ``[1, W]`` chunk executable's operands (see
        ``prefill_chunk``).

    Returns (next_tokens [B + 1] i32, new state): rows [0, B) are the
    decode samples (inactive rows hold garbage the host drops), row B is
    the chunk's last-live-token sample — meaningful only on a prompt's
    final chunk.  Jit with ``donate_argnums`` on ``state``.
    """
    rt = rt or {}
    logits_dec, state = decode_step(cfg, params, state, tokens, ctx, rt)
    state = dict(state)
    state["seq_lens"] = state["seq_lens"] + active.astype(jnp.int32)
    cache = cache_from_state(state)
    logits_chunk, cache = prefill_chunk(
        cfg, params, cache, chunk_tokens, chunk_block_table, pos_offset,
        total_len, ctx, rt)
    state.update(cache_to_state(cache))
    logits = jnp.concatenate([logits_dec, logits_chunk], axis=0)
    nxt = sample_from_logits(logits, sampling["keys"], sampling["counts"],
                             sampling["temps"], sampling["top_ks"],
                             sampling["top_ps"],
                             poison=sampling.get("poison"),
                             guard=bool((rt or {}).get("sampling_guard")))
    return nxt, state


def unified_step_chained(cfg: ModelConfig, params: Params,
                         state: Dict[str, jnp.ndarray],
                         prev_tokens: jnp.ndarray, chain_idx: jnp.ndarray,
                         use_prev: jnp.ndarray, tokens: jnp.ndarray,
                         sampling: Dict[str, jnp.ndarray],
                         active: jnp.ndarray, chunk_tokens: jnp.ndarray,
                         chunk_block_table: jnp.ndarray,
                         pos_offset: jnp.ndarray, total_len: jnp.ndarray,
                         ctx: Optional[ParallelCtx] = None,
                         rt: Optional[dict] = None
                         ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """``unified_step`` with on-device feed-token chaining — the async
    pipelined engine's executable (one dispatch perpetually in flight).

    When dispatch N+1 is enqueued, dispatch N's sampled tokens are still
    on device: row ``r``'s feed token is gathered from the *previous
    dispatch's output buffer* (``prev_tokens[chain_idx[r]]``, an
    ``[B + 1]`` buffer whose row B is the chunk sample) when
    ``use_prev[r]``, and from the host-known ``tokens[r]`` otherwise
    (pipeline restart after a flush, or a slot whose last token was
    absorbed on the host).  The gathered token is clamped at 0: a row
    the non-finite guard sampled as ``-1`` must not index the embedding
    — its successor token is garbage the engine discards at reconcile,
    exactly the megastep's clamped-placeholder-forward contract.

    Jit WITHOUT donation: the pipeline's whole point is that enqueueing
    N+1 must not wait for N, and donating a buffer that is still being
    produced by the in-flight dispatch forces the XLA CPU client to
    execute synchronously (measured: zero host/device overlap).  The
    non-donated state copy is the price of the overlap — ~2 MB on the
    reduced serving configs, well under one step of host time.
    """
    fed = jnp.where(use_prev,
                    jnp.clip(prev_tokens[chain_idx], 0, None), tokens)
    return unified_step(cfg, params, state, fed, sampling, active,
                        chunk_tokens, chunk_block_table, pos_offset,
                        total_len, ctx, rt)


def attn_prefill_ring(cfg, p, x, ctx, *, kind, cache, layer,
                      block_table, ctx_lens, rt):
    """Sliding-window prefill: compute flash-SWA attention, then write each
    token's K/V at ring slot pos % cache_len (later tokens overwrite).
    bf16-only: int8 KV is rejected for sliding archs at state creation."""
    from repro.models.attention import _qkv, _slopes
    from repro.kernels import ops as kops
    B, S, _ = x.shape
    positions = jnp.arange(S)
    q, k, v = _qkv(cfg, p, x, positions, ctx, rt)
    o = kops.flash_attention(q, k, v, _slopes(cfg), causal=True,
                             sliding_window=cfg.sliding_window,
                             use_pallas=rt.get("use_pallas"),
                             interpret=rt.get("interpret"))
    cache_len = block_table.shape[1] * cache.k.shape[2]
    # keep only the last cache_len tokens per sequence: token at position p
    # lands at ring slot p % cache_len; older tokens in the same slot must
    # be dropped, so mask tokens with p < ctx_len - cache_len.
    keep = ((positions[None] >= ctx_lens[:, None] - cache_len)
            & (positions[None] < ctx_lens[:, None]))
    # token at position p lands at ring slot p % cache_len; the keep window
    # spans at most cache_len positions, so slots are collision-free.
    cache = cache._replace(
        k=_write_ring(cache.k, layer, k, block_table, positions, keep,
                      cache_len),
        v=_write_ring(cache.v, layer, v, block_table, positions, keep,
                      cache_len))
    y = linear(o.reshape(B, S, -1), p["wo"], rt)
    return y, cache


def _write_ring(pool, layer, k, block_table, positions, keep, cache_len):
    B, S = k.shape[:2]
    bs = pool.shape[2]
    slot = positions % cache_len                              # [S]
    blk = block_table[:, slot // bs]                          # [B, S]
    off = slot % bs
    NB, BS = pool.shape[1], pool.shape[2]
    flat_idx = (blk * bs + off[None, :]).reshape(-1)
    flat_idx = jnp.where(keep.reshape(-1), flat_idx, NB * BS)
    lp = pool[layer].reshape(NB * BS, *pool.shape[3:])
    lp = lp.at[flat_idx].set(k.reshape(B * S, *k.shape[2:]).astype(pool.dtype),
                             mode="drop")
    return pool.at[layer].set(lp.reshape(NB, BS, *pool.shape[3:]))
