"""Attention layer (full / sliding-window) built on the Opt-GQA core.

Train/prefill use the flash kernel (or its XLA reference); decode uses the
paged kernel over the block-table pool, or a ring cache for sliding-window
layers (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.alibi import alibi_slopes
from repro.core.kv_quant import KVCache, kv_write_decode, kv_write_prefill
from repro.kernels import ops
from repro.models.layers import dense_init, linear, rope
from repro.runtime.sharding import ParallelCtx, shard, shard_map

Params = Dict[str, jnp.ndarray]


def attn_init(key, cfg: ModelConfig) -> Params:
    d, H, KV, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H, Dh), in_axis_size=d),
        "wk": dense_init(ks[1], (d, KV, Dh), in_axis_size=d),
        "wv": dense_init(ks[2], (d, KV, Dh), in_axis_size=d),
        "wo": dense_init(ks[3], (H, Dh, d), in_axis_size=H * Dh),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, Dh))
        p["bk"] = jnp.zeros((KV, Dh))
        p["bv"] = jnp.zeros((KV, Dh))
    return p


def _qkv(cfg: ModelConfig, p: Params, x: jnp.ndarray, positions,
         ctx: Optional[ParallelCtx], rt: Optional[dict] = None):
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = linear(x, p["wq"], rt, out_tail=(H, Dh))
    k = linear(x, p["wk"], rt, out_tail=(KV, Dh))
    v = linear(x, p["wv"], rt, out_tail=(KV, Dh))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.pos_emb == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    if ctx is not None:
        tp = ctx.tp_axis if cfg.num_heads % ctx.tp_size == 0 else None
        kv_tp = ctx.tp_axis if cfg.num_kv_heads % ctx.tp_size == 0 else None
        q = shard(ctx, q, P(ctx.dp_axes, None, tp, None))
        k = shard(ctx, k, P(ctx.dp_axes, None, kv_tp, None))
        v = shard(ctx, v, P(ctx.dp_axes, None, kv_tp, None))
    return q, k, v


def _slopes(cfg: ModelConfig):
    return alibi_slopes(cfg.num_heads) if cfg.pos_emb == "alibi" else None


def attn_apply(cfg: ModelConfig, p: Params, x: jnp.ndarray,
               ctx: Optional[ParallelCtx], *, kind: str = "full",
               pos_offset=0, rt: Optional[dict] = None) -> jnp.ndarray:
    """Train/prefill path. x: [B, S, d] -> [B, S, d]."""
    rt = rt or {}
    B, S, d = x.shape
    positions = pos_offset + jnp.arange(S)
    q, k, v = _qkv(cfg, p, x, positions, ctx, rt)
    win = cfg.sliding_window if kind == "sliding" else 0
    if rt.get("skip_mixer_core"):
        # roofline decomposition lower: mixer core replaced by identity
        # (kernel terms added analytically — launch/roofline.py)
        o = q + 1e-30 * (k.sum(2, keepdims=True) + v.sum(2, keepdims=True))
    else:
        o = ops.flash_attention(
            q, k, v, _slopes(cfg), causal=not cfg.is_encoder,
            sliding_window=win,
            use_pallas=rt.get("use_pallas"), interpret=rt.get("interpret"))
    if ctx is not None:
        tp = ctx.tp_axis if cfg.num_heads % ctx.tp_size == 0 else None
        o = shard(ctx, o, P(ctx.dp_axes, None, tp, None))
    B_, S_, H_, D_ = o.shape
    return linear(o.reshape(B_, S_, H_ * D_), p["wo"], rt)


# --------------------------------------------------------------------------
# Serving paths: prefill-with-cache-write and paged decode.
# --------------------------------------------------------------------------

def attn_prefill(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                 ctx: Optional[ParallelCtx], *, kind: str,
                 cache: KVCache, layer: int, block_table, ctx_lens,
                 rt: Optional[dict] = None):
    """Prefill: attention over the prompt AND write K/V into the paged pool.

    Returns (y, cache). cache pools: [L, NB, BS, KV, D] (quantize-on-write
    when the cache carries int8 values + scales).
    """
    rt = rt or {}
    B, S, _ = x.shape
    positions = jnp.arange(S)
    q, k, v = _qkv(cfg, p, x, positions, ctx, rt)
    win = cfg.sliding_window if kind == "sliding" else 0
    if rt.get("skip_mixer_core"):
        o = q + 1e-30 * (k.sum(2, keepdims=True) + v.sum(2, keepdims=True))
    else:
        o = ops.flash_attention(q, k, v, _slopes(cfg), causal=True,
                                sliding_window=win,
                                use_pallas=rt.get("use_pallas"),
                                interpret=rt.get("interpret"))
    cache = kv_write_prefill(cache, layer, k, v, block_table, ctx_lens)
    B_, S_, H_, D_ = o.shape
    y = linear(o.reshape(B_, S_, H_ * D_), p["wo"], rt)
    return y, cache


def attn_decode(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                ctx: Optional[ParallelCtx], *, kind: str,
                cache: KVCache, layer: int, block_table, seq_lens,
                rt: Optional[dict] = None):
    """One-token decode. x: [B, d]; cache pools [L, NB, BS, KV, D] (ring
    for SWA; int8 values + [L, NB, KV] scales when quantized).

    Returns (y [B, d], cache).

    Under a mesh, the cache write + paged attention run inside a shard_map
    island manual over the dp axes: each dp shard owns its sequences' pool
    blocks and block table (local ids), so decode attention is collective-
    free (DESIGN.md §4). The model axis stays auto (TP in the projections).
    """
    rt = rt or {}
    B, d = x.shape
    positions = (seq_lens - 1)[:, None]                   # [B,1] absolute pos
    q, k, v = _qkv(cfg, p, x[:, None, :], positions, None, rt)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                   # [B, H/KV, D]

    win = cfg.sliding_window if kind == "sliding" else 0
    cache_leaves, cache_def = jax.tree.flatten(cache)

    def island(q, k, v, block_table, seq_lens, layer, *leaves):
        o, c = _decode_cache_attend(cfg, q, k, v,
                                    jax.tree.unflatten(cache_def, leaves),
                                    block_table, seq_lens, layer, win, rt)
        return (o, *jax.tree.leaves(c))

    if ctx is not None and B % ctx.dp_size == 0 and ctx.dp_size > 1:
        dp = ctx.dp_axes
        # every cache leaf — value pool [L,NB,...] or scale pool [L,NB,KV]
        # — shards over dp on the blocks dim.
        leaf_specs = tuple(P(None, dp) for _ in cache_leaves)
        o, *leaves = shard_map(
            island, mesh=ctx.mesh,
            in_specs=(P(dp), P(dp), P(dp), P(dp), P(dp), P(), *leaf_specs),
            out_specs=(P(dp), *leaf_specs),
            axis_names=set(dp), check_vma=False,
        )(q, k, v, block_table, seq_lens, jnp.asarray(layer, jnp.int32),
          *cache_leaves)
        cache = jax.tree.unflatten(cache_def, leaves)
    else:
        o, cache = _decode_cache_attend(cfg, q, k, v, cache, block_table,
                                        seq_lens, layer, win, rt)
    y = linear(o.reshape(o.shape[0], -1), p["wo"], rt)
    return y, cache


def _decode_cache_attend(cfg, q, k, v, cache: KVCache, block_table,
                         seq_lens, layer, win, rt):
    """Local (per-dp-shard) cache write + attention; block ids are local."""
    if win > 0:
        # ring cache: slot = pos % cache_len; all cached tokens are the most
        # recent ones -> attend over valid slots, mask by window distance
        # via the stored-position trick (DESIGN.md §5). bf16-only: int8 KV
        # is rejected for sliding archs at decode-state construction.
        from repro.core.paged_cache import gather_kv, write_decode_kv
        k_pool, v_pool = cache.k, cache.v
        cache_len = block_table.shape[1] * k_pool.shape[2]
        # inactive slots (seq_len == 0) get position -1 -> write dropped
        ring_pos = jnp.where(seq_lens > 0, (seq_lens - 1) % cache_len, -1)
        k_pool = write_decode_kv(k_pool, layer, k, block_table, ring_pos)
        v_pool = write_decode_kv(v_pool, layer, v, block_table, ring_pos)
        cache = cache._replace(k=k_pool, v=v_pool)
        kc = gather_kv(k_pool, layer, block_table, cache_len)
        vc = gather_kv(v_pool, layer, block_table, cache_len)
        # absolute position of ring slot s for a sequence of length t:
        # pos(s) = t-1 - ((ring_pos - s) mod cache_len)
        s_idx = jnp.arange(cache_len)[None, :]
        kpos = (seq_lens - 1)[:, None] - jnp.mod(ring_pos[:, None] - s_idx,
                                                 cache_len)
        valid = (kpos >= 0) & (kpos > (seq_lens - 1)[:, None] - win)
        if rt.get("skip_mixer_core"):
            o = q * (1 + 1e-30 * (kc.sum() + vc.sum() + valid.sum()))
        else:
            o = _ring_attention(q, kc, vc, valid)
    else:
        cache = kv_write_decode(cache, layer, k, v, block_table, seq_lens - 1)
        if rt.get("skip_mixer_core"):
            o = q * (1 + 1e-30 * seq_lens.sum())
        elif cache.quantized:
            o = ops.paged_attention_quant(
                q, cache.k[layer], cache.k_scale[layer],
                cache.v[layer], cache.v_scale[layer],
                block_table, seq_lens, _slopes(cfg),
                use_pallas=rt.get("use_pallas"),
                interpret=rt.get("interpret"))
        else:
            o = ops.paged_attention(q, cache.k[layer], cache.v[layer],
                                    block_table, seq_lens, _slopes(cfg),
                                    use_pallas=rt.get("use_pallas"),
                                    interpret=rt.get("interpret"))
    return o, cache


def _ring_attention(q, kc, vc, valid):
    """Dense decode attention over a gathered ring cache with a slot mask."""
    B, H, D = q.shape
    KV = kc.shape[2]
    G = H // KV
    scale = D ** -0.5
    qg = q.reshape(B, KV, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, kc.astype(jnp.float32)) * scale
    s = jnp.where(valid[:, None, None, :], s, -0.7 * jnp.finfo(jnp.float32).max)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", w, vc.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)
