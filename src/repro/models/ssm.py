"""Attention-free mixers: Mamba-1 selective SSM and RG-LRU (recurrentgemma).

Both scan over time in remat'd chunks (chunk-boundary carries saved,
in-chunk activations recomputed in backward) so long sequences don't blow
activation memory — the TPU stand-in for the paper's memory-pool thinking
applied to training.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, linear

Params = Dict[str, jnp.ndarray]

CHUNK = 128


def _chunked_time_scan(step, carry, xs_time_major, chunk: int):
    """lax.scan over time in remat'd chunks. xs leaves: [S, ...]."""
    S = jax.tree.leaves(xs_time_major)[0].shape[0]
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        xs_time_major = jax.tree.map(
            lambda a: jnp.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1)),
            xs_time_major)
    n = (S + pad) // c
    xs_c = jax.tree.map(lambda a: a.reshape(n, c, *a.shape[1:]), xs_time_major)

    @jax.checkpoint
    def chunk_body(h, xc):
        return jax.lax.scan(step, h, xc)

    h, ys = jax.lax.scan(chunk_body, carry, xs_c)
    ys = jax.tree.map(lambda a: a.reshape(n * c, *a.shape[2:])[:S], ys)
    return h, ys


# ---------------------------------------------------------------- Mamba-1
def dt_rank(cfg: ModelConfig) -> int:
    return (cfg.d_model + cfg.ssm_state - 1) // cfg.ssm_state


def ssm_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    din = cfg.ssm_expand * d
    N = cfg.ssm_state
    R = dt_rank(cfg)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * din)),
        "conv_w": dense_init(ks[1], (din, cfg.ssm_conv)) * 0.5,
        "conv_b": jnp.zeros((din,)),
        "x_proj": dense_init(ks[2], (din, R + 2 * N)),
        "dt_proj": dense_init(ks[3], (R, din)),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (din,)) *
                    (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001)))),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32),
                                  (din, 1))),
        "D": jnp.ones((din,)),
        "out_proj": dense_init(ks[5], (din, d), in_axis_size=din),
    }


def _ssm_inner(cfg, p, xc, z, h0, mask=None, rt=None):
    """Selective scan. xc: [B,S,din] post-conv, z: gate. Returns (y, h).

    mask: [B,S] — False positions are state-transparent (dt=0)."""
    B, S, din = xc.shape
    N = cfg.ssm_state
    R = dt_rank(cfg)
    dbc = xc @ p["x_proj"].astype(xc.dtype)
    dt_r, b_ssm, c_ssm = jnp.split(dbc, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        dt_r @ p["dt_proj"].astype(xc.dtype)
        + p["dt_bias"].astype(xc.dtype)).astype(jnp.float32)   # [B,S,din]
    if mask is not None:
        dt = jnp.where(mask[..., None], dt, 0.0)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # [din,N]

    if (rt or {}).get("skip_mixer_core"):
        # roofline decomposition lower: the selective scan is replaced by a
        # DCE-proof identity (kernel terms added analytically).
        y = xc * (1 + 1e-30 * (dt.sum() + b_ssm.sum() + c_ssm.sum()
                               + A.sum()))
        y = y + xc * p["D"].astype(xc.dtype)
        return y * jax.nn.silu(z), h0

    xs = (dt.transpose(1, 0, 2), xc.transpose(1, 0, 2).astype(jnp.float32),
          b_ssm.transpose(1, 0, 2).astype(jnp.float32),
          c_ssm.transpose(1, 0, 2).astype(jnp.float32))

    def step(h, x_t):
        dt_t, u_t, b_t, c_t = x_t                              # [B,din],[B,din],[B,N]x2
        da = jnp.exp(dt_t[..., None] * A[None])                # [B,din,N]
        h = da * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    h, ys = _chunked_time_scan(step, h0, xs, CHUNK)
    y = ys.transpose(1, 0, 2).astype(xc.dtype)                 # [B,S,din]
    y = y + xc * p["D"].astype(xc.dtype)
    return y * jax.nn.silu(z), h


def ssm_apply(cfg: ModelConfig, p: Params, x: jnp.ndarray,
              rt: Optional[dict] = None) -> jnp.ndarray:
    """Train/prefill. x: [B,S,d] -> [B,S,d]."""
    B, S, d = x.shape
    din = cfg.ssm_expand * d
    xz = linear(x, p["in_proj"], rt)
    xi, z = jnp.split(xz, 2, axis=-1)
    # depthwise causal conv over time
    W = cfg.ssm_conv
    xp = jnp.pad(xi, ((0, 0), (W - 1, 0), (0, 0)))
    xc = sum(xp[:, i:i + S] * p["conv_w"][:, i].astype(x.dtype)
             for i in range(W)) + p["conv_b"].astype(x.dtype)
    xc = jax.nn.silu(xc)
    h0 = jnp.zeros((B, din, cfg.ssm_state), jnp.float32)
    y, _ = _ssm_inner(cfg, p, xc, z, h0, rt=rt)
    return linear(y, p["out_proj"], rt)


def ssm_prefill(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                mask: jnp.ndarray, ctx_lens: jnp.ndarray,
                rt: Optional[dict] = None):
    """Prefill returning (y, h_final, conv_state).

    Padded positions (mask False) are made state-transparent: dt -> 0 gives
    exp(0*A)=1 and zero input, so h_final is the state at ctx_len.
    """
    B, S, d = x.shape
    din = cfg.ssm_expand * d
    W = cfg.ssm_conv
    xz = linear(x, p["in_proj"], rt)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = jnp.where(mask[..., None], xi, 0)
    xp = jnp.pad(xi, ((0, 0), (W - 1, 0), (0, 0)))
    xc = sum(xp[:, i:i + S] * p["conv_w"][:, i].astype(x.dtype)
             for i in range(W)) + p["conv_b"].astype(x.dtype)
    xc = jax.nn.silu(xc)
    xc = jnp.where(mask[..., None], xc, 0)
    h0 = jnp.zeros((B, din, cfg.ssm_state), jnp.float32)
    y, h = _ssm_inner(cfg, p, xc, z, h0, mask=mask, rt=rt)
    # conv state: the last W-1 (valid) xi values per sequence
    idx = ctx_lens[:, None] - (W - 1) + jnp.arange(W - 1)[None, :]   # [B,W-1]
    valid = idx >= 0
    gathered = jnp.take_along_axis(xi, jnp.maximum(idx, 0)[..., None], axis=1)
    conv_state = jnp.where(valid[..., None], gathered, 0).transpose(0, 2, 1)
    return linear(y, p["out_proj"], rt), h, conv_state


def ssm_decode(cfg: ModelConfig, p: Params, x: jnp.ndarray,
               h: jnp.ndarray, conv_state: jnp.ndarray,
               rt: Optional[dict] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One step. x: [B,d]; h: [B,din,N]; conv_state: [B,din,W-1]."""
    B, d = x.shape
    xz = linear(x, p["in_proj"], rt)
    xi, z = jnp.split(xz, 2, axis=-1)                          # [B,din]
    window = jnp.concatenate([conv_state, xi[..., None]], axis=-1)  # [B,din,W]
    xc = jnp.einsum("bdw,dw->bd", window.astype(jnp.float32),
                    p["conv_w"].astype(jnp.float32))
    xc = jax.nn.silu(xc + p["conv_b"]).astype(x.dtype)
    new_conv = window[..., 1:]
    y3, h = _ssm_inner(cfg, p, xc[:, None, :], z[:, None, :],
                       h.astype(jnp.float32), rt=rt)
    y = linear(y3[:, 0], p["out_proj"], rt)
    return y, h, new_conv.astype(conv_state.dtype)


# ---------------------------------------------------------------- RG-LRU
def rglru_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], (d, w)),
        "w_gate_rec": dense_init(ks[1], (d, w)),
        "conv_w": dense_init(ks[2], (w, 4)) * 0.5,
        "wr": dense_init(ks[3], (w, w)),
        "wi": dense_init(ks[4], (w, w)),
        "a_param": jnp.log(jnp.exp(
            jnp.linspace(0.9, 0.999, w) * 8.0) - 1.0) / 8.0,   # softplus^-1-ish
        "w_out_rec": dense_init(ks[5], (w, d)),
    }


C_RGLRU = 8.0


def _rglru_scan(p, u, h0, mask=None, rt=None):
    """u: [B,S,w] post-conv input. Returns (h_seq [B,S,w], h_last).

    mask: [B,S] — False positions keep the state unchanged (a=1, input=0)."""
    r = jax.nn.sigmoid(u @ p["wr"].astype(u.dtype)).astype(jnp.float32)
    i = jax.nn.sigmoid(u @ p["wi"].astype(u.dtype)).astype(jnp.float32)
    log_a = -C_RGLRU * jax.nn.softplus(p["a_param"].astype(jnp.float32))
    a = jnp.exp(log_a[None, None] * r)                         # [B,S,w]
    gated = (i * u.astype(jnp.float32)) * jnp.sqrt(
        jnp.maximum(1.0 - a * a, 1e-8))
    if mask is not None:
        a = jnp.where(mask[..., None], a, 1.0)
        gated = jnp.where(mask[..., None], gated, 0.0)
    if (rt or {}).get("skip_mixer_core"):
        return gated.astype(u.dtype) * (1 + 1e-30 * a.sum()), h0
    xs = (a.transpose(1, 0, 2), gated.transpose(1, 0, 2))

    def step(h, x_t):
        a_t, g_t = x_t
        h = a_t * h + g_t
        return h, h

    h, hs = _chunked_time_scan(step, h0, xs, CHUNK)
    return hs.transpose(1, 0, 2).astype(u.dtype), h


def rglru_apply(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                rt: Optional[dict] = None) -> jnp.ndarray:
    """Recurrent block: conv -> RG-LRU -> gate -> out. x: [B,S,d]."""
    B, S, d = x.shape
    u = linear(x, p["w_in"], rt)                               # [B,S,w]
    gate = jax.nn.gelu(linear(x, p["w_gate_rec"], rt))
    up = jnp.pad(u, ((0, 0), (3, 0), (0, 0)))
    uc = sum(up[:, i:i + S] * p["conv_w"][:, i].astype(x.dtype)
             for i in range(4))
    h0 = jnp.zeros((B, u.shape[-1]), jnp.float32)
    hs, _ = _rglru_scan(p, uc, h0, rt=rt)
    return linear(hs * gate, p["w_out_rec"], rt)


def rglru_prefill(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                  mask: jnp.ndarray, ctx_lens: jnp.ndarray,
                  rt: Optional[dict] = None):
    """Prefill returning (y, h_final [B,w], conv_state [B,w,3])."""
    B, S, d = x.shape
    u = linear(x, p["w_in"], rt)
    u = jnp.where(mask[..., None], u, 0)
    gate = jax.nn.gelu(linear(x, p["w_gate_rec"], rt))
    up = jnp.pad(u, ((0, 0), (3, 0), (0, 0)))
    uc = sum(up[:, i:i + S] * p["conv_w"][:, i].astype(x.dtype)
             for i in range(4))
    h0 = jnp.zeros((B, u.shape[-1]), jnp.float32)
    hs, h = _rglru_scan(p, uc, h0, mask=mask, rt=rt)
    idx = ctx_lens[:, None] - 3 + jnp.arange(3)[None, :]
    valid = idx >= 0
    gathered = jnp.take_along_axis(u, jnp.maximum(idx, 0)[..., None], axis=1)
    conv_state = jnp.where(valid[..., None], gathered, 0).transpose(0, 2, 1)
    return linear(hs * gate, p["w_out_rec"], rt), h, conv_state


def rglru_decode(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                 h: jnp.ndarray, conv_state: jnp.ndarray,
                 rt: Optional[dict] = None):
    """One step. x: [B,d]; h: [B,w]; conv_state: [B,w,3]."""
    u = linear(x, p["w_in"], rt)                               # [B,w]
    gate = jax.nn.gelu(linear(x, p["w_gate_rec"], rt))
    window = jnp.concatenate([conv_state, u[..., None]], axis=-1)   # [B,w,4]
    uc = jnp.einsum("bwk,wk->bw", window.astype(jnp.float32),
                    p["conv_w"].astype(jnp.float32)).astype(x.dtype)
    hs, h_new = _rglru_scan(p, uc[:, None, :], h.astype(jnp.float32), rt=rt)
    y = linear(hs[:, 0] * gate, p["w_out_rec"], rt)
    return y, h_new, window[..., 1:].astype(conv_state.dtype)
