"""Model-level GPTQ/RTN quantization transforms.

``quantize_params_rtn`` — jittable round-to-nearest int4 pack of every
matmul weight (used for shape-correct dry-runs and as the RTN baseline).

``gptq_quantize_model`` — the real thing: replays the network layer by
layer on calibration data, accumulates per-linear Hessians, and runs the
OBQ loop from ``core/gptq.py``. Dense-family models (the paper quantizes
Llama-3-8B) are supported; the artifact format is identical to RTN's.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, QuantConfig
from repro.core.gptq import HessianAccumulator, gptq_quantize
from repro.core.quant import PACK, make_quant_params

QUANT_TARGETS = {
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
    "ws_gate", "ws_up", "ws_down", "in_proj", "out_proj",
    "w_in", "w_gate_rec", "w_out_rec",
}


def _rtn_pack_2d(w2: jnp.ndarray, group_size: int) -> Dict[str, jnp.ndarray]:
    """jnp RTN int4 pack of one [K, N] weight."""
    K, N = w2.shape
    gs = group_size if (K % group_size == 0 and K >= group_size) else K
    G = K // gs
    wg = w2.reshape(G, gs, N).astype(jnp.float32)
    wmax = jnp.maximum(wg.max(axis=1), 0)
    wmin = jnp.minimum(wg.min(axis=1), 0)
    scale = jnp.where(wmax - wmin > 0, (wmax - wmin) / 15.0, 1.0)
    zero = jnp.round(-wmin / scale)
    q = jnp.clip(jnp.round(wg / scale[:, None] + zero[:, None]), 0, 15)
    q = q.reshape(K, N).astype(jnp.uint32)
    qp = q.reshape(K // PACK, PACK, N)
    shifts = (4 * jnp.arange(PACK, dtype=jnp.uint32))[None, :, None]
    packed = (qp << shifts).sum(axis=1, dtype=jnp.uint32).astype(jnp.int32)
    return {"qweight": packed, "scales": scale, "zeros": zero,
            "g_idx": (jnp.arange(K, dtype=jnp.int32) // gs)}


def _quantize_leaf(w: jnp.ndarray, din: int, group_size: int,
                   n_lead: int = 0):
    """Quantize one weight; ``n_lead`` leading dims (layer stacks) are
    vmapped; the remaining dims split as (din, out) — e.g. stacked wo
    [L, H, Dh, d] with din=H*Dh -> lead (L,), in H*Dh, out d."""
    lead = w.shape[:n_lead]
    rest = w.shape[n_lead:]
    n = 1
    for i, s in enumerate(rest):
        n *= s
        if n == din:
            w2 = w.reshape(*lead, din, -1)
            fn = _rtn_pack_2d
            for _ in range(n_lead):
                fn = jax.vmap(fn, in_axes=(0, None))
            return fn(w2, group_size)
        if n > din:
            break
    raise ValueError(f"cannot split {w.shape} (lead={n_lead}) at din={din}")


def _prod(xs):
    n = 1
    for x in xs:
        n *= x
    return n


def _din_for(name: str, w: jnp.ndarray, cfg: ModelConfig) -> int:
    d, H, KV, Dh = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                    cfg.resolved_head_dim)
    din_ssm = cfg.ssm_expand * d
    w_lru = cfg.lru_width or d
    return {
        "wq": d, "wk": d, "wv": d, "wo": H * Dh,
        "w_gate": d, "w_up": d, "w_down": cfg.d_ff or w.shape[-2],
        "ws_gate": d, "ws_up": d,
        "ws_down": cfg.num_shared_experts * cfg.moe_d_ff,
        "in_proj": d, "out_proj": din_ssm,
        "w_in": d, "w_gate_rec": d, "w_out_rec": w_lru,
    }[name]


def quantize_params_rtn(params: Dict[str, Any], cfg: ModelConfig,
                        group_size: int = 128) -> Dict[str, Any]:
    """Replace every QUANT_TARGETS leaf with its int4 artifact (jnp RTN)."""

    def walk(tree, stacked):
        if not isinstance(tree, dict):
            return tree
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict):
                out[k] = walk(v, stacked or k.endswith("layers"))
            elif k in QUANT_TARGETS:
                din = _din_for(k, v, cfg)
                # w_down for dense mlp: din is d_ff; reduced cfgs override
                if k == "w_down":
                    din = v.shape[-2 if not stacked else -2]
                out[k] = _quantize_leaf(v, din, group_size,
                                        n_lead=1 if stacked else 0)
            else:
                out[k] = v
        return out

    return walk(params, False)


# --------------------------------------------------------------------------
# True GPTQ over calibration data (dense-family models).
# --------------------------------------------------------------------------

def gptq_quantize_model(cfg: ModelConfig, params: Dict[str, Any],
                        calib_batches: List[Dict[str, jnp.ndarray]],
                        qcfg: Optional[QuantConfig] = None) -> Dict[str, Any]:
    """Hessian-weighted GPTQ of a *dense* model's linears.

    Replays layers with a python loop, captures each linear's input
    activations, accumulates H = 2/N Σ xᵀx, then runs the OBQ loop.
    """
    assert cfg.family in ("dense", "vlm", "audio"), "GPTQ path: dense models"
    qcfg = qcfg or cfg.quant or QuantConfig()
    # Replay layers manually, capturing each linear's input activations.
    from repro.models.layers import apply_norm, mlp_apply
    from repro.models.attention import attn_apply
    import repro.models.transformer as T

    hess: Dict[str, HessianAccumulator] = {}

    def acc(path, x, din):
        h = hess.setdefault(path, HessianAccumulator(din))
        h.update(np.asarray(x.reshape(-1, din), np.float32))

    L = cfg.num_layers
    for batch in calib_batches:
        x = T._embed_inputs(cfg, params, batch, None, {})
        for i in range(L):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            kind = cfg.layer_kind(i)
            hn = apply_norm(lp["attn_norm"], x, cfg.norm, cfg.norm_eps)
            acc(f"layers/{i}/attn/wq", hn, cfg.d_model)
            mix = attn_apply(cfg, lp["attn"], hn, None, kind=kind, rt={})
            # wo input: recompute attention output pre-projection is implicit
            x = x + mix
            hn = apply_norm(lp["mlp_norm"], x, cfg.norm, cfg.norm_eps)
            acc(f"layers/{i}/mlp/w_gate", hn, cfg.d_model)
            y = mlp_apply(lp["mlp"], hn, cfg.act, {})
            x = x + y

    # 2. quantize: weights sharing an input share its Hessian (wq/wk/wv;
    # w_gate/w_up); others (wo, w_down) fall back to RTN-with-identity-H.
    def qt_of(w, h, din):
        w2 = np.asarray(w.reshape(din, -1), np.float64)
        return make_quant_params(gptq_quantize(w2, h, qcfg))

    new_layers = []
    for i in range(L):
        lp = jax.tree.map(lambda a: a[i], params["layers"])
        h_attn = hess[f"layers/{i}/attn/wq"].h
        h_mlp = hess[f"layers/{i}/mlp/w_gate"].h
        d = cfg.d_model
        nlp = jax.tree.map(lambda a: a, lp)
        nlp["attn"] = dict(lp["attn"])
        for nm in ("wq", "wk", "wv"):
            nlp["attn"][nm] = qt_of(lp["attn"][nm], h_attn, d)
        nlp["attn"]["wo"] = qt_of(lp["attn"]["wo"], None,
                                  cfg.num_heads * cfg.resolved_head_dim)
        nlp["mlp"] = dict(lp["mlp"])
        for nm in ("w_gate", "w_up"):
            if nm in lp["mlp"]:
                nlp["mlp"][nm] = qt_of(lp["mlp"][nm], h_mlp, d)
        nlp["mlp"]["w_down"] = qt_of(lp["mlp"]["w_down"], None,
                                     lp["mlp"]["w_down"].shape[0])
        new_layers.append(nlp)

    out = dict(params)
    out["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *new_layers)
    return out
