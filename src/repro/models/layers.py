"""Building blocks shared by all model families (pure functions, dict params)."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------- init utils
def dense_init(key, shape, in_axis_size: Optional[int] = None, dtype=jnp.float32):
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    return (jax.random.normal(key, shape) * (fan_in ** -0.5)).astype(dtype)


# ---------------------------------------------------------------- norms
def norm_init(d: int, kind: str) -> Params:
    p = {"w": jnp.ones((d,))}
    if kind == "layernorm":
        p["b"] = jnp.zeros((d,))
    return p


def apply_norm(p: Params, x: jnp.ndarray, kind: str, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (xf * p["w"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf * p["w"] + p["b"]).astype(x.dtype)


# ---------------------------------------------------------------- RoPE
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, S, H, D]; positions: [S] or [B, S]."""
    d = x.shape[-1]
    d2 = d // 2
    freqs = theta ** (-jnp.arange(0, d2, dtype=jnp.float32) / d2)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs      # [B,S,D/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :d2], x[..., d2:2 * d2]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    if 2 * d2 < d:                                              # odd head_dim tail
        rot = jnp.concatenate([rot, x[..., 2 * d2:]], -1)
    return rot.astype(x.dtype)


# ---------------------------------------------------------------- activations
def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------- dense MLP
def mlp_init(key, d: int, f: int, act: str) -> Params:
    ks = jax.random.split(key, 3)
    if act in ("silu", "swiglu"):
        return {"w_gate": dense_init(ks[0], (d, f)),
                "w_up": dense_init(ks[1], (d, f)),
                "w_down": dense_init(ks[2], (f, d), in_axis_size=f)}
    return {"w_up": dense_init(ks[1], (d, f)),
            "w_down": dense_init(ks[2], (f, d), in_axis_size=f)}


def mlp_apply(p: Params, x: jnp.ndarray, act: str,
              rt: Optional[dict] = None) -> jnp.ndarray:
    if "w_gate" in p:
        g = linear(x, p["w_gate"], rt)
        u = linear(x, p["w_up"], rt)
        return linear(act_fn(act)(g) * u, p["w_down"], rt)
    u = act_fn(act)(linear(x, p["w_up"], rt))
    return linear(u, p["w_down"], rt)


# ---------------------------------------------------------------- linear
def linear(x: jnp.ndarray, w, rt: Optional[dict] = None,
           out_tail: Optional[tuple] = None) -> jnp.ndarray:
    """x: [..., din] @ w.

    ``w`` is either a dense array whose leading dims multiply to din
    (e.g. wq [d, H, Dh] or wo [H, Dh, d]) or a GPTQ quant dict
    {qweight, scales, zeros, g_idx} (int4 path, paper §III) — then
    ``out_tail`` gives the logical output shape tail if non-2D.
    """
    din = x.shape[-1]
    if isinstance(w, dict):
        from repro.kernels.ops import quant_matmul
        rt = rt or {}
        y = quant_matmul(x, w, use_pallas=rt.get("use_pallas"),
                         interpret=rt.get("interpret"), ctx=rt.get("ctx"))
    else:
        # split w dims into (input dims, output dims) at din
        n, i = 1, 0
        while n < din and i < w.ndim:
            n *= w.shape[i]
            i += 1
        assert n == din, (w.shape, din)
        out_tail = out_tail or w.shape[i:]
        y = x @ w.reshape(din, -1).astype(x.dtype)
    if out_tail is not None and len(out_tail) > 1:
        y = y.reshape(*y.shape[:-1], *out_tail)
    return y


# ---------------------------------------------------------------- embedding
def embed_init(key, vocab: int, d: int) -> jnp.ndarray:
    return jax.random.normal(key, (vocab, d)) * 0.02


def unembed(x: jnp.ndarray, embed: jnp.ndarray,
            head: Optional[jnp.ndarray]) -> jnp.ndarray:
    if head is not None:
        return x @ head.astype(x.dtype)
    return x @ embed.T.astype(x.dtype)
