"""Model-level entry points: step functions + ShapeDtypeStruct input specs
for every (architecture × shape) dry-run cell."""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as T

I32 = jnp.int32


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def decode_geometry(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, int]:
    """Pool sizing for a decode cell."""
    bs = cfg.paging.block_size
    B = shape.global_batch
    if cfg.sliding_window and cfg.layer_kind(0) != "ssm":
        # ring cache: one block-aligned window (+1 block) per sequence
        mb = cfg.sliding_window // bs + 1
    else:
        mb = math.ceil(shape.seq_len / bs)
    # hybrid/dense full-attn archs without sliding: full-length table
    has_full = any(cfg.layer_kind(i) == "full" for i in range(cfg.num_layers))
    if has_full:
        mb = math.ceil(shape.seq_len / bs)
    nb = B * mb
    return {"block_size": bs, "max_blocks_per_seq": mb, "num_blocks": nb,
            "max_seqs": B}


def decode_state_specs(cfg: ModelConfig, shape: ShapeConfig,
                       dtype=None, kv_cache_dtype=None) -> Dict[str, Any]:
    """Decode-state ShapeDtypeStructs for a dry-run cell — derived from
    ``make_decode_state`` via eval_shape (no allocation) so the spec layer
    can never diverge from the real state layout, including the int8
    pool format and its sliding-window rejection."""
    g = decode_geometry(cfg, shape)
    return jax.eval_shape(
        lambda: T.make_decode_state(cfg, g["max_seqs"], g["num_blocks"],
                                    g["max_blocks_per_seq"], dtype=dtype,
                                    kv_cache_dtype=kv_cache_dtype))


def chunk_prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig,
                              chunk_tokens: int) -> Dict[str, Any]:
    """ShapeDtypeStructs for the serving chunk-prefill executable: one
    sequence, a fixed ``[1, chunk_tokens]`` token window and scalar
    offsets — the shape that compiles once regardless of prompt length
    (``max_num_batched_tokens`` on the serving engine)."""
    g = decode_geometry(cfg, shape)
    return {"tokens": sds((1, min(chunk_tokens,
                                  g["max_blocks_per_seq"]
                                  * g["block_size"])), I32),
            "block_table": sds((1, g["max_blocks_per_seq"]), I32),
            "pos_offset": sds((), I32),
            "total_len": sds((), I32)}


def make_chunk_prefill_step(cfg: ModelConfig, ctx=None, rt=None):
    def step(params, cache, batch):
        return T.prefill_chunk(cfg, params, cache, batch["tokens"],
                               batch["block_table"], batch["pos_offset"],
                               batch["total_len"], ctx, rt)
    return step


def unified_step_input_specs(cfg: ModelConfig, shape: ShapeConfig,
                             chunk_tokens: int) -> Dict[str, Any]:
    """ShapeDtypeStructs for the unified single-dispatch serving step:
    the decode cell's state + per-slot tokens, the ``[1, W]`` chunk
    window with its scalar offsets, and the ``[B + 1]`` sampling rows
    (decode slots + the chunk row) — the one executable a mixed engine
    iteration dispatches."""
    g = decode_geometry(cfg, shape)
    B = g["max_seqs"]
    W = min(chunk_tokens, g["max_blocks_per_seq"] * g["block_size"])
    return {"state": decode_state_specs(cfg, shape),
            "tokens": sds((B,), I32),
            "sampling": {"keys": sds((B + 1, 2), jnp.uint32),
                         "counts": sds((B + 1,), I32),
                         "temps": sds((B + 1,), jnp.float32),
                         "top_ks": sds((B + 1,), I32),
                         "top_ps": sds((B + 1,), jnp.float32)},
            "active": sds((B,), jnp.bool_),
            "chunk_tokens": sds((1, W), I32),
            "chunk_block_table": sds((1, g["max_blocks_per_seq"]), I32),
            "pos_offset": sds((), I32),
            "total_len": sds((), I32)}


def make_unified_step(cfg: ModelConfig, ctx=None, rt=None):
    def step(params, state, batch):
        return T.unified_step(cfg, params, state, batch["tokens"],
                              batch["sampling"], batch["active"],
                              batch["chunk_tokens"],
                              batch["chunk_block_table"],
                              batch["pos_offset"], batch["total_len"],
                              ctx, rt)
    return step


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the step function's data arguments."""
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    if shape.kind == "train":
        if cfg.is_encoder:
            return {"frames": sds((B, S, d), jnp.bfloat16),
                    "labels": sds((B, S), I32)}
        batch: Dict[str, Any] = {"tokens": sds((B, S + 1), I32)}
        if cfg.frontend == "vision_patches":
            batch["vision_embeds"] = sds((B, cfg.num_prefix_embeds, d),
                                         jnp.bfloat16)
        return batch
    if shape.kind == "prefill":
        if cfg.is_encoder:
            return {"frames": sds((B, S, d), jnp.bfloat16)}
        batch = {"tokens": sds((B, S), I32), "ctx_lens": sds((B,), I32)}
        if cfg.frontend == "vision_patches":
            batch["vision_embeds"] = sds((B, cfg.num_prefix_embeds, d),
                                         jnp.bfloat16)
        return batch
    # decode
    return {"tokens": sds((B,), I32), "state": decode_state_specs(cfg, shape)}


def param_specs(cfg: ModelConfig, ep: int = 1,
                dtype=jnp.float32) -> Any:
    """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
    return jax.eval_shape(
        lambda k: T.init_params(cfg, k, ep), jax.random.PRNGKey(0))


# ---------------------------------------------------------------- steps
def make_forward_step(cfg: ModelConfig, ctx=None, rt=None):
    def step(params, batch):
        return T.forward(cfg, params, batch, ctx, rt)
    return step


def make_loss_step(cfg: ModelConfig, ctx=None, rt=None):
    def step(params, batch):
        return T.loss_fn(cfg, params, batch, ctx, rt)
    return step


def make_prefill_step(cfg: ModelConfig, ctx=None, rt=None):
    def step(params, state, batch):
        return T.prefill(cfg, params, state, batch, ctx, rt)
    return step


def make_decode_step(cfg: ModelConfig, ctx=None, rt=None):
    def step(params, state, tokens):
        return T.decode_step(cfg, params, state, tokens, ctx, rt)
    return step
