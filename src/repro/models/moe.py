"""Mixture-of-Experts FFN with expert parallelism.

Dropless-ish EP without all-to-all (DESIGN.md §4): tokens stay on their
data shard (activations are replicated across the model axis between TP
ops anyway); every model shard computes the contributions of its *local*
experts for all local tokens via ``jax.lax.ragged_dot`` after a sort-by-
expert, then a psum over the model axis combines. Trash assignments
(non-local experts) are sorted to the back and dropped by a capacity cut.

Shared experts are merged into one wide MLP (sum of SwiGLU experts ==
concatenated-hidden SwiGLU) and TP-sharded on the hidden dim; the same
psum combines them.

Experts are zero-padded to a multiple of the EP axis (60 -> 64 for
qwen2-moe); the router only ever produces logits for real experts.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import act_fn, dense_init
from repro.runtime.sharding import ParallelCtx, shard_map

Params = Dict[str, jnp.ndarray]

CAPACITY_FACTOR = 2.0


def padded_experts(cfg: ModelConfig, ep: int = 1) -> int:
    e = cfg.num_experts
    return ((e + ep - 1) // ep) * ep


def moe_init(key, cfg: ModelConfig, ep: int = 1) -> Params:
    d, f = cfg.d_model, cfg.moe_d_ff
    e_pad = padded_experts(cfg, ep)
    fs = cfg.num_shared_experts * cfg.moe_d_ff
    ks = jax.random.split(key, 8)
    p = {
        "router": dense_init(ks[0], (d, cfg.num_experts)),
        "we_gate": dense_init(ks[1], (e_pad, d, f), in_axis_size=d),
        "we_up": dense_init(ks[2], (e_pad, d, f), in_axis_size=d),
        "we_down": dense_init(ks[3], (e_pad, f, d), in_axis_size=f),
    }
    if fs:
        p.update({
            "ws_gate": dense_init(ks[4], (d, fs)),
            "ws_up": dense_init(ks[5], (d, fs)),
            "ws_down": dense_init(ks[6], (fs, d), in_axis_size=fs),
        })
    return p


def _routed_local(cfg: ModelConfig, p: Params, x2: jnp.ndarray,
                  e0: int, e_local: int, capacity: int,
                  rt: Optional[dict] = None) -> jnp.ndarray:
    """Routed-expert contribution of experts [e0, e0+e_local) for tokens x2.

    x2: [T, d]. Returns [T, d] partial output (sum over local experts).
    Under shard_map, p's expert weights are already the local shard
    [e_local, d, f]; e0 (possibly a traced axis_index) only selects which
    assignment ids are local.
    """
    T, d = x2.shape
    k = cfg.moe_top_k
    logits = (x2 @ p["router"].astype(x2.dtype)).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, k)                   # [T, k]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    ids = top_ids.reshape(-1)                                  # [T*k]
    w = top_w.reshape(-1).astype(x2.dtype)
    tok = jnp.arange(T * k) // k
    local = (ids >= e0) & (ids < e0 + e_local)
    # composite key: (local expert id, trash flag) — real rows of an expert
    # sort before trash rows so the capacity cut drops trash first.
    key = jnp.where(local, (ids - e0) * 2, (e_local - 1) * 2 + 1)
    order = jnp.argsort(key)
    key_s, tok_s, w_s = key[order], tok[order], w[order]
    keep = min(capacity, T * k)
    key_c, tok_c = key_s[:keep], tok_s[:keep]
    w_c = jnp.where(key_c % 2 == 0, w_s[:keep], 0.0)           # zero trash
    gs = jnp.bincount(key_c // 2, length=e_local)              # group sizes

    xs = x2[tok_c]                                             # [C, d]
    assert p["we_gate"].shape[0] == e_local, (p["we_gate"].shape, e_local)
    wg = p["we_gate"].astype(x2.dtype)
    wu = p["we_up"].astype(x2.dtype)
    wd = p["we_down"].astype(x2.dtype)
    if (rt or {}).get("skip_mixer_core"):
        # roofline decomposition lower: XLA cost-counts ragged_dot as a
        # DENSE per-group contraction (e_local x overcount), so the grouped
        # matmuls are skipped here and added analytically (mixer_terms).
        rows = xs * (1 + 1e-30 * (wg.sum() + wu.sum() + wd.sum()
                                  + gs.sum()))
    else:
        g = jax.lax.ragged_dot(xs, wg, gs)
        u = jax.lax.ragged_dot(xs, wu, gs)
        rows = jax.lax.ragged_dot(act_fn(cfg.act)(g) * u, wd, gs)  # [C, d]
    y = jnp.zeros_like(x2)
    return y.at[tok_c].add(rows * w_c[:, None])


def _shared_local(cfg: ModelConfig, p: Params, x2: jnp.ndarray) -> jnp.ndarray:
    g = x2 @ p["ws_gate"].astype(x2.dtype)
    u = x2 @ p["ws_up"].astype(x2.dtype)
    return (act_fn(cfg.act)(g) * u) @ p["ws_down"].astype(x2.dtype)


def _capacity(cfg: ModelConfig, tokens: int, e_local: int, e_pad: int) -> int:
    c = int(tokens * cfg.moe_top_k * e_local / e_pad * CAPACITY_FACTOR)
    return max(8, min((c + 7) // 8 * 8, tokens * cfg.moe_top_k))


def moe_apply(cfg: ModelConfig, p: Params, x: jnp.ndarray,
              ctx: Optional[ParallelCtx], rt: Optional[dict] = None
              ) -> jnp.ndarray:
    """x: [B, S, d] -> [B, S, d]."""
    B, S, d = x.shape
    e_pad = p["we_gate"].shape[0]

    if ctx is None or ctx.tp_axis is None:   # single device / dp_only policy
        x2 = x.reshape(-1, d)
        y = _routed_local(cfg, p, x2, 0, e_pad,
                          capacity=x2.shape[0] * cfg.moe_top_k, rt=rt)
        if "ws_gate" in p:
            y = y + _shared_local(cfg, p, x2)
        return y.reshape(B, S, d)

    mesh = ctx.mesh
    tp = ctx.tp_axis
    dp = ctx.dp_axes
    tpn = ctx.tp_size
    ep = tpn if e_pad % tpn == 0 else 1
    e_local = e_pad // ep
    t_local = (B // ctx.dp_size) * S
    cap = _capacity(cfg, t_local, e_local, e_pad)

    espec = P(tp, None, None) if ep > 1 else P(None, None, None)
    fspec_in = P(None, tp)
    fspec_out = P(tp, None)
    in_specs = {"router": P(None, None),
                "we_gate": espec, "we_up": espec, "we_down": espec}
    if "ws_gate" in p:
        in_specs.update({"ws_gate": fspec_in, "ws_up": fspec_in,
                         "ws_down": fspec_out})

    def f(xl, pl):
        x2 = xl.reshape(-1, d)
        if ep > 1:
            e0 = jax.lax.axis_index(tp) * e_local
        else:
            e0 = 0
        y = _routed_local(cfg, pl, x2, e0, e_local, cap, rt=rt)
        if "ws_gate" in pl:
            y = y + _shared_local(cfg, pl, x2)
        y = jax.lax.psum(y, tp)
        return y.reshape(xl.shape)

    return shard_map(
        f, mesh=mesh,
        in_specs=(P(dp, None, None), in_specs),
        out_specs=P(dp, None, None),
        check_vma=False,
    )(x, {k: p[k] for k in in_specs})


def moe_apply_dense_ref(cfg: ModelConfig, p: Params, x: jnp.ndarray
                        ) -> jnp.ndarray:
    """O(E) dense loop oracle for tests."""
    B, S, d = x.shape
    x2 = x.reshape(-1, d)
    logits = (x2 @ p["router"].astype(x2.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    top_w, top_ids = jax.lax.top_k(probs, cfg.moe_top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    y = jnp.zeros_like(x2)
    for e in range(cfg.num_experts):
        g = x2 @ p["we_gate"][e].astype(x2.dtype)
        u = x2 @ p["we_up"][e].astype(x2.dtype)
        o = (act_fn(cfg.act)(g) * u) @ p["we_down"][e].astype(x2.dtype)
        w_e = jnp.where(top_ids == e, top_w, 0.0).sum(-1).astype(x2.dtype)
        y = y + o * w_e[:, None]
    if "ws_gate" in p:
        y = y + _shared_local(cfg, p, x2)
    return y.reshape(B, S, d)
