"""Pallas TPU fused dequant-matmul for GPTQ int4 weights (W4A16).

TPU adaptation of the paper's quantized-linear DCU kernel:

* Packed weights stay int32 in HBM (4.0 bits/weight moved — the memory-
  bound decode matmul speeds up by ~4x over bf16 weight traffic).
* The k-tile equals the GPTQ group_size, so each grid step touches exactly
  one (scale, zero) row — no gather on g_idx inside the kernel (GPTQ
  act_order keeps groups contiguous in the original column order).
* Unpack = shift/mask in VREGs -> bf16/f32 tile -> MXU matmul; f32
  accumulator in VMEM scratch across k-tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

PACK = 8


def _gptq_mm_kernel(x_ref, qw_ref, s_ref, z_ref, o_ref, acc_ref, *,
                    nk: int, group_size: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)                  # [Tm, Tk]
    qw = qw_ref[...]                                    # [Tk//8, Tn] int32
    # unpack nibbles: [Tk//8, 8, Tn] -> [Tk, Tn]
    shifts = (4 * jax.lax.broadcasted_iota(jnp.uint32, (1, PACK, 1), 1))
    codes = (qw.astype(jnp.uint32)[:, None, :] >> shifts) & 0xF
    codes = codes.reshape(group_size, -1).astype(jnp.float32)
    w = (codes - z_ref[0][None, :]) * s_ref[0][None, :]  # [Tk, Tn] dequant
    acc_ref[...] += jax.lax.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _final():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def gptq_matmul(
    x: jnp.ndarray,            # [M, K] activations
    qweight: jnp.ndarray,      # [K//8, N] int32 packed codes
    scales: jnp.ndarray,       # [K//group_size, N] f32
    zeros: jnp.ndarray,        # [K//group_size, N] f32
    *,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    M, K = x.shape
    N = qweight.shape[1]
    n_groups = scales.shape[0]
    assert K % n_groups == 0
    group_size = K // n_groups
    assert group_size % PACK == 0
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    pm, pn = (-M) % block_m, (-N) % block_n
    xp = jnp.pad(x, ((0, pm), (0, 0)))
    qwp = jnp.pad(qweight, ((0, 0), (0, pn)))
    sp = jnp.pad(scales, ((0, 0), (0, pn)))
    zp = jnp.pad(zeros, ((0, 0), (0, pn)))
    nm, nn, nk = (M + pm) // block_m, (N + pn) // block_n, n_groups

    out = pl.pallas_call(
        functools.partial(_gptq_mm_kernel, nk=nk, group_size=group_size),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((block_m, group_size), lambda m, n, k: (m, k)),
            pl.BlockSpec((group_size // PACK, block_n), lambda m, n, k: (k, n)),
            pl.BlockSpec((1, block_n), lambda m, n, k: (k, n)),
            pl.BlockSpec((1, block_n), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda m, n, k: (m, n)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((M + pm, N + pn), x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xp, qwp, sp, zp)
    return out[:M, :N]
