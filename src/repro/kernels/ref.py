"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the *semantic* definitions; kernels must match them to
``assert_allclose`` tolerance across the test shape/dtype sweep. They are
also the path the multi-pod dry-run lowers (Pallas TPU kernels cannot
lower on the CPU backend — see DESIGN.md §3).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp

from repro.core.gqa import decode_attention, grouped_attention
from repro.core.paged_cache import gather_kv
from repro.core.quant import quant_matmul_ref as _qmm


def flash_attention_ref(q, k, v, *, causal=True, sliding_window=0,
                        alibi_slopes=None, q_offset=0, segment_ids=None):
    """[B,S,H,D] x [B,S,KV,D]^2 -> [B,S,H,D]; O(S^2) reference."""
    del segment_ids
    return grouped_attention(q, k, v, causal=causal,
                             sliding_window=sliding_window,
                             alibi_slopes=alibi_slopes, q_offset=q_offset)


def paged_attention_ref(q, k_pool, v_pool, block_table, seq_lens, *,
                        alibi_slopes=None, sliding_window=0):
    """Decode attention over the paged pool.

    q: [B, H, D]; k_pool/v_pool: [NB, BS, KV, D] (single layer's pool);
    block_table: [B, MB]; seq_lens: [B].
    """
    bs = k_pool.shape[1]
    max_len = block_table.shape[1] * bs
    kc = gather_kv(k_pool[None], 0, block_table, max_len)
    vc = gather_kv(v_pool[None], 0, block_table, max_len)
    return decode_attention(q, kc, vc, seq_lens, alibi_slopes=alibi_slopes,
                            sliding_window=sliding_window)


def paged_attention_quant_ref(q, k_values, k_scales, v_values, v_scales,
                              block_table, seq_lens, *,
                              alibi_slopes=None, sliding_window=0):
    """Decode attention over the int8 paged pool: dequantize the gathered
    pages (per-block-per-head scales), then the same contiguous oracle.

    q: [B, H, D]; k_values/v_values: [NB, BS, KV, D] int8 (single layer);
    k_scales/v_scales: [NB, KV] f32; block_table: [B, MB]; seq_lens: [B].
    """
    from repro.core.kv_quant import gather_kv_quant
    bs = k_values.shape[1]
    max_len = block_table.shape[1] * bs
    kc = gather_kv_quant(k_values[None], k_scales[None], 0, block_table,
                         max_len)
    vc = gather_kv_quant(v_values[None], v_scales[None], 0, block_table,
                         max_len)
    return decode_attention(q, kc, vc, seq_lens, alibi_slopes=alibi_slopes,
                            sliding_window=sliding_window)


def quant_matmul_ref(x: jnp.ndarray, params: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """W4A16 matmul oracle: dequantize then matmul."""
    return _qmm(x, params)
