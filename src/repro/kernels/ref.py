"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the *semantic* definitions; kernels must match them to
``assert_allclose`` tolerance across the test shape/dtype sweep. They are
also the path the multi-pod dry-run lowers (Pallas TPU kernels cannot
lower on the CPU backend — see DESIGN.md §3).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.gqa import decode_attention, grouped_attention
from repro.core.paged_cache import gather_kv
from repro.core.quant import quant_matmul_ref as _qmm


def flash_attention_ref(q, k, v, *, causal=True, sliding_window=0,
                        alibi_slopes=None, q_offset=0, segment_ids=None):
    """[B,S,H,D] x [B,S,KV,D]^2 -> [B,S,H,D]; O(S^2) reference."""
    del segment_ids
    return grouped_attention(q, k, v, causal=causal,
                             sliding_window=sliding_window,
                             alibi_slopes=alibi_slopes, q_offset=q_offset)


def paged_attention_ref(q, k_pool, v_pool, block_table, seq_lens, *,
                        alibi_slopes=None, sliding_window=0):
    """Decode attention over the paged pool.

    q: [B, H, D]; k_pool/v_pool: [NB, BS, KV, D] (single layer's pool);
    block_table: [B, MB]; seq_lens: [B].
    """
    bs = k_pool.shape[1]
    max_len = block_table.shape[1] * bs
    kc = gather_kv(k_pool[None], 0, block_table, max_len)
    vc = gather_kv(v_pool[None], 0, block_table, max_len)
    return decode_attention(q, kc, vc, seq_lens, alibi_slopes=alibi_slopes,
                            sliding_window=sliding_window)


def paged_attention_quant_ref(q, k_values, k_scales, v_values, v_scales,
                              block_table, seq_lens, *,
                              alibi_slopes=None, sliding_window=0):
    """Decode attention over the int8 paged pool: dequantize the gathered
    pages (per-block-per-head scales), then the same contiguous oracle.

    q: [B, H, D]; k_values/v_values: [NB, BS, KV, D] int8 (single layer);
    k_scales/v_scales: [NB, KV] f32; block_table: [B, MB]; seq_lens: [B].
    """
    from repro.core.kv_quant import gather_kv_quant
    bs = k_values.shape[1]
    max_len = block_table.shape[1] * bs
    kc = gather_kv_quant(k_values[None], k_scales[None], 0, block_table,
                         max_len)
    vc = gather_kv_quant(v_values[None], v_scales[None], 0, block_table,
                         max_len)
    return decode_attention(q, kc, vc, seq_lens, alibi_slopes=alibi_slopes,
                            sliding_window=sliding_window)


def chunk_prefill_attention_ref(q, k_pool, v_pool, k_scales, v_scales,
                                layer, block_table, q_offset, total_len,
                                k_raw, v_raw, *, alibi_slopes=None,
                                sliding_window=0):
    """Chunk-prefill attention over the paged pool (XLA oracle).

    The semantic definition of ``flash_attention_chunk``: gather the
    pool's live pages (a *bounded* walk — ``ceil(total_len / BS)`` page
    reads via ``kv_gather_bounded``, never the table capacity), overlay
    the chunk's own raw K/V at ``[q_offset, q_offset + W)`` so the chunk
    never sees itself pool-roundtripped (int8 parity), then the O(S^2)
    grouped-attention reference with the traced ``q_offset`` driving the
    causal mask.  This is also the lowering the serving engine runs off
    TPU and the multi-pod dry-run compiles.

    q: [1, W, H, D]; k_pool/v_pool: [L, NB, BS, KV, D] (int8 when scales
    are given, with k_scales/v_scales [L, NB, KV] f32); layer: traced
    index; block_table: [1, MB]; q_offset/total_len: traced i32 scalars;
    k_raw/v_raw: [1, W, KV, D].
    """
    from repro.core.kv_quant import KVCache, kv_gather_bounded
    cache = KVCache(k_pool, v_pool, k_scales, v_scales)
    bs = cache.block_size
    cap = block_table.shape[1] * bs
    W = q.shape[1]
    live = (jnp.asarray(total_len, jnp.int32) + bs - 1) // bs
    kc, vc = kv_gather_bounded(cache, layer, block_table, cap, live,
                               q.dtype)
    # raw overlay: the W-row scratch tail keeps the dynamic write from
    # clamping when a chunk ends at capacity (same trick as the serving
    # chunk executable always used).
    scratch = jnp.zeros((1, W) + kc.shape[2:], kc.dtype)
    kc = jax.lax.dynamic_update_slice(
        jnp.concatenate([kc, scratch], 1), k_raw.astype(kc.dtype),
        (0, q_offset, 0, 0))[:, :cap]
    vc = jax.lax.dynamic_update_slice(
        jnp.concatenate([vc, scratch], 1), v_raw.astype(vc.dtype),
        (0, q_offset, 0, 0))[:, :cap]
    return grouped_attention(q, kc, vc, causal=True,
                             sliding_window=sliding_window,
                             alibi_slopes=alibi_slopes, q_offset=q_offset)


def quant_matmul_ref(x: jnp.ndarray, params: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """W4A16 matmul oracle: dequantize then matmul."""
    return _qmm(x, params)
