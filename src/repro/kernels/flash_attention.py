"""Pallas TPU flash-attention kernel for Opt-GQA prefill.

Adaptation of the paper's DCU attention kernel to TPU (DESIGN.md §3):

* Q is laid out as [B, KV, G, S, D] (G = q_per_kv): the grid iterates over
  *KV heads*, and each K/V tile loaded into VMEM is contracted against all
  G query heads of its group at once — the paper's "shared key-value"
  becomes a batched MXU matmul with G× higher arithmetic intensity.
* ALiBi bias is computed from iota inside the tile (never a [S,S] mask).
* Causal / sliding-window tiles that are fully masked are *skipped*
  (pl.when) — the sparse-attention half of the paper's title.
* Online softmax (flash) with f32 accumulators in VMEM scratch.

Tile sizes default to MXU-aligned (128) in S and D.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _fa_kernel(slopes_ref, q_ref, k_ref, v_ref, o_ref,
               acc_ref, m_ref, l_ref, *,
               block_q: int, block_k: int, causal: bool,
               sliding_window: int, use_alibi: bool, q_offset: int,
               num_k_blocks: int, seq_len_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    dist = q_pos - k_pos

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # [G, Tq, D]
        k = k_ref[0, 0].astype(jnp.float32)               # [Tk, D]
        v = v_ref[0, 0].astype(jnp.float32)               # [Tk, D]
        scale = q.shape[-1] ** -0.5
        s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        # s: [G, Tq, Tk]
        if use_alibi:
            slopes = slopes_ref[0].astype(jnp.float32)     # [G]
            s = s - slopes[:, None, None] * jnp.maximum(dist, 0)[None].astype(jnp.float32)
        mask = k_pos < seq_len_k
        if causal:
            mask &= dist >= 0
        if sliding_window > 0:
            mask &= dist < sliding_window
        s = jnp.where(mask[None], s, NEG_INF)

        m_prev = m_ref[...]                               # [G, Tq]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])                  # [G, Tq, Tk]
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + pv

    if causal or sliding_window > 0:
        # tile-skip: live iff some (q,k) in tile satisfies the band.
        q_hi = q_offset + (iq + 1) * block_q - 1
        q_lo = q_offset + iq * block_q
        k_lo = ik * block_k
        k_hi = (ik + 1) * block_k - 1
        live = True
        if causal:
            live = jnp.logical_and(live, k_lo <= q_hi)
        if sliding_window > 0:
            live = jnp.logical_and(live, k_hi > q_lo - sliding_window)
        pl.when(live)(_compute)
    else:
        _compute()

    @pl.when(ik == num_k_blocks - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def _fa_chunk_kernel(block_table_ref, info_ref,      # scalar prefetch (SMEM)
                     slopes_ref, q_ref, *refs,
                     block_q: int, block_size: int, num_pool_blocks: int,
                     num_raw_blocks: int, use_alibi: bool,
                     sliding_window: int, quantized: bool):
    """Dynamic-offset chunk-prefill flash body (one sequence).

    The K axis of the grid walks TWO sources: the first
    ``num_pool_blocks`` steps are paged-pool pages holding the already-
    prefilled prefix ``[0, q_offset)`` (physical page ids resolved from
    the prefetched block table, exactly like ``paged_attention.py``), the
    remaining ``num_raw_blocks`` steps are the chunk's own raw K/V tiles
    at absolute positions ``[q_offset, q_offset + W)`` — the chunk
    attends its own tokens unquantized / un-roundtripped, matching the
    whole-prompt prefill semantics (and keeping int8 parity).

    ``info_ref`` holds the two *traced* scalars ``[q_offset, total_len]``
    — the causal mask, ALiBi distances and the live-page clamp are all
    computed from them, so every chunk of every prompt runs from one
    compiled executable.  ``quantized`` reuses the in-register dequant
    of ``paged_attention_quant.py``: pool tiles are int8 with one f32
    scale per (page, kv head); raw tiles are always full precision.
    """
    if quantized:
        (kp_ref, ks_ref, vp_ref, vs_ref, kr_ref, vr_ref,
         o_ref, acc_ref, m_ref, l_ref) = refs
    else:
        kp_ref, vp_ref, kr_ref, vr_ref, o_ref, acc_ref, m_ref, l_ref = refs
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    q_off = info_ref[0]
    tlen = info_ref[1]

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = q_off + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_size), 0)

    def _accum(k, v, k_pos, mask):
        q = q_ref[0].astype(jnp.float32)                   # [G, Tq, D]
        scale = q.shape[-1] ** -0.5
        s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        dist = q_pos - k_pos                               # [Tq, Tk]
        if use_alibi:
            slopes = slopes_ref[0].astype(jnp.float32)     # [G]
            s = s - slopes[:, None, None] \
                * jnp.maximum(dist, 0)[None].astype(jnp.float32)
        if sliding_window > 0:
            mask &= dist < sliding_window
        s = jnp.where(mask[None], s, NEG_INF)
        m_prev = m_ref[...]                                # [G, Tq]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])                  # [G, Tq, Tk]
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + pv

    # ---- pool pages: the prefix [0, q_offset). Pages past the prefix
    # are skipped (their DMA re-resolved to the last live page, compute
    # gated off) — the HBM walk is ceil(q_offset / block_size), never
    # the static table capacity.
    def _pool():
        k = kp_ref[0, :, 0, :].astype(jnp.float32)         # [BS, D]
        v = vp_ref[0, :, 0, :].astype(jnp.float32)
        if quantized:
            k = k * ks_ref[0, 0]
            v = v * vs_ref[0, 0]
        k_pos = ik * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_size), 1)
        _accum(k, v, k_pos, k_pos < q_off)

    pool_live = jnp.logical_and(ik < num_pool_blocks,
                                ik * block_size < q_off)
    if sliding_window > 0:
        pool_live = jnp.logical_and(
            pool_live,
            (ik + 1) * block_size - 1 > q_off + iq * block_q
            - sliding_window)
    pl.when(pool_live)(_pool)

    # ---- raw chunk tiles: positions [q_offset, q_offset + W), causal
    # within the chunk; padded tail positions masked by total_len.
    def _raw():
        j = ik - num_pool_blocks
        k = kr_ref[0, 0].astype(jnp.float32)               # [BS, D]
        v = vr_ref[0, 0].astype(jnp.float32)
        k_pos = q_off + j * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_size), 1)
        _accum(k, v, k_pos, (k_pos < tlen) & (q_pos - k_pos >= 0))

    j = ik - num_pool_blocks
    raw_live = jnp.logical_and(
        ik >= num_pool_blocks,
        jnp.logical_and(j * block_size <= iq * block_q + block_q - 1,
                        q_off + j * block_size < tlen))
    if sliding_window > 0:
        raw_live = jnp.logical_and(
            raw_live,
            (j + 1) * block_size - 1 > iq * block_q - sliding_window)
    pl.when(raw_live)(_raw)

    @pl.when(ik == num_pool_blocks + num_raw_blocks - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("sliding_window", "block_q", "interpret"))
def flash_attention_chunk(
    q: jnp.ndarray,                  # [1, W, H, D] — one chunk, one sequence
    k_pool: jnp.ndarray,             # [NB, BS, KV, D] (int8 when quantized)
    v_pool: jnp.ndarray,
    block_table: jnp.ndarray,        # [1, MB] int32
    q_offset: jnp.ndarray,           # i32 scalar (traced)
    total_len: jnp.ndarray,          # i32 scalar (traced): q_offset + live len
    k_raw: jnp.ndarray,              # [1, W, KV, D] — the chunk's own K/V
    v_raw: jnp.ndarray,
    alibi_slopes: Optional[jnp.ndarray] = None,   # [H]
    *,
    k_scales: Optional[jnp.ndarray] = None,       # [NB, KV] f32 (int8 pools)
    v_scales: Optional[jnp.ndarray] = None,
    sliding_window: int = 0,
    block_q: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Chunk-prefill attention straight over the paged pool (TPU serving).

    The dynamic-offset counterpart of ``flash_attention``: ``q_offset``
    and ``total_len`` are *device scalars* (scalar-prefetch operands), so
    the fixed-shape ``[1, W]`` serving chunk executable needs no gather
    of the pool to a contiguous ``[cap]`` view and no per-offset
    recompile — the page walk is bounded by the live prefix length the
    way ``paged_attention`` bounds its decode walk.  Causality within the
    chunk is handled by raw-tile masking; the chunk's own K/V come from
    ``k_raw``/``v_raw`` (never pool-roundtripped, so int8 quantization
    noise only enters for *earlier* chunks' positions — identical
    semantics to the XLA oracle in ``ref.chunk_prefill_attention_ref``).
    """
    B, W, H, D = q.shape
    assert B == 1, "chunk executable serves one sequence per dispatch"
    NB, BS, KV, _ = k_pool.shape
    G = H // KV
    MB = block_table.shape[1]
    quantized = k_scales is not None
    use_alibi = alibi_slopes is not None
    slopes = (alibi_slopes.reshape(KV, G) if use_alibi
              else jnp.zeros((KV, G), jnp.float32))

    bq = min(block_q, W)
    pq = (-W) % bq
    nq = (W + pq) // bq
    pr = (-W) % BS
    nr = (W + pr) // BS                              # raw chunk K tiles
    qg = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))[0] \
        .reshape(W + pq, KV, G, D).transpose(1, 2, 0, 3)   # [KV, G, Wq, D]
    kr = jnp.pad(k_raw, ((0, 0), (0, pr), (0, 0), (0, 0)))[0] \
        .transpose(1, 0, 2).reshape(KV, nr, BS, D)
    vr = jnp.pad(v_raw, ((0, 0), (0, pr), (0, 0), (0, 0)))[0] \
        .transpose(1, 0, 2).reshape(KV, nr, BS, D)
    info = jnp.stack([jnp.asarray(q_offset, jnp.int32),
                      jnp.asarray(total_len, jnp.int32)])

    kernel = functools.partial(
        _fa_chunk_kernel, block_q=bq, block_size=BS, num_pool_blocks=MB,
        num_raw_blocks=nr, use_alibi=use_alibi,
        sliding_window=sliding_window, quantized=quantized)

    def page_map(h, iq, ik, bt, info):
        # pages past the live prefix re-resolve to its last live page
        # (Pallas skips the DMA when consecutive steps map to the same
        # block), so the walk is bounded by ceil(q_offset / BS).
        return (bt[0, _chunk_clamp(ik, info[0], BS, MB)], 0, h, 0)

    def scale_map(h, iq, ik, bt, info):
        return (bt[0, _chunk_clamp(ik, info[0], BS, MB)], h)

    def raw_map(h, iq, ik, bt, info):
        return (h, jnp.clip(ik - MB, 0, nr - 1), 0, 0)

    in_specs = [
        pl.BlockSpec((1, G), lambda h, iq, ik, bt, info: (h, 0)),
        pl.BlockSpec((1, G, bq, D), lambda h, iq, ik, bt, info: (h, 0, iq, 0)),
        pl.BlockSpec((1, BS, 1, D), page_map),
    ]
    args = [k_pool]
    if quantized:
        in_specs.append(pl.BlockSpec((1, 1), scale_map))
        args.append(k_scales)
    in_specs.append(pl.BlockSpec((1, BS, 1, D), page_map))
    args.append(v_pool)
    if quantized:
        in_specs.append(pl.BlockSpec((1, 1), scale_map))
        args.append(v_scales)
    in_specs += [pl.BlockSpec((1, 1, BS, D), raw_map),
                 pl.BlockSpec((1, 1, BS, D), raw_map)]
    args += [kr, vr]

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,                 # block_table, [off, len]
            grid=(KV, nq, MB + nr),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, G, bq, D),
                                   lambda h, iq, ik, bt, info: (h, 0, iq, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, bq, D), jnp.float32),
                pltpu.VMEM((G, bq), jnp.float32),
                pltpu.VMEM((G, bq), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((KV, G, W + pq, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_table, info, slopes, qg, *args)

    return out.transpose(2, 0, 1, 3).reshape(1, W + pq, H, D)[:, :W]


def _chunk_clamp(i, prefix_len, block_size, num_table_blocks):
    """Clamp K-grid step ``i`` to the prefix's last live table entry
    (``prefix_len`` may be 0 on a first chunk: clamp to entry 0, the
    kernel's ``pool_live`` guard skips the compute anyway)."""
    last = jnp.maximum((prefix_len + block_size - 1) // block_size, 1) - 1
    return jnp.minimum(jnp.minimum(i, num_table_blocks - 1), last)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sliding_window", "block_q", "block_k",
                     "q_offset", "interpret"))
def flash_attention(
    q: jnp.ndarray,                  # [B, S, H, D]
    k: jnp.ndarray,                  # [B, S_k, KV, D]
    v: jnp.ndarray,
    alibi_slopes: Optional[jnp.ndarray] = None,   # [H]
    *,
    causal: bool = True,
    sliding_window: int = 0,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    # pad seq to tile multiples
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))

    qg = qp.reshape(B, Sq + pq, KV, G, D).transpose(0, 2, 3, 1, 4)  # [B,KV,G,S,D]
    kg = kp.transpose(0, 2, 1, 3)                                    # [B,KV,S,D]
    vg = vp.transpose(0, 2, 1, 3)
    use_alibi = alibi_slopes is not None
    slopes = (alibi_slopes.reshape(KV, G) if use_alibi
              else jnp.zeros((KV, G), jnp.float32))

    nq = (Sq + pq) // block_q
    nk = (Sk + pk) // block_k
    grid = (B, KV, nq, nk)

    kernel = functools.partial(
        _fa_kernel, block_q=block_q, block_k=block_k, causal=causal,
        sliding_window=sliding_window, use_alibi=use_alibi,
        q_offset=q_offset, num_k_blocks=nk, seq_len_k=Sk)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=0,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, G), lambda b, h, iq, ik: (h, 0)),
                pl.BlockSpec((1, 1, G, block_q, D),
                             lambda b, h, iq, ik: (b, h, 0, iq, 0)),
                pl.BlockSpec((1, 1, block_k, D),
                             lambda b, h, iq, ik: (b, h, ik, 0)),
                pl.BlockSpec((1, 1, block_k, D),
                             lambda b, h, iq, ik: (b, h, ik, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, block_q, D),
                                   lambda b, h, iq, ik: (b, h, 0, iq, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, block_q, D), jnp.float32),
                pltpu.VMEM((G, block_q), jnp.float32),
                pltpu.VMEM((G, block_q), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, Sq + pq, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(slopes, qg, kg, vg)

    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq + pq, H, D)
    return out[:, :Sq]
