"""Pallas TPU flash-attention kernel for Opt-GQA prefill.

Adaptation of the paper's DCU attention kernel to TPU (DESIGN.md §3):

* Q is laid out as [B, KV, G, S, D] (G = q_per_kv): the grid iterates over
  *KV heads*, and each K/V tile loaded into VMEM is contracted against all
  G query heads of its group at once — the paper's "shared key-value"
  becomes a batched MXU matmul with G× higher arithmetic intensity.
* ALiBi bias is computed from iota inside the tile (never a [S,S] mask).
* Causal / sliding-window tiles that are fully masked are *skipped*
  (pl.when) — the sparse-attention half of the paper's title.
* Online softmax (flash) with f32 accumulators in VMEM scratch.

Tile sizes default to MXU-aligned (128) in S and D.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _fa_kernel(slopes_ref, q_ref, k_ref, v_ref, o_ref,
               acc_ref, m_ref, l_ref, *,
               block_q: int, block_k: int, causal: bool,
               sliding_window: int, use_alibi: bool, q_offset: int,
               num_k_blocks: int, seq_len_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    dist = q_pos - k_pos

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # [G, Tq, D]
        k = k_ref[0, 0].astype(jnp.float32)               # [Tk, D]
        v = v_ref[0, 0].astype(jnp.float32)               # [Tk, D]
        scale = q.shape[-1] ** -0.5
        s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        # s: [G, Tq, Tk]
        if use_alibi:
            slopes = slopes_ref[0].astype(jnp.float32)     # [G]
            s = s - slopes[:, None, None] * jnp.maximum(dist, 0)[None].astype(jnp.float32)
        mask = k_pos < seq_len_k
        if causal:
            mask &= dist >= 0
        if sliding_window > 0:
            mask &= dist < sliding_window
        s = jnp.where(mask[None], s, NEG_INF)

        m_prev = m_ref[...]                               # [G, Tq]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])                  # [G, Tq, Tk]
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + pv

    if causal or sliding_window > 0:
        # tile-skip: live iff some (q,k) in tile satisfies the band.
        q_hi = q_offset + (iq + 1) * block_q - 1
        q_lo = q_offset + iq * block_q
        k_lo = ik * block_k
        k_hi = (ik + 1) * block_k - 1
        live = True
        if causal:
            live = jnp.logical_and(live, k_lo <= q_hi)
        if sliding_window > 0:
            live = jnp.logical_and(live, k_hi > q_lo - sliding_window)
        pl.when(live)(_compute)
    else:
        _compute()

    @pl.when(ik == num_k_blocks - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sliding_window", "block_q", "block_k",
                     "q_offset", "interpret"))
def flash_attention(
    q: jnp.ndarray,                  # [B, S, H, D]
    k: jnp.ndarray,                  # [B, S_k, KV, D]
    v: jnp.ndarray,
    alibi_slopes: Optional[jnp.ndarray] = None,   # [H]
    *,
    causal: bool = True,
    sliding_window: int = 0,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    # pad seq to tile multiples
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))

    qg = qp.reshape(B, Sq + pq, KV, G, D).transpose(0, 2, 3, 1, 4)  # [B,KV,G,S,D]
    kg = kp.transpose(0, 2, 1, 3)                                    # [B,KV,S,D]
    vg = vp.transpose(0, 2, 1, 3)
    use_alibi = alibi_slopes is not None
    slopes = (alibi_slopes.reshape(KV, G) if use_alibi
              else jnp.zeros((KV, G), jnp.float32))

    nq = (Sq + pq) // block_q
    nk = (Sk + pk) // block_k
    grid = (B, KV, nq, nk)

    kernel = functools.partial(
        _fa_kernel, block_q=block_q, block_k=block_k, causal=causal,
        sliding_window=sliding_window, use_alibi=use_alibi,
        q_offset=q_offset, num_k_blocks=nk, seq_len_k=Sk)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=0,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, G), lambda b, h, iq, ik: (h, 0)),
                pl.BlockSpec((1, 1, G, block_q, D),
                             lambda b, h, iq, ik: (b, h, 0, iq, 0)),
                pl.BlockSpec((1, 1, block_k, D),
                             lambda b, h, iq, ik: (b, h, ik, 0)),
                pl.BlockSpec((1, 1, block_k, D),
                             lambda b, h, iq, ik: (b, h, ik, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, block_q, D),
                                   lambda b, h, iq, ik: (b, h, 0, iq, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, block_q, D), jnp.float32),
                pltpu.VMEM((G, block_q), jnp.float32),
                pltpu.VMEM((G, block_q), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, Sq + pq, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(slopes, qg, kg, vg)

    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq + pq, H, D)
    return out[:, :Sq]
