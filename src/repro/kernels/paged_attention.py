"""Pallas TPU paged-attention decode kernel (Opt-GQA over block tables).

The TPU form of the paper's custom DCU decode kernel:

* The KV pool ``[NB, BS, KV, D]`` stays in HBM; the *block table* is a
  scalar-prefetch operand (SMEM) so the BlockSpec ``index_map`` itself
  resolves the per-sequence physical block id — the DMA engine walks the
  page list, which is exactly "paging" on TPU.
* One grid step = (sequence, kv_head, page): the page's K/V tile is pulled
  into VMEM once and contracted with *all* G grouped query heads (shared
  K/V -> batched matmul, the Opt-GQA insight).
* ALiBi bias from iota in-tile; positions past ``seq_len`` masked; online
  softmax accumulated in VMEM scratch across pages.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _clamp_live(i, seq_len, block_size):
    """Clamp page index ``i`` to the sequence's last live page.

    ``seq_len`` may be 0 for inactive slots; clamp to page 0 then (the
    kernel's ``k_lo < seq_len`` guard skips the compute anyway).
    """
    last = jnp.maximum((seq_len + block_size - 1) // block_size, 1) - 1
    return jnp.minimum(i, last)


def _pa_kernel(block_tables_ref, seq_lens_ref,       # scalar prefetch (SMEM)
               slopes_ref, q_ref, *refs,
               block_size: int, num_pages: int, use_alibi: bool,
               sliding_window: int, quantized: bool = False):
    """Shared online-softmax body for the bf16 and int8 decode kernels.

    ``refs`` is (k, v, o, acc, m, l) in the dense mode and
    (k, k_scale, v, v_scale, o, acc, m, l) when ``quantized`` — the int8
    wrapper (``paged_attention_quant.py``) reuses this body so the
    softmax loop can never diverge between the two pool formats.
    """
    if quantized:
        k_ref, ks_ref, v_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref = refs
    else:
        k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref = refs
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    seq_len = seq_lens_ref[b]
    k_lo = i * block_size

    @pl.when(k_lo < seq_len)                          # skip pages past the end
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # [G, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)     # [BS, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)     # [BS, D]
        if quantized:
            # in-register dequant: int8 tile * the page's per-head scale
            k = k * ks_ref[0, 0]
            v = v * vs_ref[0, 0]
        scale = q.shape[-1] ** -0.5
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        # s: [G, BS]
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (1, block_size), 1)[0]
        q_pos = seq_len - 1
        if use_alibi:
            slopes = slopes_ref[0].astype(jnp.float32)                 # [G]
            s = s - slopes[:, None] * jnp.maximum(q_pos - k_pos, 0)[None]
        mask = k_pos < seq_len
        if sliding_window > 0:
            mask &= k_pos > q_pos - sliding_window
        s = jnp.where(mask[None], s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv

    @pl.when(i == num_pages - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sliding_window", "interpret"))
def paged_attention(
    q: jnp.ndarray,                  # [B, H, D] — one new token per sequence
    k_pool: jnp.ndarray,             # [NB, BS, KV, D]
    v_pool: jnp.ndarray,
    block_table: jnp.ndarray,        # [B, MB] int32
    seq_lens: jnp.ndarray,           # [B] int32
    alibi_slopes: Optional[jnp.ndarray] = None,
    *,
    sliding_window: int = 0,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, D = q.shape
    NB, BS, KV, _ = k_pool.shape
    G = H // KV
    MB = block_table.shape[1]
    use_alibi = alibi_slopes is not None
    slopes = (alibi_slopes.reshape(KV, G) if use_alibi
              else jnp.zeros((KV, G), jnp.float32))
    qg = q.reshape(B, KV, G, D)

    kernel = functools.partial(
        _pa_kernel, block_size=BS, num_pages=MB, use_alibi=use_alibi,
        sliding_window=sliding_window)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,                     # block_table, seq_lens
            grid=(B, KV, MB),
            in_specs=[
                pl.BlockSpec((1, G), lambda b, h, i, bt, sl: (h, 0)),
                pl.BlockSpec((1, 1, G, D), lambda b, h, i, bt, sl: (b, h, 0, 0)),
                # the paging step: physical page id comes from the prefetched
                # block table inside the index_map. Pages past the sequence's
                # live page count re-resolve to its last live page: Pallas
                # skips the DMA when consecutive grid steps map to the same
                # block, so the HBM walk is bounded by ceil(seq_len/BS), not
                # the static MB (compute for those steps is skipped too).
                pl.BlockSpec((1, BS, 1, D),
                             lambda b, h, i, bt, sl: (
                                 bt[b, _clamp_live(i, sl[b], BS)], 0, h, 0)),
                pl.BlockSpec((1, BS, 1, D),
                             lambda b, h, i, bt, sl: (
                                 bt[b, _clamp_live(i, sl[b], BS)], 0, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, D),
                                   lambda b, h, i, bt, sl: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, D), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_table, seq_lens, slopes, qg, k_pool, v_pool)

    return out.reshape(B, H, D)
