"""Version shims for the Pallas TPU API surface."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
