"""Public kernel entry points with automatic Pallas / XLA-reference dispatch.

``use_pallas=None`` (default) picks Pallas on TPU, interpret-mode Pallas is
available for CPU validation, and the pure-XLA reference otherwise.
The dry-run always lowers the reference path (Pallas cannot lower on the
CPU backend of the 512-device compile-only mesh).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.flash_attention import (
    flash_attention_chunk as _flash_chunk_pallas)
from repro.kernels.paged_attention import paged_attention as _paged_pallas
from repro.kernels.paged_attention_quant import (
    paged_attention_quant as _paged_quant_pallas)
from repro.kernels.gptq_matmul import gptq_matmul as _gptq_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, alibi_slopes=None, *, causal=True,
                    sliding_window=0, q_offset=0,
                    use_pallas: Optional[bool] = None,
                    interpret: Optional[bool] = None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _flash_pallas(q, k, v, alibi_slopes, causal=causal,
                             sliding_window=sliding_window, q_offset=q_offset,
                             interpret=(not _on_tpu()) if interpret is None else interpret)
    if q.shape[1] > 512 and isinstance(q_offset, int):
        # flash-structured XLA lowering: no [S,S] materialization
        from repro.core.gqa import grouped_attention_chunked
        return grouped_attention_chunked(q, k, v, causal=causal,
                                         sliding_window=sliding_window,
                                         alibi_slopes=alibi_slopes,
                                         q_offset=q_offset)
    return _ref.flash_attention_ref(q, k, v, causal=causal,
                                    sliding_window=sliding_window,
                                    alibi_slopes=alibi_slopes, q_offset=q_offset)


def chunk_prefill_attention(q, k_pool, v_pool, k_scales, v_scales, layer,
                            block_table, q_offset, total_len, k_raw, v_raw,
                            alibi_slopes=None, *, sliding_window=0,
                            use_pallas: Optional[bool] = None,
                            interpret: Optional[bool] = None):
    """Serving chunk-prefill attention with a *traced* ``q_offset``.

    One chunk of one sequence attends over the paged pool's live prefix
    plus its own raw K/V — the Pallas path walks the pool pages directly
    (scalar-prefetch block table, page walk clamped to the live prefix,
    in-register int8 dequant when scales are given); the XLA path is the
    bounded-gather + raw-overlay oracle in ``ref.py``.  Both cost
    O(total_len) pool bytes per layer per chunk, never O(capacity).

    q: [1, W, H, D]; k_pool/v_pool: [L, NB, BS, KV, D]; k_scales/
    v_scales: [L, NB, KV] f32 or None (bf16 pools); layer: traced layer
    index; block_table: [1, MB]; q_offset/total_len: traced i32 scalars;
    k_raw/v_raw: [1, W, KV, D] (the chunk's own full-precision K/V).
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        quant = k_scales is not None
        return _flash_chunk_pallas(
            q, k_pool[layer], v_pool[layer], block_table, q_offset,
            total_len, k_raw, v_raw, alibi_slopes,
            k_scales=k_scales[layer] if quant else None,
            v_scales=v_scales[layer] if quant else None,
            sliding_window=sliding_window,
            interpret=(not _on_tpu()) if interpret is None else interpret)
    return _ref.chunk_prefill_attention_ref(
        q, k_pool, v_pool, k_scales, v_scales, layer, block_table,
        q_offset, total_len, k_raw, v_raw, alibi_slopes=alibi_slopes,
        sliding_window=sliding_window)


def paged_attention(q, k_pool, v_pool, block_table, seq_lens,
                    alibi_slopes=None, *, sliding_window=0,
                    use_pallas: Optional[bool] = None,
                    interpret: Optional[bool] = None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _paged_pallas(q, k_pool, v_pool, block_table, seq_lens,
                             alibi_slopes, sliding_window=sliding_window,
                             interpret=(not _on_tpu()) if interpret is None else interpret)
    return _ref.paged_attention_ref(q, k_pool, v_pool, block_table, seq_lens,
                                    alibi_slopes=alibi_slopes,
                                    sliding_window=sliding_window)


def paged_attention_quant(q, k_values, k_scales, v_values, v_scales,
                          block_table, seq_lens, alibi_slopes=None, *,
                          sliding_window=0,
                          use_pallas: Optional[bool] = None,
                          interpret: Optional[bool] = None):
    """Decode attention over the int8 KV pool (per-block-per-head scales),
    dequantizing inside the kernel instead of materializing bf16 pages."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _paged_quant_pallas(
            q, k_values, k_scales, v_values, v_scales, block_table, seq_lens,
            alibi_slopes, sliding_window=sliding_window,
            interpret=(not _on_tpu()) if interpret is None else interpret)
    return _ref.paged_attention_quant_ref(
        q, k_values, k_scales, v_values, v_scales, block_table, seq_lens,
        alibi_slopes=alibi_slopes, sliding_window=sliding_window)


def quant_matmul(x: jnp.ndarray, params: Dict[str, jnp.ndarray], *,
                 use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None,
                 ctx=None) -> jnp.ndarray:
    """x: [..., K] @ packed int4 weight -> [..., N]."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        if ctx is not None and ctx.tp_axis is not None:
            # keep the dequantized weight sharded like its packed source —
            # otherwise GSPMD may all-gather it (22 GB/step at qwen2 decode)
            from jax.sharding import PartitionSpec as P
            from repro.core.quant import dequantize
            from repro.runtime.sharding import shard
            n = params["scales"].shape[-1]
            tp = ctx.tp_axis if n % ctx.tp_size == 0 else None
            w = dequantize(params, x.shape[-1], x.dtype)
            w = shard(ctx, w, P(None, tp))
            y = x @ w
            if "bias" in params:
                y = y + params["bias"].astype(y.dtype)
            return y
        return _ref.quant_matmul_ref(x, params)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = _gptq_pallas(x2, params["qweight"], params["scales"], params["zeros"],
                     interpret=(not _on_tpu()) if interpret is None else interpret)
    if "bias" in params:
        y = y + params["bias"].astype(y.dtype)
    return y.reshape(*lead, -1)
