"""Pallas TPU paged-attention decode kernel over the int8 KV pool.

Same grid / scalar-prefetch structure as ``paged_attention.py`` — one
grid step = (sequence, kv_head, page); the block table resolves physical
page ids inside the BlockSpec ``index_map``; online softmax across pages
in VMEM scratch; Opt-GQA shared-KV contraction of all G grouped query
heads per tile.  The kernel body IS ``_pa_kernel`` (``quantized=True``):
the K/V tiles DMA'd into VMEM are **int8** with one f32 scale per
(page, kv head), dequantized in-register right before the contraction.
The quantized cache is never materialized in HBM at full precision:
attention consumes it directly (the TurboAttention observation, arXiv
2412.08585), so the kernel moves ~1/2 (bf16) to ~1/4 (f32) of the
baseline's KV bytes per decode step.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams
from repro.kernels.paged_attention import _clamp_live, _pa_kernel


@functools.partial(jax.jit, static_argnames=("sliding_window", "interpret"))
def paged_attention_quant(
    q: jnp.ndarray,                  # [B, H, D] — one new token per sequence
    k_values: jnp.ndarray,           # [NB, BS, KV, D] int8
    k_scales: jnp.ndarray,           # [NB, KV] f32
    v_values: jnp.ndarray,
    v_scales: jnp.ndarray,
    block_table: jnp.ndarray,        # [B, MB] int32
    seq_lens: jnp.ndarray,           # [B] int32
    alibi_slopes: Optional[jnp.ndarray] = None,
    *,
    sliding_window: int = 0,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, D = q.shape
    NB, BS, KV, _ = k_values.shape
    G = H // KV
    MB = block_table.shape[1]
    use_alibi = alibi_slopes is not None
    slopes = (alibi_slopes.reshape(KV, G) if use_alibi
              else jnp.zeros((KV, G), jnp.float32))
    qg = q.reshape(B, KV, G, D)

    kernel = functools.partial(
        _pa_kernel, block_size=BS, num_pages=MB, use_alibi=use_alibi,
        sliding_window=sliding_window, quantized=True)

    def page_map(b, h, i, bt, sl):
        return (bt[b, _clamp_live(i, sl[b], BS)], 0, h, 0)

    def scale_map(b, h, i, bt, sl):
        return (bt[b, _clamp_live(i, sl[b], BS)], h)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,                     # block_table, seq_lens
            grid=(B, KV, MB),
            in_specs=[
                pl.BlockSpec((1, G), lambda b, h, i, bt, sl: (h, 0)),
                pl.BlockSpec((1, 1, G, D), lambda b, h, i, bt, sl: (b, h, 0, 0)),
                # paging exactly as the bf16 kernel: the prefetched block
                # table picks the physical page; dead pages re-resolve to
                # the last live one so their DMA + compute are skipped.
                pl.BlockSpec((1, BS, 1, D), page_map),
                pl.BlockSpec((1, 1), scale_map),
                pl.BlockSpec((1, BS, 1, D), page_map),
                pl.BlockSpec((1, 1), scale_map),
            ],
            out_specs=pl.BlockSpec((1, 1, G, D),
                                   lambda b, h, i, bt, sl: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, D), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_table, seq_lens, slopes, qg, k_values, k_scales,
      v_values, v_scales)

    return out.reshape(B, H, D)
