"""Device-side half of the serving engine: decode state + jitted calls.

Owns the paged KV pools / SSM state pools, the jitted prefill /
per-token decode / fused megastep executables, on-device sampling for the
legacy loop, and the copy-on-write block copies.  It knows nothing about
queues, slots-as-policy, or request lifecycles — the ``Scheduler`` does;
the engine facade wires the two together.

Buffer-donation invariant (see docs/PERF.md): the megastep donates the
whole decode state, so after a fused dispatch the previous ``state``
arrays are dead — always re-read ``runner.state``.
"""
from __future__ import annotations

import contextlib
from typing import Dict, List, Optional, Sequence as Seq, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.kv_quant import (cache_from_state, cache_to_state,
                                 normalize_kv_cache_dtype)
from repro.core.paged_cache import copy_blocks
from repro.core.sampling import sample_from_logits
from repro.models import transformer as T
from repro.obs.trace import NULL_TRACER

# decode-state entries that are pool-shaped [L, NB, ...] and therefore
# owned globally by the engine (scattered whole, not per-slot)
_POOL_KEYS = ("k_pool", "v_pool", "k_scales", "v_scales")


class ModelRunner:
    def __init__(self, cfg: ModelConfig, params, *, max_slots: int,
                 num_blocks: int, max_blocks_per_seq: int,
                 rt: Optional[dict] = None, max_horizon: int = 8,
                 state_dtype=jnp.float32, kv_cache_dtype: str = "bf16",
                 chunk_tokens: Optional[int] = None,
                 unified: bool = False, tracer=None,
                 profile_labels: bool = False):
        self.cfg = cfg
        self.params = params
        # engine-owned span tracer (obs); NULL_TRACER = zero-work no-op
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # when True, dispatches also carry jax.profiler.TraceAnnotation
        # labels so a --profile-dir capture names each device region
        self.profile_labels = bool(profile_labels)
        self.max_slots = max_slots
        self.num_blocks = num_blocks
        self.mb = max_blocks_per_seq
        self.rt = dict(rt or {})
        self.max_horizon = max(1, max_horizon)
        self.kv_cache_dtype = normalize_kv_cache_dtype(kv_cache_dtype)
        self.chunk_tokens = chunk_tokens
        self.unified = bool(unified and chunk_tokens)
        # device dispatches issued so far (jitted calls + CoW copies) —
        # the engine diffs this around each step for
        # ``device_dispatches_per_step`` (host->device table uploads are
        # transfers, not dispatches, and are not counted)
        self.dispatches = 0
        self.state = T.make_decode_state(cfg, max_slots, num_blocks, self.mb,
                                         dtype=state_dtype,
                                         kv_cache_dtype=self.kv_cache_dtype)

        self._prefill = jax.jit(
            lambda p, s, b: T.prefill(cfg, p, s, b, None, self.rt))
        # the serving chunk executable: [1, chunk_tokens] + scalar offsets
        # regardless of prompt length or batch composition, so it compiles
        # exactly once. Pools are donated: the chunk scatter updates the
        # [L, NB, BS, KV, D] arrays (+ int8 scales) in place.
        self._prefill_chunk = None
        if chunk_tokens:
            self._prefill_chunk = jax.jit(
                lambda p, c, t, bt, off, tl: T.prefill_chunk(
                    cfg, p, c, t, bt, off, tl, None, self.rt),
                donate_argnums=(1,))
        self._decode = jax.jit(
            lambda p, s, t: T.decode_step(cfg, p, s, t, None, self.rt))
        # the fused megastep donates the whole decode state: the KV pools
        # are updated in place instead of copied every token.
        self._megastep = jax.jit(
            lambda p, s, t, sp, a, n: T.decode_megastep(
                cfg, p, s, t, sp, a, n,
                max_horizon=self.max_horizon, ctx=None, rt=self.rt),
            donate_argnums=(1,))
        # the unified step: ONE donated dispatch = one decode step for the
        # active slots + one prefill chunk + per-row sampling.  Shapes are
        # pinned to [max_slots] decode rows and the [1, chunk_tokens]
        # chunk window, so it compiles exactly once.
        self._unified = None
        self._unified_chained = None
        if self.unified:
            self._unified = jax.jit(
                lambda p, s, t, sp, a, c, cbt, off, tl: T.unified_step(
                    cfg, p, s, t, sp, a, c, cbt, off, tl, None, self.rt),
                donate_argnums=(1,))
            # the async pipeline's executable: same unified step, but the
            # decode feed tokens are gathered on device from the PREVIOUS
            # dispatch's (still in-flight) output buffer.  Deliberately
            # NOT donated: donating a buffer the in-flight dispatch is
            # still producing forces the XLA CPU client to run the call
            # synchronously (measured: zero host/device overlap), which
            # is exactly what the pipeline exists to avoid.  The state
            # copy this costs is ~the pool size per step and is hidden
            # under the overlapped host work (see docs/PERF.md).
            self._unified_chained = jax.jit(
                lambda p, s, pv, ci, up, t, sp, a, c, cbt, off, tl:
                T.unified_step_chained(cfg, p, s, pv, ci, up, t, sp, a,
                                       c, cbt, off, tl, None, self.rt))
        # host-known zero feed buffer for pipeline-restart dispatches
        # (use_prev all False): allocated once so the chained executable
        # keeps a single (shape, dtype) signature either way
        self.zero_prev = jnp.zeros((max_slots + 1,), jnp.int32)
        # legacy-loop sampling: the SAME per-slot kernel the megastep runs,
        # jitted standalone so both paths are bitwise identical.  ``guard``
        # is trace-static (a python bool branching on jnp.isfinite): with
        # guards off the traced program is identical to the pre-guard one.
        self._sample = jax.jit(sample_from_logits,
                               static_argnames=("guard",))

    # ------------------------------------------------------------ obs
    def _label(self, name: str):
        """A ``jax.profiler.TraceAnnotation`` region when deep-dive
        profiling is on (``--profile-dir``), else a free nullcontext —
        the hot path never touches the profiler by default."""
        if self.profile_labels:
            return jax.profiler.TraceAnnotation(name)
        return contextlib.nullcontext()

    # ------------------------------------------------------------ tables
    def sync_tables(self, running: Dict[int, "object"]) -> None:
        """Rebuild seq_lens / block_table device rows from host truth."""
        bt = np.zeros((self.max_slots, self.mb), np.int32)
        sl = np.zeros((self.max_slots,), np.int32)
        for slot, s in running.items():
            bt[slot, :len(s.block_ids)] = s.block_ids
            sl[slot] = s.seq_len
        if "block_table" in self.state:
            self.state["block_table"] = jnp.asarray(bt)
        self.state["seq_lens"] = jnp.asarray(sl)

    # ------------------------------------------------------------ prefill
    def prefill(self, seqs: List["object"], maxlen: int) -> jnp.ndarray:
        """Prefill a wave of admitted sequences (padded to ``maxlen``);
        scatters pool / per-slot state rows back into the live engine
        state and returns last-token logits [len(seqs), V]."""
        B = len(seqs)
        toks = np.zeros((B, maxlen), np.int32)
        lens = np.zeros((B,), np.int32)
        for i, s in enumerate(seqs):
            toks[i, :s.seq_len] = s.req.prompt
            lens[i] = s.seq_len
        # temporary contiguous state for the prefill batch, then scatter
        # into the live engine state at each sequence's slot/table.
        sub = dict(self.state)
        bt = np.zeros((B, self.mb), np.int32)
        for i, s in enumerate(seqs):
            bt[i, :len(s.block_ids)] = s.block_ids
        sub["block_table"] = jnp.asarray(bt) if "block_table" in sub else None
        sub = {k: v for k, v in sub.items() if v is not None}
        # prefill writes pools in-place via the shared pool arrays: pools
        # are engine-global, per-slot state rows are gathered/scattered.
        per_seq = {}
        for k in ("ssm_h", "ssm_conv", "lru_h", "rec_conv"):
            if k in sub:
                per_seq[k] = sub[k][:, [s.slot for s in seqs]]
                sub[k] = per_seq[k]
        sub["seq_lens"] = jnp.asarray(lens)
        batch = {"tokens": jnp.asarray(toks), "ctx_lens": jnp.asarray(lens)}
        self.dispatches += 1
        with self.tracer.span("dispatch:prefill", cat="device",
                              args={"batch": B, "maxlen": maxlen}), \
                self._label("prefill"):
            logits, sub = self._prefill(self.params, sub, batch)
        for k in _POOL_KEYS:
            if k in sub:
                self.state[k] = sub[k]
        for k in per_seq:
            self.state[k] = self.state[k].at[:, [s.slot for s in seqs]].set(
                sub[k])
        return logits

    def prefill_chunk(self, seq, start: int, length: int) -> jnp.ndarray:
        """Run one prefill chunk of one sequence through the fixed-shape
        executable: tokens [1, W] right-padded, scalar position offset.
        Scatters the chunk K/V into the live pools (donated, in place)
        and returns the last-live-token logits [1, V] as a *device*
        array — the engine batches first-token sampling across the
        step's final chunks, so no host sync happens here."""
        W = self.chunk_tokens
        toks = np.zeros((1, W), np.int32)
        toks[0, :length] = seq.req.prompt[start:start + length]
        bt = np.zeros((1, self.mb), np.int32)
        bt[0, :len(seq.block_ids)] = seq.block_ids
        cache = cache_from_state(self.state)
        self.dispatches += 1
        with self.tracer.span("dispatch:chunk", cat="device",
                              args={"start": start, "length": length}), \
                self._label("prefill_chunk"):
            logits, cache = self._prefill_chunk(
                self.params, cache, jnp.asarray(toks), jnp.asarray(bt),
                jnp.int32(start), jnp.int32(start + length))
        self.state.update(cache_to_state(cache))
        return logits

    def unified_step(self, tokens: np.ndarray,
                     sampling: Dict[str, np.ndarray], active: np.ndarray,
                     chunk_prompt: Seq[int], block_ids: Seq[int],
                     start: int, length: int) -> jnp.ndarray:
        """ONE donated device dispatch for a whole mixed engine iteration:
        a single decode step over the active slots, one prefill chunk of
        one sequence, and the per-row sampling for both.  Returns the
        ``[max_slots + 1]`` token buffer as a *device* array — the engine
        reads it back after the whole step's dispatches are in flight, so
        an admission burst of several chunks pipelines behind one sync.
        Rows [0, max_slots) are the decode slots' samples; row max_slots
        is the chunk's first token (meaningful only on final chunks)."""
        W = self.chunk_tokens
        toks = np.zeros((1, W), np.int32)
        toks[0, :length] = chunk_prompt[start:start + length]
        bt = np.zeros((1, self.mb), np.int32)
        bt[0, :len(block_ids)] = block_ids
        sp = {k: jnp.asarray(v) for k, v in sampling.items()}
        self.dispatches += 1
        with self.tracer.span("dispatch:unified", cat="device",
                              args={"start": start, "length": length}), \
                self._label("unified_step"):
            out, self.state = self._unified(
                self.params, self.state, jnp.asarray(tokens), sp,
                jnp.asarray(active), jnp.asarray(toks), jnp.asarray(bt),
                jnp.int32(start), jnp.int32(start + length))
        return out

    def unified_step_chained(self, prev_out, chain_idx: np.ndarray,
                             use_prev: np.ndarray, tokens: np.ndarray,
                             sampling: Dict[str, np.ndarray],
                             active: np.ndarray, chunk_prompt: Seq[int],
                             block_ids: Seq[int], start: int,
                             length: int) -> jnp.ndarray:
        """``unified_step`` for the async pipeline: the decode feed
        tokens are gathered ON DEVICE from ``prev_out`` — the previous
        dispatch's still-in-flight ``[max_slots + 1]`` output buffer —
        wherever ``use_prev`` is set (``chain_idx`` names the source
        row; row ``max_slots`` is the chunk sample).  Returns this
        dispatch's own ``[max_slots + 1]`` buffer as a device array the
        engine reads back one step later.  Non-donating (see __init__):
        the previous state stays alive until its readback."""
        W = self.chunk_tokens
        toks = np.zeros((1, W), np.int32)
        toks[0, :length] = chunk_prompt[start:start + length]
        bt = np.zeros((1, self.mb), np.int32)
        bt[0, :len(block_ids)] = block_ids
        sp = {k: jnp.asarray(v) for k, v in sampling.items()}
        if prev_out is None:
            prev_out = self.zero_prev
        self.dispatches += 1
        with self.tracer.span("dispatch:unified_chained", cat="device",
                              args={"start": start, "length": length}), \
                self._label("unified_step_chained"):
            out, self.state = self._unified_chained(
                self.params, self.state, prev_out,
                jnp.asarray(chain_idx), jnp.asarray(use_prev),
                jnp.asarray(tokens), sp, jnp.asarray(active),
                jnp.asarray(toks), jnp.asarray(bt),
                jnp.int32(start), jnp.int32(start + length))
        return out

    @staticmethod
    def _cache_size(fn) -> float:
        """Jit compile count via the wrapper's ``_cache_size`` (private
        jax API): NaN if a jax bump removed it, so gates skip with an
        API-drift notice instead of reading as a fake regression."""
        if not hasattr(fn, "_cache_size"):     # pragma: no cover - jax API
            return float("nan")
        return float(fn._cache_size())

    def prefill_compiles(self) -> float:
        """Compile count of the executable that actually runs prefill
        work: the unified step (which embeds the chunk path) in unified
        mode, else the fixed-shape chunk executable — 1 forever for
        either fixed-shape path; one per distinct (wave size, bucket)
        shape for the whole-prompt oracle (the recompile explosion the
        chunked path removes)."""
        if self.unified and self._unified is not None:
            return self.unified_compiles()
        fn = self._prefill_chunk if self._prefill_chunk is not None \
            else self._prefill
        return self._cache_size(fn)

    def unified_compiles(self) -> float:
        """Max compile count across the unified step executables (NaN
        when unified dispatch is off or the private jax cache API
        drifted).  The async engine runs mixed steps through the chained
        variant and the flush fallbacks through the donated one — each
        fixed-shape executable must compile exactly once, so a healthy
        run reads 1.0 whichever subset actually dispatched."""
        if self._unified is None:
            return float("nan")
        counts = [self._cache_size(self._unified)]
        if self._unified_chained is not None:
            counts.append(self._cache_size(self._unified_chained))
        return float(max(counts))

    # ------------------------------------------------------------ decode
    def decode(self, tokens: np.ndarray) -> jnp.ndarray:
        """One per-token decode step for all slots; tokens: [max_slots]."""
        self.dispatches += 1
        with self.tracer.span("dispatch:decode", cat="device"), \
                self._label("decode"):
            logits, self.state = self._decode(self.params, self.state,
                                              jnp.asarray(tokens))
        return logits

    def megastep(self, tokens: np.ndarray, sampling: Dict[str, np.ndarray],
                 active: np.ndarray, n_steps: int) -> np.ndarray:
        """Dispatch one fused horizon; returns the [n_steps, max_slots]
        token buffer as numpy (the ONE host sync of the dispatch)."""
        sp = {k: jnp.asarray(v) for k, v in sampling.items()}
        self.dispatches += 1
        with self.tracer.span("dispatch:megastep", cat="device",
                              args={"n_steps": int(n_steps)}), \
                self._label("megastep"):
            out, self.state = self._megastep(
                self.params, self.state, jnp.asarray(tokens), sp,
                jnp.asarray(active), jnp.int32(n_steps))
            with self.tracer.span("readback", cat="device"):
                return np.asarray(out[:n_steps])

    def sample(self, logits, sampling: Dict[str, np.ndarray]) -> np.ndarray:
        """Per-slot sampling for the legacy loop / prefill first token.
        An optional "poison" row-bias (fault injection) and the
        non-finite guard flag ride through so the two-call oracle path
        gets the exact same protection as the fused executables."""
        self.dispatches += 1
        kw = {}
        if "poison" in sampling:
            kw["poison"] = jnp.asarray(sampling["poison"])
        with self.tracer.span("dispatch:sample", cat="device"), \
                self._label("sample"):
            return np.asarray(self._sample(
                logits, jnp.asarray(sampling["keys"]),
                jnp.asarray(sampling["counts"]),
                jnp.asarray(sampling["temps"]),
                jnp.asarray(sampling["top_ks"]),
                jnp.asarray(sampling["top_ps"]),
                guard=bool(self.rt.get("sampling_guard")), **kw))

    # ------------------------------------------------------------ CoW
    def copy_cow(self, pairs: Seq[Tuple[int, int]]) -> None:
        """Resolve copy-on-write on device: block contents never visit the
        host. pairs: [(src_block, dst_block), ...]. Padded to a fixed
        ``max_slots`` length so ``copy_blocks`` compiles once, not once per
        CoW batch size. Padding entries are self-copies of the first src
        block: a pad index can never collide with a real dst (dst blocks
        are freshly allocated, src blocks are still live), so the scatter
        stays duplicate-free on every real destination."""
        pad = (pairs[0][0],) * (self.max_slots - len(pairs))
        src = np.asarray([p[0] for p in pairs] + list(pad), np.int32)
        dst = np.asarray([p[1] for p in pairs] + list(pad), np.int32)
        self.dispatches += 1
        # int8 mode: the scale rows ride along with the value blocks —
        # a fork that dropped them would dequantize its prefix with junk
        with self.tracer.span("dispatch:cow", cat="device",
                              args={"pairs": len(pairs)}), \
                self._label("copy_cow"):
            for k in _POOL_KEYS:
                if k in self.state:
                    self.state[k] = copy_blocks(self.state[k], src, dst)

    # ------------------------------------------------------------ memory
    def kv_pool_bytes(self) -> int:
        """Device bytes held by the paged KV pools (values + scales)."""
        return sum(int(self.state[k].size) * self.state[k].dtype.itemsize
                   for k in _POOL_KEYS if k in self.state)

    def kv_bytes_per_token(self) -> float:
        """KV bytes per cached token position, across all attention layers
        (scales amortized over the block): the figure the int8 pool halves
        vs bf16 (~4x vs the f32 CPU pools)."""
        bs = self.cfg.paging.block_size
        return self.kv_pool_bytes() / float(self.num_blocks * bs)
