"""Serving layer public surface.

New code::

    from repro.serving import LLM, SamplingParams, RequestOutput

Deprecated (one-release shim)::

    from repro.serving import ServingEngine, Request
"""
from repro.serving.engine import (EngineOverloadedError, Request,
                                  ServingEngine)
from repro.serving.faults import (FaultInjector, FaultSpec,
                                  PoisonedDispatchError,
                                  TransientDeviceError, random_schedule)
from repro.serving.llm import LLM
from repro.serving.params import RequestOutput, SamplingParams
from repro.serving.scheduler import (PrefillChunk, RequestState, Scheduler,
                                     Sequence, StepPlan)

__all__ = ["LLM", "SamplingParams", "RequestOutput", "ServingEngine",
           "Request", "RequestState", "Scheduler", "Sequence",
           "StepPlan", "PrefillChunk",
           "EngineOverloadedError", "FaultInjector", "FaultSpec",
           "PoisonedDispatchError", "TransientDeviceError",
           "random_schedule"]
