"""Deterministic fault injection for the serving engine.

Serving fault tolerance is only trustworthy if every failure mode is a
*reproducible test*: a seeded schedule decides, per engine step and per
named site, whether a fault fires — so a chaos run can be replayed
token-for-token and compared against a fault-free run (the same
determinism contract the sampling streams already obey).

Four injection sites, consulted by the engine / scheduler at the exact
points the real failures would surface:

* ``dispatch`` — a device dispatch raises ``TransientDeviceError``
  *before* the jitted call is issued (so donated buffers are never left
  half-dead and a retry is always safe).  A spec with ``count=k`` models
  a transient error that clears after ``k`` attempts; a spec with
  ``rid=r`` models a *poisoned request*: every dispatch whose batch
  contains ``r`` fails until the engine quarantines it.
* ``nan`` — the sampler sees non-finite logits for the chosen request's
  row (injected as a NaN bias added to that row's logits on device, so
  the engine's non-finite guard is exercised end to end, not simulated).
* ``alloc`` — the block allocator reports exhaustion: admission and
  prefill-chunk growth see zero headroom for the scheduled steps.
* ``stall`` — the step stalls (host sleep) past the straggler
  watchdog's threshold.

Everything is host-side and O(1) per consultation; an engine built
without an injector (the default) never constructs one and pays a single
``is None`` check per site.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Optional, Sequence, Set

import numpy as np

SITES = ("dispatch", "nan", "alloc", "stall")


class TransientDeviceError(RuntimeError):
    """An injected (or real) recoverable device/dispatch failure."""


class PoisonedDispatchError(RuntimeError):
    """A dispatch that kept failing after bounded retries.

    Carries the request ids that were in the failing batch so the
    engine's recovery path can requeue and bisect them.
    """

    def __init__(self, rids: Iterable[int], cause: Optional[str] = None):
        self.rids = sorted(set(rids))
        super().__init__(f"dispatch failed after retries (rids="
                         f"{self.rids}{': ' + cause if cause else ''})")


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    site:  one of ``SITES``.
    step:  first engine step (0-based, counted by ``step_begin``) at
           which the spec is armed.
    count: how many consultations fire before the spec clears — the
           "transient" knob (``dispatch``/``alloc``/``stall``).  Ignored
           for rid-targeted ``dispatch`` specs, which are persistent
           until the engine quarantines the request.
    rid:   target request id.  For ``dispatch``: the poisoned request
           (any batch containing it fails).  For ``nan``: the row whose
           logits go non-finite (fires ``count`` times).
    seconds: stall duration for ``stall`` specs.
    """
    site: str
    step: int = 0
    count: int = 1
    rid: Optional[int] = None
    seconds: float = 0.0

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"expected one of {SITES}")


def random_schedule(seed: int, steps: int, *,
                    p_dispatch: float = 0.0, p_nan: float = 0.0,
                    p_alloc: float = 0.0, rids: Sequence[int] = (),
                    ) -> List[FaultSpec]:
    """A seeded random chaos schedule over ``steps`` engine steps.

    Each step independently draws transient-dispatch / NaN-row /
    alloc-exhaustion events; NaN events target a random rid from
    ``rids``.  Same seed => same schedule => reproducible chaos runs.
    """
    rng = np.random.default_rng(seed)
    specs: List[FaultSpec] = []
    for s in range(steps):
        if p_dispatch and rng.random() < p_dispatch:
            specs.append(FaultSpec("dispatch", step=s,
                                   count=int(rng.integers(1, 3))))
        if p_nan and rids and rng.random() < p_nan:
            specs.append(FaultSpec("nan", step=s,
                                   rid=int(rng.choice(list(rids)))))
        if p_alloc and rng.random() < p_alloc:
            specs.append(FaultSpec("alloc", step=s,
                                   count=int(rng.integers(1, 3))))
    return specs


@dataclass
class _Armed:
    spec: FaultSpec
    remaining: int


class FaultInjector:
    """Schedule-driven injector the engine consults at named sites.

    Construct with explicit ``FaultSpec``s (or ``random_schedule``),
    attach via ``ServingEngine(..., fault_injector=...)``.  The engine
    calls ``step_begin`` once per iteration; site hooks then report
    whether the step's armed specs fire.  ``fired`` records every
    injection (site, step, rid) for test assertions.
    """

    def __init__(self, specs: Sequence[FaultSpec] = ()):
        self.specs = list(specs)
        self.step = -1
        self._armed: List[_Armed] = []
        self._pending = sorted(self.specs, key=lambda s: s.step)
        self.quarantined: Set[int] = set()
        self.fired: List[dict] = []

    def step_begin(self, step: Optional[int] = None) -> None:
        """Arm every spec whose step has arrived (engine calls once per
        iteration)."""
        self.step = self.step + 1 if step is None else step
        while self._pending and self._pending[0].step <= self.step:
            spec = self._pending.pop(0)
            self._armed.append(_Armed(spec, spec.count))

    def _fire(self, a: _Armed, **info) -> None:
        self.fired.append({"site": a.spec.site, "step": self.step, **info})
        a.remaining -= 1
        if a.remaining <= 0 and not (a.spec.site == "dispatch"
                                     and a.spec.rid is not None):
            self._armed.remove(a)

    def forgive(self, rid: int) -> None:
        """Clear rid-targeted specs for a quarantined request (the
        engine already failed it; keeping the spec armed would poison
        nothing but still be consulted)."""
        self.quarantined.add(rid)
        self._armed = [a for a in self._armed if a.spec.rid != rid]

    # ------------------------------------------------------------ sites
    def check_dispatch(self, rids: Iterable[int]) -> None:
        """Raise ``TransientDeviceError`` if an armed dispatch spec fires
        for this batch.  rid-targeted specs fire on any batch containing
        the poisoned rid and never clear on their own (persistent until
        ``forgive``); untargeted specs clear after ``count`` fires."""
        rids = set(rids)
        for a in list(self._armed):
            if a.spec.site != "dispatch":
                continue
            if a.spec.rid is not None:
                if a.spec.rid in rids:
                    self._fire(a, rid=a.spec.rid)
                    raise TransientDeviceError(
                        f"injected poisoned dispatch (rid {a.spec.rid})")
            else:
                self._fire(a)
                raise TransientDeviceError("injected transient device "
                                           "error")

    def nan_rids(self, rids: Optional[Iterable[int]] = None) -> Set[int]:
        """Request ids whose sampled-logit rows go non-finite this
        consultation (one dispatch's worth; each spec fires ``count``
        times).  ``rids`` — the batch being dispatched — keeps a spec
        armed until a dispatch actually contains its target, so a fault
        scheduled for a step where the victim sat waiting still lands."""
        present = None if rids is None else set(rids)
        out: Set[int] = set()
        for a in list(self._armed):
            if a.spec.site == "nan" and a.spec.rid is not None:
                if present is not None and a.spec.rid not in present:
                    continue
                out.add(a.spec.rid)
                self._fire(a, rid=a.spec.rid)
        return out

    def alloc_blocked(self) -> bool:
        """Whether the allocator should report exhaustion for this step's
        admission / chunk-growth decisions."""
        for a in list(self._armed):
            if a.spec.site == "alloc":
                self._fire(a)
                return True
        return False

    def stall_seconds(self) -> float:
        """Injected host stall (seconds) for this step, 0.0 if none."""
        total = 0.0
        for a in list(self._armed):
            if a.spec.site == "stall":
                self._fire(a)
                total += a.spec.seconds
        return total
