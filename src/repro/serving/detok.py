"""Bounded background detokenize / RequestOutput fan-out worker.

The async pipelined engine (``enable_async_step``) moves everything a
token event costs *after* the model math — incremental detokenization
and ``RequestOutput`` construction — off the hot loop onto this worker,
so it overlaps with the next step's in-flight device dispatch instead
of serializing behind the readback.

Determinism contract: jobs are processed strictly FIFO on ONE worker
thread, and ``collect_upto(n)`` returns *exactly* the outputs of the
first ``n`` submitted jobs (blocking until they are done — normally
they already are, having had a whole device step to complete).  The
engine snapshots at submit time everything a job needs (the new token
ids, finished flag, cumulative token list), so the worker never reads
engine-mutated state; the only fields the worker writes
(``req.text`` / the legacy shim timestamps) are never touched by the
engine thread while the worker owns emission.  Worker exceptions are
re-raised on the engine thread at the next collect, never swallowed.

The queue is bounded (``maxsize``): if detokenization ever falls a full
queue behind, ``submit`` blocks the engine — backpressure, not
unbounded memory growth.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Callable, List, Optional

from repro.serving.params import RequestOutput


@dataclass
class _Job:
    """Everything one emission needs, snapshotted on the engine thread."""
    req: object                    # RequestState (worker writes .text only)
    new_token_ids: List[int]
    token_ids: List[int]           # cumulative output snapshot
    prompt_token_ids: List[int]
    finished: bool
    finish_reason: Optional[str]


class DetokWorker:
    """Single-threaded FIFO detokenize + fan-out worker (see module doc)."""

    def __init__(self, detokenizer: Optional[Callable], tracer,
                 maxsize: int = 1024):
        self.detokenizer = detokenizer
        self.tracer = tracer
        self._q: "queue.Queue" = queue.Queue(maxsize=maxsize)
        self._cv = threading.Condition()
        self._done: List[RequestOutput] = []   # processed, not yet collected
        self._submitted = 0
        self._processed = 0
        self._collected = 0
        self._exc: Optional[BaseException] = None
        self._closed = False
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="repro-detok")
        self._thread.start()

    # ------------------------------------------------------------ engine side
    @property
    def submitted(self) -> int:
        return self._submitted

    def pending(self) -> int:
        """Jobs submitted but not yet collected (0 = fully drained)."""
        return self._submitted - self._collected

    def submit(self, req, new_token_ids: List[int], finished: bool,
               finish_reason: Optional[str]) -> None:
        if self._closed:
            raise RuntimeError("DetokWorker is closed")
        self._submitted += 1
        self._q.put(_Job(req=req, new_token_ids=list(new_token_ids),
                         token_ids=list(req.output),
                         prompt_token_ids=list(req.prompt_token_ids),
                         finished=finished, finish_reason=finish_reason))

    def collect_upto(self, n: int) -> List[RequestOutput]:
        """Outputs of the first ``n`` submitted jobs not yet collected
        (FIFO; blocks until the worker has processed through job ``n``)."""
        take = min(n, self._submitted) - self._collected
        if take <= 0:
            self._raise_if_failed()
            return []
        with self._cv:
            self._cv.wait_for(
                lambda: self._processed >= self._collected + take
                or self._exc is not None)
            self._raise_if_failed()
            outs = self._done[:take]
            del self._done[:take]
            self._collected += take
            return outs

    def collect_all(self) -> List[RequestOutput]:
        return self.collect_upto(self._submitted)

    def close(self) -> List[RequestOutput]:
        """Drain every outstanding job, stop the thread, and return the
        remaining outputs (engine shutdown: no event is ever dropped)."""
        if self._closed:
            return []
        self._closed = True
        try:
            outs = self.collect_all()
        finally:
            self._q.put(None)                  # sentinel: thread exits
            self._thread.join(timeout=10.0)
        return outs

    def _raise_if_failed(self) -> None:
        if self._exc is not None:
            exc, self._exc = self._exc, None
            self._closed = True
            raise exc

    # ------------------------------------------------------------ worker side
    def _run(self) -> None:
        while True:
            job = self._q.get()
            if job is None:
                return
            try:
                out = self._build(job)
            except BaseException as e:        # re-raised at next collect
                with self._cv:
                    self._exc = e
                    self._cv.notify_all()
                return
            with self._cv:
                self._done.append(out)
                self._processed += 1
                self._cv.notify_all()

    def _build(self, job: _Job) -> RequestOutput:
        req = job.req
        text = new_text = ""
        if self.detokenizer is not None:
            with self.tracer.span("detokenize", cat="host",
                                  args={"tokens": len(job.new_token_ids)}):
                new_text = self.detokenizer(job.new_token_ids) \
                    if job.new_token_ids else ""
            req.text += new_text
            text = req.text
        if req.shim is not None:      # legacy Request: mirror timestamps
            req.shim.first_token_t = req.first_token_t
            req.shim.done_t = req.done_t
        return RequestOutput(
            request_id=req.rid, prompt_token_ids=job.prompt_token_ids,
            token_ids=job.token_ids, new_token_ids=job.new_token_ids,
            finished=job.finished, finish_reason=job.finish_reason,
            text=text, new_text=new_text)
