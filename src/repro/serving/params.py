"""Public request surface of the serving API (the vLLM-shaped half).

``SamplingParams`` travels with a request through admission, the legacy
per-token loop and the fused decode megastep — the engine lowers it to
padded per-slot device arrays (see ``core.sampling.sample_from_logits``).
``RequestOutput`` is what the engine emits back: one event per request per
engine step that produced tokens for it, carrying both the delta and the
cumulative generation, plus a ``finish_reason`` once the request ends.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

FINISH_STOP = "stop"          # hit a stop token id
FINISH_LENGTH = "length"      # generated max_tokens
FINISH_CAPACITY = "capacity"  # force-finished at block-table capacity
FINISH_ABORT = "aborted"      # caller cancelled via engine.abort()
FINISH_DEADLINE = "deadline"  # per-request deadline expired
FINISH_ERROR = "error"        # quarantined: poisoned dispatch / NaN row
FINISH_SHED = "shed"          # load-shed from a full waiting queue


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling controls.

    temperature: 0.0 => greedy argmax; > 0 scales logits before sampling.
    top_k:       keep only the k highest logits (0 disables).
    top_p:       nucleus sampling — keep the smallest set of tokens whose
                 probability mass reaches top_p (1.0 disables).
    seed:        per-request PRNG stream seed; None derives a stream from
                 the engine seed and the request id (still deterministic,
                 but tied to the engine instance).
    stop:        token ids that end the generation; the matched token is
                 included in the output and finish_reason is "stop".
    max_tokens:  generation budget; finish_reason "length" when reached.
    ttft_deadline_ms: wall-clock budget (from arrival) for the FIRST
                 token; a request still token-less past it finishes with
                 finish_reason "deadline" (None disables).
    deadline_ms: total wall-clock budget (from arrival) for the whole
                 request; enforced by the scheduler every step, whether
                 the request is waiting, mid-prefill, or decoding —
                 finish_reason "deadline", partial output kept (None
                 disables).
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None
    stop: List[int] = field(default_factory=list)
    max_tokens: int = 32
    ttft_deadline_ms: Optional[float] = None
    deadline_ms: Optional[float] = None

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError("temperature must be >= 0")
        if not (0.0 < self.top_p <= 1.0):
            raise ValueError("top_p must be in (0, 1]")
        if self.top_k < 0:
            raise ValueError("top_k must be >= 0")
        if self.max_tokens < 1:
            raise ValueError("max_tokens must be >= 1")
        for name in ("ttft_deadline_ms", "deadline_ms"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"{name} must be > 0 (or None)")


@dataclass
class RequestOutput:
    """One streamed event for a request.

    ``new_token_ids`` is the delta since the previous event for the same
    request; ``token_ids`` is the cumulative generation so far.  ``text``
    / ``new_text`` are filled only when the engine was given a
    detokenizer.  ``finish_reason`` is None while the request is running,
    else one of "stop" | "length" | "capacity" | "aborted" | "deadline"
    | "error" | "shed".
    """
    request_id: int
    prompt_token_ids: List[int]
    token_ids: List[int]
    new_token_ids: List[int]
    finished: bool = False
    finish_reason: Optional[str] = None
    text: str = ""
    new_text: str = ""
