"""Continuous-batching serving engine facade (the vLLM role in the paper).

The engine is a thin conductor over two halves:

* ``serving.scheduler.Scheduler`` — pure host policy: admission
  (watermark + prompt clamping), slot/block accounting, recompute-style
  preemption, capacity force-finishing, fused-horizon planning;
* ``serving.model_runner.ModelRunner`` — the device: paged KV pools,
  jitted prefill / per-token decode / fused megastep, CoW block copies,
  on-device per-slot sampling.

Requests enter with a ``SamplingParams`` (temperature / top_k / top_p /
seed / stop token ids / max_tokens) that is lowered to padded per-slot
device arrays, so one batch freely mixes greedy, temperature and
top-k/top-p requests — through *both* the legacy per-token loop
(``use_fused=False``, the bitwise-equivalence oracle) and the fused
decode megastep (default; one buffer-donated device call per multi-token
horizon, one host↔device round trip per dispatch).

Results stream back as ``RequestOutput`` deltas: ``step()`` returns the
events produced by that iteration and ``stream()`` yields them as
horizons complete, so callers see tokens long before the batch drains —
and ``add_request`` / ``add`` may be called while streaming (continuous
intake). ``run_until_done`` is retained as the drain-everything driver.

The pre-redesign surface — ``ServingEngine(cfg, params)`` plus the bare
``Request(prompt, max_new_tokens, temperature)`` — keeps working as a
deprecation shim for one release; new code should construct via
``serving.llm.LLM`` and speak ``SamplingParams`` / ``RequestOutput``.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence as SeqT

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.paged_cache import BlockAllocator
from repro.serving.model_runner import ModelRunner
from repro.serving.params import (FINISH_LENGTH, FINISH_STOP, RequestOutput,
                                  SamplingParams)
from repro.serving.scheduler import RequestState, Scheduler, Sequence


@dataclass
class Request:
    """Deprecated pre-``SamplingParams`` request record (one-release shim).

    Use ``engine.add(prompt, SamplingParams(...))`` instead; this maps
    onto it via ``add_request`` and keeps filling ``output`` in place.
    """
    rid: int
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    arrival: float = 0.0
    output: List[int] = field(default_factory=list)
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 8,
                 num_blocks: int = 512, max_blocks_per_seq: int = 64,
                 prefill_bucket: int = 64, rt: Optional[dict] = None,
                 seed: int = 0, use_fused: bool = True,
                 max_horizon: int = 8, detokenizer=None,
                 kv_cache_dtype: str = "bf16"):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.mb = max_blocks_per_seq
        self.prefill_bucket = prefill_bucket
        self.use_fused = use_fused
        self.max_horizon = max(1, max_horizon)
        self.detokenizer = detokenizer
        self.seed = seed
        self.metrics: Dict[str, float] = {
            "prompt_tokens": 0, "gen_tokens": 0, "preemptions": 0,
            "host_syncs": 0, "decode_dispatches": 0, "decode_steps": 0,
            "decode_time_s": 0.0, "truncated_prompts": 0,
            # dispatches after the first: excludes jit compile of the step
            "decode_warm_steps": 0, "decode_warm_time_s": 0.0}
        # sliding-window-only archs use a fixed ring cache: no block growth
        ring_only = bool(cfg.sliding_window) and not any(
            cfg.layer_kind(i) == "full" for i in range(cfg.num_layers))
        alloc = BlockAllocator(
            num_blocks, cfg.paging.block_size,
            enable_prefix_reuse=cfg.paging.enable_prefix_reuse,
            watermark_frac=cfg.paging.watermark_frac)
        self.scheduler = Scheduler(alloc, max_slots=max_slots,
                                   max_blocks_per_seq=max_blocks_per_seq,
                                   ring_only=ring_only, metrics=self.metrics)
        self.runner = ModelRunner(cfg, params, max_slots=max_slots,
                                  num_blocks=num_blocks,
                                  max_blocks_per_seq=max_blocks_per_seq,
                                  rt=rt, max_horizon=self.max_horizon,
                                  kv_cache_dtype=kv_cache_dtype)
        self.kv_cache_dtype = self.runner.kv_cache_dtype
        self._t0: Optional[float] = None
        self._next_rid = 0

    # ---------------------------------------------------- facade views
    @property
    def alloc(self) -> BlockAllocator:
        return self.scheduler.alloc

    @property
    def waiting(self) -> List[RequestState]:
        return self.scheduler.waiting

    @property
    def running(self) -> Dict[int, Sequence]:
        return self.scheduler.running

    @property
    def finished(self) -> List[RequestState]:
        return self.scheduler.finished

    @property
    def state(self):
        return self.runner.state

    @property
    def rt(self) -> dict:
        return self.runner.rt

    # ------------------------------------------------------------ intake
    def _base_key(self, rid: int, sp: SamplingParams) -> np.ndarray:
        """Per-request PRNG stream root: explicit seed wins; otherwise a
        stream derived from (engine seed, request id)."""
        if sp.seed is not None:
            k = jax.random.PRNGKey(sp.seed)
        else:
            k = jax.random.fold_in(jax.random.PRNGKey(self.seed), rid)
        return np.asarray(k, np.uint32)

    def add(self, prompt: SeqT[int],
            sampling_params: Optional[SamplingParams] = None,
            request_id: Optional[int] = None) -> int:
        """Queue a request (allowed while running / streaming). Returns
        the request id used in its ``RequestOutput`` events."""
        sp = sampling_params or SamplingParams()
        rid = self._next_rid if request_id is None else request_id
        self._next_rid = max(self._next_rid, rid) + 1
        rec = RequestState(rid=rid, prompt=list(prompt), sampling=sp,
                           base_key=self._base_key(rid, sp))
        self.scheduler.add(rec)
        return rid

    def add_request(self, req: Request) -> None:
        """Deprecated: wrap a legacy ``Request``; its ``output`` list is
        shared with the engine so old call sites keep reading results."""
        warnings.warn(
            "ServingEngine.add_request(Request(...)) is deprecated; use "
            "engine.add(prompt, SamplingParams(...)) or serving.llm.LLM",
            DeprecationWarning, stacklevel=2)
        sp = SamplingParams(temperature=req.temperature,
                            max_tokens=req.max_new_tokens)
        rec = RequestState(rid=req.rid, prompt=req.prompt, sampling=sp,
                           output=req.output, shim=req,
                           base_key=self._base_key(req.rid, sp))
        self._next_rid = max(self._next_rid, req.rid + 1)
        self.scheduler.add(rec)
        req.arrival = rec.arrival

    # ------------------------------------------------------------ outputs
    def _emit(self, req: RequestState, outs: List[RequestOutput]) -> None:
        if req.shim is not None:     # legacy Request: mirror timestamps
            req.shim.first_token_t = req.first_token_t
            req.shim.done_t = req.done_t
        new = list(req.output[req.emitted:])
        finished = req.finish_reason is not None
        if not new and not finished:
            return
        text = new_text = ""
        if self.detokenizer is not None:
            # incremental: only the delta is detokenized per event, the
            # cumulative text accumulates on the request record
            new_text = self.detokenizer(new) if new else ""
            req.text += new_text
            text = req.text
        outs.append(RequestOutput(
            request_id=req.rid, prompt_token_ids=req.prompt_token_ids,
            token_ids=list(req.output), new_token_ids=new,
            finished=finished, finish_reason=req.finish_reason,
            text=text, new_text=new_text))
        req.emitted = len(req.output)

    def _absorb(self, s: Sequence, toks, now: float,
                outs: List[RequestOutput]) -> None:
        """Fold sampled tokens into a sequence, honouring stop token ids
        and the max_tokens budget; finishing frees KV blocks immediately
        (tokens past a stop are discarded). Emits the delta event."""
        req = s.req
        for tok in toks:
            req.output.append(int(tok))
            s.last_token = int(tok)
            s.seq_len += 1
            self.metrics["gen_tokens"] += 1
            if req.first_token_t is None:
                req.first_token_t = now
            if int(tok) in req.sampling.stop:
                self.scheduler.finish(s, FINISH_STOP)
                break
            if req.tokens_remaining() <= 0:
                self.scheduler.finish(s, FINISH_LENGTH)
                break
        self._emit(req, outs)

    # ------------------------------------------------------------ prefill
    def _bucket(self, n: int) -> int:
        b = self.prefill_bucket
        return min(((n + b - 1) // b) * b, self.scheduler.cap_tokens)

    def _sampling_rows(self, recs: List[RequestState]) -> Dict[str, np.ndarray]:
        """Stack per-request SamplingParams into padded device-ready rows."""
        B = len(recs)
        arr = {"keys": np.zeros((B, 2), np.uint32),
               "counts": np.zeros((B,), np.int32),
               "temps": np.zeros((B,), np.float32),
               "top_ks": np.zeros((B,), np.int32),
               "top_ps": np.ones((B,), np.float32)}
        for i, r in enumerate(recs):
            if r is None:
                continue
            arr["keys"][i] = r.base_key
            arr["counts"][i] = len(r.output)
            arr["temps"][i] = r.sampling.temperature
            arr["top_ks"][i] = r.sampling.top_k
            arr["top_ps"][i] = r.sampling.top_p
        return arr

    def _slot_sampling(self) -> Dict[str, np.ndarray]:
        recs: List[Optional[RequestState]] = [None] * self.max_slots
        for slot, s in self.scheduler.running.items():
            recs[slot] = s.req
        return self._sampling_rows(recs)

    def _run_prefill(self, seqs: List[Sequence],
                     outs: List[RequestOutput]) -> None:
        maxlen = self._bucket(max(s.seq_len for s in seqs))
        logits = self.runner.prefill(seqs, maxlen)
        self.metrics["prompt_tokens"] += sum(s.seq_len for s in seqs)
        # first sampled token, per-request sampling streams
        nxt = self.runner.sample(logits, self._sampling_rows(
            [s.req for s in seqs]))
        self.metrics["host_syncs"] += 1
        now = time.perf_counter()
        for i, s in enumerate(seqs):
            self._absorb(s, [int(nxt[i])], now, outs)
        # leave device tables consistent with the host bookkeeping
        # (slots just prefilled or freed) instead of relying on the next
        # decode's sync.
        self.runner.sync_tables(self.scheduler.running)

    # ------------------------------------------------------------ decode
    def _record_decode_time(self, dt: float, steps: int) -> None:
        self.metrics["decode_time_s"] += dt
        if self.metrics["decode_dispatches"] > 1:    # past the compile call
            self.metrics["decode_warm_time_s"] += dt
            self.metrics["decode_warm_steps"] += steps

    def _prepare_dispatch(self, horizon: int) -> int:
        """Plan + pre-allocate one dispatch; returns the granted horizon
        (0 if nothing is runnable after preemption)."""
        h = self.scheduler.plan_horizon(horizon)
        if not self.scheduler.running or h == 0:
            return 0
        cow_pairs = self.scheduler.grow_for_horizon(h)
        if cow_pairs:
            self.runner.copy_cow(cow_pairs)
        self.runner.sync_tables(self.scheduler.running)
        return h

    def _decode_legacy(self, outs: List[RequestOutput]) -> None:
        """Oracle path: one token per dispatch, host-side readback each
        step — same planner, same sampling kernel as the fused path."""
        t0 = time.perf_counter()
        if self._prepare_dispatch(1) == 0:
            return
        toks = np.zeros((self.max_slots,), np.int32)
        for slot, s in self.scheduler.running.items():
            toks[slot] = s.last_token
        logits = self.runner.decode(toks)
        nxt = self.runner.sample(logits, self._slot_sampling())
        self.metrics["host_syncs"] += 1
        self.metrics["decode_dispatches"] += 1
        self.metrics["decode_steps"] += 1
        now = time.perf_counter()
        for slot in sorted(self.scheduler.running):
            self._absorb(self.scheduler.running[slot], [int(nxt[slot])],
                         now, outs)
        self._record_decode_time(time.perf_counter() - t0, 1)

    def _decode_fused(self, outs: List[RequestOutput]) -> None:
        t0 = time.perf_counter()
        h = self._prepare_dispatch(self.max_horizon)
        if h == 0:
            return
        toks = np.zeros((self.max_slots,), np.int32)
        active = np.zeros((self.max_slots,), bool)
        for slot, s in self.scheduler.running.items():
            toks[slot] = s.last_token
            active[slot] = True
        out_np = self.runner.megastep(toks, self._slot_sampling(), active, h)
        self.metrics["host_syncs"] += 1
        self.metrics["decode_dispatches"] += 1
        self.metrics["decode_steps"] += h
        now = time.perf_counter()
        for slot in sorted(self.scheduler.running):
            self._absorb(self.scheduler.running[slot],
                         out_np[:, slot].tolist(), now, outs)
        self._record_decode_time(time.perf_counter() - t0, h)

    # ------------------------------------------------------------ drive
    def step(self) -> List[RequestOutput]:
        """One engine iteration: admit, then decode for all running — a
        single token (legacy) or a fused multi-token horizon. Returns the
        ``RequestOutput`` deltas produced by this iteration."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        outs: List[RequestOutput] = []
        for req in self.scheduler.finish_at_capacity():
            self._emit(req, outs)    # free slots/blocks before admission
        admitted = self.scheduler.try_admit()
        if admitted:
            self._run_prefill(admitted, outs)
        for req in self.scheduler.finish_at_capacity():
            self._emit(req, outs)    # a fresh exactly-cap prefill may
        if not self.scheduler.running:  # already be at the table boundary
            return outs
        if self.use_fused:
            self._decode_fused(outs)
        else:
            self._decode_legacy(outs)
        return outs

    def stream(self, max_steps: int = 100000) -> Iterator[RequestOutput]:
        """Yield ``RequestOutput`` deltas as horizons complete — callers
        see first tokens while the batch is still running, and may keep
        calling ``add`` / ``add_request`` between events."""
        steps = 0
        while self.scheduler.has_work() and steps < max_steps:
            yield from self.step()
            steps += 1

    def run_until_done(self, max_steps: int = 10000) -> Dict[str, float]:
        steps = 0
        while self.scheduler.has_work() and steps < max_steps:
            self.step()
            steps += 1
        return self.report()

    def report(self) -> Dict[str, float]:
        """The paper's three numbers (+ fast-path and streaming counters)."""
        t1 = time.perf_counter()
        wall = max(t1 - (self._t0 or t1), 1e-9)
        fin = self.scheduler.finished
        n = len(fin)
        lat = float(np.mean([r.done_t - r.arrival for r in fin])) \
            if n else float("nan")
        ttft = float(np.mean([r.first_token_t - r.arrival for r in fin
                              if r.first_token_t is not None])) \
            if n else float("nan")
        total_toks = self.metrics["prompt_tokens"] + self.metrics["gen_tokens"]
        d_steps = max(self.metrics["decode_steps"], 1)
        # prefer warm (post-compile) per-step latency when measurable
        if self.metrics["decode_warm_steps"]:
            step_lat = (self.metrics["decode_warm_time_s"]
                        / self.metrics["decode_warm_steps"])
        else:
            step_lat = self.metrics["decode_time_s"] / d_steps
        return {
            "latency_s": lat,
            "ttft_s": ttft,
            "throughput_req_s": n / wall,
            "throughput_tok_s": total_toks / wall,
            "generate_tok_s": self.metrics["gen_tokens"] / wall,
            "preemptions": self.metrics["preemptions"],
            "block_utilization": self.alloc.utilization(),
            "blocks_reused": self.alloc.stats["reused"],
            # pool memory: the figure kv_cache_dtype="int8" halves vs bf16
            "kv_pool_bytes": self.runner.kv_pool_bytes(),
            "kv_bytes_per_token": self.runner.kv_bytes_per_token(),
            "wall_s": wall,
            "host_syncs": self.metrics["host_syncs"],
            "decode_dispatches": self.metrics["decode_dispatches"],
            "decode_steps": self.metrics["decode_steps"],
            "decode_step_latency_us": step_lat * 1e6,
            # decode-path syncs only (one per dispatch): prefill-wave syncs
            # are excluded, so legacy reads exactly 1.0 and fused 1/horizon
            "syncs_per_decode_step":
                self.metrics["decode_dispatches"] / d_steps,
        }
