"""Continuous-batching serving engine (the vLLM role in the paper).

Dynamic scheduling happens in Python; the *device step* is static-shape
(padded slot arrays) so XLA never recompiles:

* fixed ``max_slots`` decode slots; a slot holds one running sequence,
* paged KV blocks come from the ref-counted ``BlockAllocator``
  (prefix reuse + copy-on-write, paper §III.C "cache sharing and reuse"),
* admission: prompts are prefilled (padded to a bucket length) when enough
  free blocks exist (watermark), else queued; decode preempts nothing —
  out-of-blocks preempts the *youngest* sequence back to the queue
  (recompute-style preemption, like vLLM),
* metrics match the paper's Fig. 2: latency, all-throughput (req/s,
  tok/s), generation throughput (tok/s).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.paged_cache import BlockAllocator, OutOfBlocksError
from repro.models import transformer as T
from repro.serving.sampler import sample


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    arrival: float = 0.0
    # filled by the engine
    output: List[int] = field(default_factory=list)
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None


@dataclass
class _Seq:
    req: Request
    slot: int
    block_ids: List[int]
    seq_len: int                      # tokens in cache (incl. last fed)
    last_token: int


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 8,
                 num_blocks: int = 512, max_blocks_per_seq: int = 64,
                 prefill_bucket: int = 64, rt: Optional[dict] = None,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.mb = max_blocks_per_seq
        self.prefill_bucket = prefill_bucket
        self.rt = dict(rt or {})
        self.alloc = BlockAllocator(
            num_blocks, cfg.paging.block_size,
            enable_prefix_reuse=cfg.paging.enable_prefix_reuse,
            watermark_frac=cfg.paging.watermark_frac)
        self.state = T.make_decode_state(cfg, max_slots, num_blocks, self.mb,
                                         dtype=jnp.float32)
        self.waiting: List[Request] = []
        self.running: Dict[int, _Seq] = {}
        self.finished: List[Request] = []
        self.free_slots = list(range(max_slots - 1, -1, -1))
        self.key = jax.random.PRNGKey(seed)
        self.metrics: Dict[str, float] = {"prompt_tokens": 0,
                                          "gen_tokens": 0, "preemptions": 0}
        self._t0: Optional[float] = None

        self._prefill = jax.jit(
            lambda p, s, b: T.prefill(cfg, p, s, b, None, self.rt))
        self._decode = jax.jit(
            lambda p, s, t: T.decode_step(cfg, p, s, t, None, self.rt))

    # ------------------------------------------------------------ intake
    def add_request(self, req: Request) -> None:
        req.arrival = time.perf_counter()
        self.waiting.append(req)

    # ------------------------------------------------------------ admission
    def _bucket(self, n: int) -> int:
        b = self.prefill_bucket
        return min(((n + b - 1) // b) * b, self.mb * self.alloc.block_size)

    def _try_admit(self) -> None:
        admitted: List[_Seq] = []
        while self.waiting and self.free_slots:
            req = self.waiting[0]
            need = (len(req.prompt) + self.alloc.block_size - 1) \
                // self.alloc.block_size + 1
            if not self.alloc.can_allocate(need):
                break
            self.waiting.pop(0)
            block_ids, _reused = self.alloc.allocate_prompt(req.prompt)
            slot = self.free_slots.pop()
            seq = _Seq(req=req, slot=slot, block_ids=block_ids,
                       seq_len=len(req.prompt), last_token=req.prompt[-1])
            self.running[slot] = seq
            admitted.append(seq)
        if admitted:
            self._run_prefill(admitted)

    def _run_prefill(self, seqs: List[_Seq]) -> None:
        bs = self.alloc.block_size
        maxlen = self._bucket(max(s.seq_len for s in seqs))
        B = len(seqs)
        toks = np.zeros((B, maxlen), np.int32)
        lens = np.zeros((B,), np.int32)
        for i, s in enumerate(seqs):
            toks[i, :s.seq_len] = s.req.prompt
            lens[i] = s.seq_len
        # temporary contiguous state for the prefill batch, then scatter
        # into the live engine state at each sequence's slot/table.
        sub = dict(self.state)
        bt = np.zeros((B, self.mb), np.int32)
        for i, s in enumerate(seqs):
            bt[i, :len(s.block_ids)] = s.block_ids
        sub["block_table"] = jnp.asarray(bt) if "block_table" in sub else None
        sub = {k: v for k, v in sub.items() if v is not None}
        # prefill writes pools in-place via the shared pool arrays: pools are
        # engine-global, per-slot state rows are gathered/scattered below.
        per_seq = {}
        for k in ("ssm_h", "ssm_conv", "lru_h", "rec_conv"):
            if k in sub:
                per_seq[k] = sub[k][:, [s.slot for s in seqs]]
                sub[k] = per_seq[k]
        sub["seq_lens"] = jnp.asarray(lens)
        batch = {"tokens": jnp.asarray(toks), "ctx_lens": jnp.asarray(lens)}
        logits, sub = self._prefill(self.params, sub, batch)
        # scatter updated state back
        for k in ("k_pool", "v_pool"):
            if k in sub:
                self.state[k] = sub[k]
        for k in per_seq:
            self.state[k] = self.state[k].at[:, [s.slot for s in seqs]].set(
                sub[k])
        self.metrics["prompt_tokens"] += int(lens.sum())
        # first sampled token
        self.key, sk = jax.random.split(self.key)
        nxt = sample(logits, sk, [s.req.temperature for s in seqs])
        now = time.perf_counter()
        for i, s in enumerate(seqs):
            tok = int(nxt[i])
            s.req.output.append(tok)
            s.req.first_token_t = now
            s.last_token = tok
            s.seq_len += 1
            self.metrics["gen_tokens"] += 1
            self._maybe_finish(s)

    # ------------------------------------------------------------ decode
    def _sync_tables(self) -> None:
        bt = np.zeros((self.max_slots, self.mb), np.int32)
        sl = np.zeros((self.max_slots,), np.int32)
        for slot, s in self.running.items():
            bt[slot, :len(s.block_ids)] = s.block_ids
            sl[slot] = s.seq_len
        if "block_table" in self.state:
            self.state["block_table"] = jnp.asarray(bt)
        self.state["seq_lens"] = jnp.asarray(sl)

    def _grow_blocks(self, s: _Seq) -> None:
        bs = self.alloc.block_size
        pos = s.seq_len - 1                      # position the new token writes
        if self.cfg.sliding_window and not any(
                self.cfg.layer_kind(i) == "full"
                for i in range(self.cfg.num_layers)):
            return                               # ring cache: fixed blocks
        s.block_ids, _cow = self.alloc.append_slot(s.block_ids, pos)

    def step(self) -> None:
        """One engine iteration: admit, then one decode for all running."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        self._try_admit()
        if not self.running:
            return
        # grow block tables (may preempt on OOM)
        for slot in sorted(self.running):
            s = self.running[slot]
            try:
                self._grow_blocks(s)
            except OutOfBlocksError:
                self._preempt_youngest()
        self._sync_tables()
        toks = np.zeros((self.max_slots,), np.int32)
        for slot, s in self.running.items():
            toks[slot] = s.last_token
        logits, self.state = self._decode(self.params, self.state,
                                          jnp.asarray(toks))
        self.key, sk = jax.random.split(self.key)
        temps = [self.running[s].req.temperature if s in self.running else 0.0
                 for s in range(self.max_slots)]
        nxt = sample(logits, sk, temps)
        now = time.perf_counter()
        for slot in list(self.running):
            s = self.running[slot]
            tok = int(nxt[slot])
            s.req.output.append(tok)
            s.last_token = tok
            s.seq_len += 1
            self.metrics["gen_tokens"] += 1
            self._maybe_finish(s)

    def _maybe_finish(self, s: _Seq) -> None:
        if len(s.req.output) >= s.req.max_new_tokens:
            s.req.done_t = time.perf_counter()
            self.finished.append(s.req)
            self.alloc.free_sequence(s.block_ids)
            del self.running[s.slot]
            self.free_slots.append(s.slot)

    def _preempt_youngest(self) -> None:
        slot = max(self.running,
                   key=lambda sl: self.running[sl].req.arrival)
        s = self.running.pop(slot)
        self.alloc.free_sequence(s.block_ids)
        self.free_slots.append(slot)
        self.metrics["preemptions"] += 1
        # recompute-style preemption: requeue with prompt+generated prefix
        s.req.prompt = list(s.req.prompt) + list(s.req.output)
        self.waiting.insert(0, s.req)

    # ------------------------------------------------------------ drive
    def run_until_done(self, max_steps: int = 10000) -> Dict[str, float]:
        steps = 0
        while (self.waiting or self.running) and steps < max_steps:
            self.step()
            steps += 1
        return self.report()

    def report(self) -> Dict[str, float]:
        """The paper's three numbers."""
        t1 = time.perf_counter()
        wall = max(t1 - (self._t0 or t1), 1e-9)
        n = len(self.finished)
        lat = float(np.mean([r.done_t - r.arrival for r in self.finished])) \
            if n else float("nan")
        total_toks = self.metrics["prompt_tokens"] + self.metrics["gen_tokens"]
        return {
            "latency_s": lat,
            "throughput_req_s": n / wall,
            "throughput_tok_s": total_toks / wall,
            "generate_tok_s": self.metrics["gen_tokens"] / wall,
            "preemptions": self.metrics["preemptions"],
            "block_utilization": self.alloc.utilization(),
            "blocks_reused": self.alloc.stats["reused"],
            "wall_s": wall,
        }
