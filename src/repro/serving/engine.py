"""Continuous-batching serving engine (the vLLM role in the paper).

Dynamic scheduling happens in Python; the *device step* is static-shape
(padded slot arrays) so XLA never recompiles:

* fixed ``max_slots`` decode slots; a slot holds one running sequence,
* paged KV blocks come from the ref-counted ``BlockAllocator``
  (prefix reuse + copy-on-write, paper §III.C "cache sharing and reuse"),
* admission: prompts are prefilled (padded to a bucket length) when enough
  free blocks exist (watermark), else queued; decode preempts nothing —
  out-of-blocks preempts the *youngest* sequence back to the queue
  (recompute-style preemption, like vLLM),
* metrics match the paper's Fig. 2: latency, all-throughput (req/s,
  tok/s), generation throughput (tok/s).

Decode fast path (``use_fused=True``, the default): instead of one jitted
call + one blocking host sync per generated token, the engine dispatches a
fused **decode megastep** — a single buffer-donated device call that runs
KV scatter + paged attention + logits + sampling for up to ``max_horizon``
tokens (``lax.fori_loop`` with a *dynamic* trip count, so no recompiles).
The host plans ``steps_until_boundary`` = min over running sequences of
(tokens remaining, horizon cap), pre-allocates every KV block the horizon
will touch (copy-on-write resolved by a device-side block copy, never via
host numpy), dispatches exactly that many fused steps, and reads back one
``[horizon, slots]`` token buffer — a single host↔device round trip per
horizon. The legacy per-token loop is kept (``use_fused=False``) as the
bitwise-equivalence oracle and bench baseline.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.paged_cache import (BlockAllocator, OutOfBlocksError,
                                    copy_blocks)
from repro.models import transformer as T
from repro.serving.sampler import sample


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    arrival: float = 0.0
    # filled by the engine
    output: List[int] = field(default_factory=list)
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None


@dataclass
class _Seq:
    req: Request
    slot: int
    block_ids: List[int]
    seq_len: int                      # tokens in cache (incl. last fed)
    last_token: int


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 8,
                 num_blocks: int = 512, max_blocks_per_seq: int = 64,
                 prefill_bucket: int = 64, rt: Optional[dict] = None,
                 seed: int = 0, use_fused: bool = True,
                 max_horizon: int = 8):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.mb = max_blocks_per_seq
        self.prefill_bucket = prefill_bucket
        self.rt = dict(rt or {})
        self.use_fused = use_fused
        self.max_horizon = max(1, max_horizon)
        self.alloc = BlockAllocator(
            num_blocks, cfg.paging.block_size,
            enable_prefix_reuse=cfg.paging.enable_prefix_reuse,
            watermark_frac=cfg.paging.watermark_frac)
        self.state = T.make_decode_state(cfg, max_slots, num_blocks, self.mb,
                                         dtype=jnp.float32)
        self.waiting: List[Request] = []
        self.running: Dict[int, _Seq] = {}
        self.finished: List[Request] = []
        self.free_slots = list(range(max_slots - 1, -1, -1))
        self.key = jax.random.PRNGKey(seed)
        self.metrics: Dict[str, float] = {
            "prompt_tokens": 0, "gen_tokens": 0, "preemptions": 0,
            "host_syncs": 0, "decode_dispatches": 0, "decode_steps": 0,
            "decode_time_s": 0.0, "truncated_prompts": 0,
            # dispatches after the first: excludes jit compile of the step
            "decode_warm_steps": 0, "decode_warm_time_s": 0.0}
        self._t0: Optional[float] = None
        # sliding-window-only archs use a fixed ring cache: no block growth
        self._ring_only = bool(cfg.sliding_window) and not any(
            cfg.layer_kind(i) == "full" for i in range(cfg.num_layers))
        # hard per-sequence KV capacity: the block table is mb entries wide
        self._cap_tokens = self.mb * self.alloc.block_size

        self._prefill = jax.jit(
            lambda p, s, b: T.prefill(cfg, p, s, b, None, self.rt))
        self._decode = jax.jit(
            lambda p, s, t: T.decode_step(cfg, p, s, t, None, self.rt))
        # the fused megastep donates the whole decode state: the KV pools
        # are updated in place instead of copied every token.
        self._megastep = jax.jit(
            lambda p, s, t, tm, a, n, k: T.decode_megastep(
                cfg, p, s, t, tm, a, n, k,
                max_horizon=self.max_horizon, ctx=None, rt=self.rt),
            donate_argnums=(1,))

    # ------------------------------------------------------------ intake
    def add_request(self, req: Request) -> None:
        req.arrival = time.perf_counter()
        self.waiting.append(req)

    # ------------------------------------------------------------ admission
    def _bucket(self, n: int) -> int:
        b = self.prefill_bucket
        return min(((n + b - 1) // b) * b, self.mb * self.alloc.block_size)

    def _try_admit(self) -> None:
        admitted: List[_Seq] = []
        while self.waiting and self.free_slots:
            req = self.waiting[0]
            if len(req.prompt) > self._cap_tokens:
                # prompt would overflow the mb-wide block table: clamp it
                # instead of crashing the prefill scatter. An exactly-cap
                # prompt still fits (it prefills, yields one token, then
                # force-finishes), so requeued preempted sequences — whose
                # prompt+output never exceeds cap — are never clamped and
                # keep their full generated context.
                req.prompt = req.prompt[:self._cap_tokens]
                self.metrics["truncated_prompts"] += 1
            need = (len(req.prompt) + self.alloc.block_size - 1) \
                // self.alloc.block_size + 1
            if not self.alloc.can_allocate(need):
                break
            self.waiting.pop(0)
            block_ids, _reused = self.alloc.allocate_prompt(req.prompt)
            slot = self.free_slots.pop()
            seq = _Seq(req=req, slot=slot, block_ids=block_ids,
                       seq_len=len(req.prompt), last_token=req.prompt[-1])
            self.running[slot] = seq
            admitted.append(seq)
        if admitted:
            self._run_prefill(admitted)

    def _run_prefill(self, seqs: List[_Seq]) -> None:
        maxlen = self._bucket(max(s.seq_len for s in seqs))
        B = len(seqs)
        toks = np.zeros((B, maxlen), np.int32)
        lens = np.zeros((B,), np.int32)
        for i, s in enumerate(seqs):
            toks[i, :s.seq_len] = s.req.prompt
            lens[i] = s.seq_len
        # temporary contiguous state for the prefill batch, then scatter
        # into the live engine state at each sequence's slot/table.
        sub = dict(self.state)
        bt = np.zeros((B, self.mb), np.int32)
        for i, s in enumerate(seqs):
            bt[i, :len(s.block_ids)] = s.block_ids
        sub["block_table"] = jnp.asarray(bt) if "block_table" in sub else None
        sub = {k: v for k, v in sub.items() if v is not None}
        # prefill writes pools in-place via the shared pool arrays: pools are
        # engine-global, per-slot state rows are gathered/scattered below.
        per_seq = {}
        for k in ("ssm_h", "ssm_conv", "lru_h", "rec_conv"):
            if k in sub:
                per_seq[k] = sub[k][:, [s.slot for s in seqs]]
                sub[k] = per_seq[k]
        sub["seq_lens"] = jnp.asarray(lens)
        batch = {"tokens": jnp.asarray(toks), "ctx_lens": jnp.asarray(lens)}
        logits, sub = self._prefill(self.params, sub, batch)
        # scatter updated state back
        for k in ("k_pool", "v_pool"):
            if k in sub:
                self.state[k] = sub[k]
        for k in per_seq:
            self.state[k] = self.state[k].at[:, [s.slot for s in seqs]].set(
                sub[k])
        self.metrics["prompt_tokens"] += int(lens.sum())
        # first sampled token
        self.key, sk = jax.random.split(self.key)
        nxt = sample(logits, sk, [s.req.temperature for s in seqs])
        self.metrics["host_syncs"] += 1
        now = time.perf_counter()
        for i, s in enumerate(seqs):
            tok = int(nxt[i])
            s.req.output.append(tok)
            s.req.first_token_t = now
            s.last_token = tok
            s.seq_len += 1
            self.metrics["gen_tokens"] += 1
            self._maybe_finish(s)
        # leave self.state consistent with the host bookkeeping (seq_lens /
        # block_table rows for the slots just prefilled or freed) instead of
        # relying on the next decode's _sync_tables.
        self._sync_tables()

    # ------------------------------------------------------------ decode
    def _sync_tables(self) -> None:
        bt = np.zeros((self.max_slots, self.mb), np.int32)
        sl = np.zeros((self.max_slots,), np.int32)
        for slot, s in self.running.items():
            bt[slot, :len(s.block_ids)] = s.block_ids
            sl[slot] = s.seq_len
        if "block_table" in self.state:
            self.state["block_table"] = jnp.asarray(bt)
        self.state["seq_lens"] = jnp.asarray(sl)

    def _grow_blocks(self, s: _Seq, num_tokens: int = 1):
        """Ensure KV capacity for the next ``num_tokens`` writes; returns
        the (src, dst) CoW block pair (device copy pending) or None."""
        if self._ring_only:
            return None                          # ring cache: fixed blocks
        pos = s.seq_len - 1                      # position the next write hits
        s.block_ids, cow = self.alloc.grow(s.block_ids, pos, num_tokens)
        return cow

    def _writes_left(self, s: _Seq) -> int:
        """Tokens the sequence can still decode before its block table is
        full (next write position is seq_len - 1)."""
        if self._ring_only:
            return 10**9                         # ring slots wrap forever
        return self._cap_tokens - (s.seq_len - 1)

    def _finish_at_capacity(self) -> None:
        """Force-finish sequences whose next KV write would overflow the
        ``max_blocks_per_seq``-wide block table (output is truncated)."""
        for slot in list(self.running):
            if self._writes_left(self.running[slot]) <= 0:
                self._finish(self.running[slot])

    def step(self) -> None:
        """One engine iteration: admit, then decode for all running —
        a single token (legacy) or a fused multi-token horizon."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        self._finish_at_capacity()       # free slots/blocks before admission
        self._try_admit()
        self._finish_at_capacity()       # a fresh exactly-cap prefill may
        if not self.running:             # already be at the table boundary
            return
        if self.use_fused:
            self._decode_fused()
        else:
            self._decode_legacy()

    # -- legacy per-token loop (oracle + bench baseline) -----------------
    def _decode_legacy(self) -> None:
        t0 = time.perf_counter()
        # grow block tables (may preempt on OOM; retry growth after a
        # preemption frees blocks — otherwise this sequence would decode
        # through a zero-padded block-table row and corrupt block 0)
        for slot in sorted(self.running):
            s = self.running.get(slot)
            if s is None:                        # preempted earlier this pass
                continue
            cow = None
            while slot in self.running:
                try:
                    cow = self._grow_blocks(s)
                    break
                except OutOfBlocksError:
                    self._preempt_youngest()     # may preempt s itself
            if slot not in self.running:
                continue
            if cow is not None:
                self._copy_cow([cow])
        if not self.running:
            return
        self._sync_tables()
        toks = np.zeros((self.max_slots,), np.int32)
        for slot, s in self.running.items():
            toks[slot] = s.last_token
        logits, self.state = self._decode(self.params, self.state,
                                          jnp.asarray(toks))
        self.key, sk = jax.random.split(self.key)
        temps = [self.running[s].req.temperature if s in self.running else 0.0
                 for s in range(self.max_slots)]
        nxt = sample(logits, sk, temps)
        self.metrics["host_syncs"] += 1
        self.metrics["decode_dispatches"] += 1
        self.metrics["decode_steps"] += 1
        now = time.perf_counter()
        for slot in list(self.running):
            s = self.running[slot]
            tok = int(nxt[slot])
            s.req.output.append(tok)
            s.last_token = tok
            s.seq_len += 1
            self.metrics["gen_tokens"] += 1
            self._maybe_finish(s)
        self._record_decode_time(time.perf_counter() - t0, 1)

    def _record_decode_time(self, dt: float, steps: int) -> None:
        self.metrics["decode_time_s"] += dt
        if self.metrics["decode_dispatches"] > 1:    # past the compile call
            self.metrics["decode_warm_time_s"] += dt
            self.metrics["decode_warm_steps"] += steps

    # -- fused megastep path ---------------------------------------------
    def _plan_horizon(self) -> int:
        """steps_until_boundary: the longest horizon every running sequence
        can decode without host intervention — bounded by tokens remaining
        (finish boundary) and by free KV blocks (allocation boundary).
        Preempts the youngest sequence if even a single step cannot fit."""
        while self.running:
            h = min(self.max_horizon,
                    min(min(s.req.max_new_tokens - len(s.req.output),
                            self._writes_left(s))
                        for s in self.running.values()))
            h = max(1, h)
            if self._ring_only:
                return h
            while h >= 1:
                need = sum(
                    self.alloc.blocks_needed(s.block_ids, s.seq_len - 1, h)
                    for s in self.running.values())
                if need <= self.alloc.num_free:
                    return h
                h -= 1                   # linear: blocks_needed is monotone
            self._preempt_youngest()
        return 0

    def _copy_cow(self, pairs) -> None:
        """Resolve copy-on-write on device: block contents never visit the
        host. pairs: [(src_block, dst_block), ...]. Padded to a fixed
        ``max_slots`` length so ``copy_blocks`` compiles once, not once per
        CoW batch size. Padding entries are self-copies of the first src
        block: a pad index can never collide with a real dst (dst blocks
        are freshly allocated, src blocks are still live), so the scatter
        stays duplicate-free on every real destination."""
        pad = (pairs[0][0],) * (self.max_slots - len(pairs))
        src = np.asarray([p[0] for p in pairs] + list(pad), np.int32)
        dst = np.asarray([p[1] for p in pairs] + list(pad), np.int32)
        self.state["k_pool"] = copy_blocks(self.state["k_pool"], src, dst)
        self.state["v_pool"] = copy_blocks(self.state["v_pool"], src, dst)

    def _decode_fused(self) -> None:
        t0 = time.perf_counter()
        h = self._plan_horizon()
        if not self.running or h == 0:
            return
        # pre-allocate every block the horizon touches; CoW via device copy
        cow_pairs = []
        for slot in sorted(self.running):
            s = self.running[slot]
            cow = self._grow_blocks(s, h)        # cannot raise: h was planned
            if cow is not None:
                cow_pairs.append(cow)
        if cow_pairs:
            self._copy_cow(cow_pairs)
        self._sync_tables()
        toks = np.zeros((self.max_slots,), np.int32)
        temps = np.zeros((self.max_slots,), np.float32)
        active = np.zeros((self.max_slots,), bool)
        for slot, s in self.running.items():
            toks[slot] = s.last_token
            temps[slot] = s.req.temperature
            active[slot] = True
        out, self.state, self.key = self._megastep(
            self.params, self.state, jnp.asarray(toks), jnp.asarray(temps),
            jnp.asarray(active), jnp.int32(h), self.key)
        out_np = np.asarray(out[:h])             # the ONE host sync
        self.metrics["host_syncs"] += 1
        self.metrics["decode_dispatches"] += 1
        self.metrics["decode_steps"] += h
        for slot in list(self.running):
            s = self.running[slot]
            for t in range(h):
                tok = int(out_np[t, slot])
                s.req.output.append(tok)
                s.last_token = tok
                s.seq_len += 1
                self.metrics["gen_tokens"] += 1
            self._maybe_finish(s)
        self._record_decode_time(time.perf_counter() - t0, h)

    def _finish(self, s: _Seq) -> None:
        s.req.done_t = time.perf_counter()
        self.finished.append(s.req)
        self.alloc.free_sequence(s.block_ids)
        del self.running[s.slot]
        self.free_slots.append(s.slot)

    def _maybe_finish(self, s: _Seq) -> None:
        if len(s.req.output) >= s.req.max_new_tokens:
            self._finish(s)

    def _preempt_youngest(self) -> None:
        slot = max(self.running,
                   key=lambda sl: self.running[sl].req.arrival)
        s = self.running.pop(slot)
        self.alloc.free_sequence(s.block_ids)
        self.free_slots.append(slot)
        self.metrics["preemptions"] += 1
        # recompute-style preemption: requeue with prompt+generated prefix
        s.req.prompt = list(s.req.prompt) + list(s.req.output)
        self.waiting.insert(0, s.req)

    # ------------------------------------------------------------ drive
    def run_until_done(self, max_steps: int = 10000) -> Dict[str, float]:
        steps = 0
        while (self.waiting or self.running) and steps < max_steps:
            self.step()
            steps += 1
        return self.report()

    def report(self) -> Dict[str, float]:
        """The paper's three numbers (+ fast-path counters)."""
        t1 = time.perf_counter()
        wall = max(t1 - (self._t0 or t1), 1e-9)
        n = len(self.finished)
        lat = float(np.mean([r.done_t - r.arrival for r in self.finished])) \
            if n else float("nan")
        total_toks = self.metrics["prompt_tokens"] + self.metrics["gen_tokens"]
        d_steps = max(self.metrics["decode_steps"], 1)
        # prefer warm (post-compile) per-step latency when measurable
        if self.metrics["decode_warm_steps"]:
            step_lat = (self.metrics["decode_warm_time_s"]
                        / self.metrics["decode_warm_steps"])
        else:
            step_lat = self.metrics["decode_time_s"] / d_steps
        return {
            "latency_s": lat,
            "throughput_req_s": n / wall,
            "throughput_tok_s": total_toks / wall,
            "generate_tok_s": self.metrics["gen_tokens"] / wall,
            "preemptions": self.metrics["preemptions"],
            "block_utilization": self.alloc.utilization(),
            "blocks_reused": self.alloc.stats["reused"],
            "wall_s": wall,
            "host_syncs": self.metrics["host_syncs"],
            "decode_dispatches": self.metrics["decode_dispatches"],
            "decode_steps": self.metrics["decode_steps"],
            "decode_step_latency_us": step_lat * 1e6,
            # decode-path syncs only (one per dispatch): prefill-wave syncs
            # are excluded, so legacy reads exactly 1.0 and fused 1/horizon
            "syncs_per_decode_step":
                self.metrics["decode_dispatches"] / d_steps,
        }
