"""Continuous-batching serving engine facade (the vLLM role in the paper).

The engine is a thin conductor over two halves:

* ``serving.scheduler.Scheduler`` — pure host policy: admission
  (watermark + prompt clamping), slot/block accounting, recompute-style
  preemption, capacity force-finishing, and the per-iteration token
  budget plan (``plan_step``): running decodes packed first
  (decode-priority, so inter-token latency stays bounded at O(chunk)
  instead of O(longest prompt)), then prefill *chunks* of
  partially-admitted prompts into the remaining
  ``max_num_batched_tokens``, with KV blocks allocated incrementally
  per chunk;
* ``serving.model_runner.ModelRunner`` — the device: paged KV pools,
  the fixed-shape ``[1, chunk_tokens]`` chunk-prefill executable
  (compiled ONCE regardless of prompt length or wave composition),
  jitted per-token decode / fused megastep, CoW block copies,
  on-device per-slot sampling.

``enable_chunked_prefill=False`` (or an arch whose prefill state cannot
yet re-enter mid-prompt: SSM / recurrent / sliding-ring stacks)
restores the stop-the-world whole-prompt wave — retained as the parity
oracle: chunked greedy serving is token-exact against it on the
reduced configs for both the bf16 and int8 KV pools.

With ``enable_unified_step=True`` (default; needs chunked mode and
``use_fused``) a mixed iteration — decodes interleaved with a prefill
chunk — executes as ONE donated device dispatch: the decode step, the
chunk (through the dynamic-offset chunk-flash path) and every row's
sampling fused under one jit, one ``[max_slots + 1]`` token readback.
``enable_unified_step=False`` keeps the two-call execute (decode
dispatch, then chunk dispatch(es), then a first-token sample dispatch)
as the unified path's token-exact / bitwise-sampling parity oracle;
``report()['device_dispatches_per_step']`` shows the difference
(1.0 unified vs ~2-3 two-call in the steady mixed state).

Requests enter with a ``SamplingParams`` (temperature / top_k / top_p /
seed / stop token ids / max_tokens) that is lowered to padded per-slot
device arrays, so one batch freely mixes greedy, temperature and
top-k/top-p requests — through *both* the legacy per-token loop
(``use_fused=False``, the bitwise-equivalence oracle) and the fused
decode megastep (default; one buffer-donated device call per multi-token
horizon, one host↔device round trip per dispatch).

Results stream back as ``RequestOutput`` deltas: ``step()`` returns the
events produced by that iteration and ``stream()`` yields them as
horizons complete, so callers see tokens long before the batch drains —
and ``add_request`` / ``add`` may be called while streaming (continuous
intake). ``run_until_done`` is retained as the drain-everything driver.

The pre-redesign surface — ``ServingEngine(cfg, params)`` plus the bare
``Request(prompt, max_new_tokens, temperature)`` — keeps working as a
deprecation shim for one release; new code should construct via
``serving.llm.LLM`` and speak ``SamplingParams`` / ``RequestOutput``.
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence as SeqT

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.paged_cache import BlockAllocator
from repro.models import transformer as T
from repro.serving.model_runner import ModelRunner
from repro.serving.params import (FINISH_LENGTH, FINISH_STOP, RequestOutput,
                                  SamplingParams)
from repro.serving.scheduler import (PrefillChunk, RequestState, Scheduler,
                                     Sequence, StepPlan)


@dataclass
class Request:
    """Deprecated pre-``SamplingParams`` request record (one-release shim).

    Use ``engine.add(prompt, SamplingParams(...))`` instead; this maps
    onto it via ``add_request`` and keeps filling ``output`` in place.
    """
    rid: int
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    arrival: float = 0.0
    output: List[int] = field(default_factory=list)
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 8,
                 num_blocks: int = 512, max_blocks_per_seq: int = 64,
                 prefill_bucket: int = 64, rt: Optional[dict] = None,
                 seed: int = 0, use_fused: bool = True,
                 max_horizon: int = 8, detokenizer=None,
                 kv_cache_dtype: str = "bf16",
                 max_num_batched_tokens: int = 256,
                 enable_chunked_prefill: bool = True,
                 enable_unified_step: bool = True):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.mb = max_blocks_per_seq
        self.prefill_bucket = prefill_bucket
        self.use_fused = use_fused
        self.max_horizon = max(1, max_horizon)
        self.detokenizer = detokenizer
        self.seed = seed
        self.metrics: Dict[str, float] = {
            "prompt_tokens": 0, "gen_tokens": 0, "preemptions": 0,
            "host_syncs": 0, "decode_dispatches": 0, "decode_steps": 0,
            "decode_time_s": 0.0, "truncated_prompts": 0,
            # dispatches after the first: excludes jit compile of the step
            "decode_warm_steps": 0, "decode_warm_time_s": 0.0,
            "timed_decode_dispatches": 0,
            "prefill_chunks": 0, "plan_steps": 0, "budget_tokens_used": 0,
            # device calls per engine iteration (the unified-dispatch
            # figure): work_steps counts iterations that dispatched at all
            "device_dispatches": 0, "work_steps": 0}
        # sliding-window-only archs use a fixed ring cache: no block growth
        ring_only = bool(cfg.sliding_window) and not any(
            cfg.layer_kind(i) == "full" for i in range(cfg.num_layers))
        # chunked prefill needs every layer's prefill state to live in the
        # paged pool; SSM / recurrent / ring archs keep the oracle path
        self.chunked = bool(enable_chunked_prefill) \
            and T.supports_chunked_prefill(cfg)
        alloc = BlockAllocator(
            num_blocks, cfg.paging.block_size,
            enable_prefix_reuse=cfg.paging.enable_prefix_reuse,
            watermark_frac=cfg.paging.watermark_frac)
        self.scheduler = Scheduler(alloc, max_slots=max_slots,
                                   max_blocks_per_seq=max_blocks_per_seq,
                                   ring_only=ring_only, metrics=self.metrics)
        self.max_num_batched_tokens = int(max_num_batched_tokens)
        if self.chunked and self.max_num_batched_tokens <= max_slots:
            raise ValueError(
                f"max_num_batched_tokens={max_num_batched_tokens} must "
                f"exceed max_slots={max_slots}: a step of all-decode slots "
                "would otherwise leave prefill no budget (starvation)")
        # the chunk executable's fixed token width: a chunk can never be
        # longer than the budget, nor than a sequence's KV capacity
        chunk_tokens = min(self.max_num_batched_tokens,
                           self.scheduler.cap_tokens) if self.chunked \
            else None
        # unified single-dispatch step: decode + the step's prefill chunk
        # + sampling fused under one jit.  Needs the chunk executable
        # (chunked mode) and the fused on-device sampling contract
        # (use_fused) — the two-call path survives behind
        # ``enable_unified_step=False`` as the parity oracle.
        self.unified = bool(enable_unified_step) and self.chunked \
            and use_fused
        self.runner = ModelRunner(cfg, params, max_slots=max_slots,
                                  num_blocks=num_blocks,
                                  max_blocks_per_seq=max_blocks_per_seq,
                                  rt=rt, max_horizon=self.max_horizon,
                                  kv_cache_dtype=kv_cache_dtype,
                                  chunk_tokens=chunk_tokens,
                                  unified=self.unified)
        self.kv_cache_dtype = self.runner.kv_cache_dtype
        self._t0: Optional[float] = None
        self._next_rid = 0
        # bounded window: a long-lived streaming engine must not grow a
        # sample per token forever; 64k recent gaps is plenty for p99
        self._itl_samples: deque = deque(maxlen=65536)

    # ---------------------------------------------------- facade views
    @property
    def alloc(self) -> BlockAllocator:
        return self.scheduler.alloc

    @property
    def waiting(self) -> List[RequestState]:
        return self.scheduler.waiting

    @property
    def running(self) -> Dict[int, Sequence]:
        return self.scheduler.running

    @property
    def finished(self) -> List[RequestState]:
        return self.scheduler.finished

    @property
    def state(self):
        return self.runner.state

    @property
    def rt(self) -> dict:
        return self.runner.rt

    # ------------------------------------------------------------ intake
    def _base_key(self, rid: int, sp: SamplingParams) -> np.ndarray:
        """Per-request PRNG stream root: explicit seed wins; otherwise a
        stream derived from (engine seed, request id)."""
        if sp.seed is not None:
            k = jax.random.PRNGKey(sp.seed)
        else:
            k = jax.random.fold_in(jax.random.PRNGKey(self.seed), rid)
        return np.asarray(k, np.uint32)

    def add(self, prompt: SeqT[int],
            sampling_params: Optional[SamplingParams] = None,
            request_id: Optional[int] = None) -> int:
        """Queue a request (allowed while running / streaming). Returns
        the request id used in its ``RequestOutput`` events."""
        sp = sampling_params or SamplingParams()
        rid = self._next_rid if request_id is None else request_id
        self._next_rid = max(self._next_rid, rid) + 1
        rec = RequestState(rid=rid, prompt=list(prompt), sampling=sp,
                           base_key=self._base_key(rid, sp))
        self.scheduler.add(rec)
        return rid

    def add_request(self, req: Request) -> None:
        """Deprecated: wrap a legacy ``Request``; its ``output`` list is
        shared with the engine so old call sites keep reading results."""
        warnings.warn(
            "ServingEngine.add_request(Request(...)) is deprecated; use "
            "engine.add(prompt, SamplingParams(...)) or serving.llm.LLM",
            DeprecationWarning, stacklevel=2)
        sp = SamplingParams(temperature=req.temperature,
                            max_tokens=req.max_new_tokens)
        rec = RequestState(rid=req.rid, prompt=req.prompt, sampling=sp,
                           output=req.output, shim=req,
                           base_key=self._base_key(req.rid, sp))
        self._next_rid = max(self._next_rid, req.rid + 1)
        self.scheduler.add(rec)
        req.arrival = rec.arrival

    # ------------------------------------------------------------ outputs
    def _emit(self, req: RequestState, outs: List[RequestOutput]) -> None:
        if req.shim is not None:     # legacy Request: mirror timestamps
            req.shim.first_token_t = req.first_token_t
            req.shim.done_t = req.done_t
        new = list(req.output[req.emitted:])
        finished = req.finish_reason is not None
        if not new and not finished:
            return
        text = new_text = ""
        if self.detokenizer is not None:
            # incremental: only the delta is detokenized per event, the
            # cumulative text accumulates on the request record
            new_text = self.detokenizer(new) if new else ""
            req.text += new_text
            text = req.text
        outs.append(RequestOutput(
            request_id=req.rid, prompt_token_ids=req.prompt_token_ids,
            token_ids=list(req.output), new_token_ids=new,
            finished=finished, finish_reason=req.finish_reason,
            text=text, new_text=new_text))
        req.emitted = len(req.output)

    def _absorb(self, s: Sequence, toks, now: float,
                outs: List[RequestOutput]) -> None:
        """Fold sampled tokens into a sequence, honouring stop token ids
        and the max_tokens budget; finishing frees KV blocks immediately
        (tokens past a stop are discarded). Emits the delta event."""
        req = s.req
        if toks:
            # inter-token latency sample: gap between this token-bearing
            # event and the request's previous one (TTFT excluded)
            if req.last_event_t is not None:
                self._itl_samples.append(now - req.last_event_t)
            req.last_event_t = now
        for tok in toks:
            req.output.append(int(tok))
            s.last_token = int(tok)
            s.seq_len += 1
            self.metrics["gen_tokens"] += 1
            if req.first_token_t is None:
                req.first_token_t = now
            if int(tok) in req.sampling.stop:
                self.scheduler.finish(s, FINISH_STOP)
                break
            if req.tokens_remaining() <= 0:
                self.scheduler.finish(s, FINISH_LENGTH)
                break
        self._emit(req, outs)

    # ------------------------------------------------------------ prefill
    def _sampling_rows(self, recs: List[RequestState]) -> Dict[str, np.ndarray]:
        """Stack per-request SamplingParams into padded device-ready rows."""
        B = len(recs)
        arr = {"keys": np.zeros((B, 2), np.uint32),
               "counts": np.zeros((B,), np.int32),
               "temps": np.zeros((B,), np.float32),
               "top_ks": np.zeros((B,), np.int32),
               "top_ps": np.ones((B,), np.float32)}
        for i, r in enumerate(recs):
            if r is None:
                continue
            arr["keys"][i] = r.base_key
            arr["counts"][i] = len(r.output)
            arr["temps"][i] = r.sampling.temperature
            arr["top_ks"][i] = r.sampling.top_k
            arr["top_ps"][i] = r.sampling.top_p
        return arr

    def _slot_sampling(self) -> Dict[str, np.ndarray]:
        recs: List[Optional[RequestState]] = [None] * self.max_slots
        for slot, s in self.scheduler.running.items():
            recs[slot] = s.req
        return self._sampling_rows(recs)

    def _run_prefill_oracle(self, seqs: List[Sequence],
                            outs: List[RequestOutput]) -> None:
        """Stop-the-world wave prefill — retained ONLY as the parity
        oracle behind ``enable_chunked_prefill=False`` (and for archs the
        chunk executable cannot serve): pads the whole wave to a
        ``prefill_bucket`` multiple, so it recompiles per (wave size,
        bucket) pair and stalls every running sequence for the duration
        of the longest prompt."""
        b = self.prefill_bucket
        maxlen = max(s.seq_len for s in seqs)
        maxlen = min(((maxlen + b - 1) // b) * b, self.scheduler.cap_tokens)
        logits = self.runner.prefill(seqs, maxlen)
        self.metrics["prompt_tokens"] += sum(s.seq_len for s in seqs)
        # first sampled token, per-request sampling streams
        nxt = self.runner.sample(logits, self._sampling_rows(
            [s.req for s in seqs]))
        self.metrics["host_syncs"] += 1
        now = time.perf_counter()
        for i, s in enumerate(seqs):
            self._absorb(s, [int(nxt[i])], now, outs)
        # leave device tables consistent with the host bookkeeping
        # (slots just prefilled or freed) instead of relying on the next
        # decode's sync.
        self.runner.sync_tables(self.scheduler.running)

    def _run_prefill_chunks(self, chunks: List[PrefillChunk],
                            outs: List[RequestOutput]) -> None:
        """Execute the plan's prefill chunks through the fixed-shape
        executable.  Logits stay on device; prompts completing this step
        have their first token sampled in ONE batched call (a single
        host sync for any number of finishing prompts)."""
        final: List[tuple] = []
        for c in chunks:
            logits = self.runner.prefill_chunk(c.seq, c.start, c.length)
            self.scheduler.complete_chunk(c)
            self.metrics["prefill_chunks"] += 1
            self.metrics["prompt_tokens"] += c.length
            if c.last:
                final.append((c.seq, logits))
        if not final:
            return
        # pad to max_slots rows so this sample executable compiles once
        # regardless of how many prompts finish in a step (and shares its
        # shape with the legacy decode path's per-slot sample)
        pad = self.max_slots - len(final)
        stacked = jnp.concatenate(
            [lg for _, lg in final]
            + ([jnp.zeros((pad,) + final[0][1].shape[1:],
                          final[0][1].dtype)] if pad else []), axis=0)
        nxt = self.runner.sample(stacked, self._sampling_rows(
            [s.req for s, _ in final] + [None] * pad))
        self.metrics["host_syncs"] += 1
        now = time.perf_counter()
        for i, (s, _) in enumerate(final):
            self._absorb(s, [int(nxt[i])], now, outs)

    # ------------------------------------------------------------ decode
    def _record_decode_time(self, dt: float, steps: int) -> None:
        self.metrics["decode_time_s"] += dt
        # warm = past the megastep/decode compile call.  Gated on the
        # count of *timed* decode dispatches, not decode_dispatches: an
        # earlier unified mixed dispatch (never timed here) must not make
        # the first pure-decode dispatch — the compile — read as warm.
        self.metrics["timed_decode_dispatches"] += 1
        if self.metrics["timed_decode_dispatches"] > 1:
            self.metrics["decode_warm_time_s"] += dt
            self.metrics["decode_warm_steps"] += steps

    def _prepare_dispatch(self, horizon: int) -> StepPlan:
        """Oracle-mode planning: horizon + block growth for all running
        (= all decodable) sequences, as one degenerate StepPlan."""
        h = self.scheduler.plan_horizon(horizon)
        cow = self.scheduler.grow_for_horizon(h) if h else []
        return StepPlan(decode_slots=sorted(self.scheduler.decodable())
                        if h else [], horizon=h, cow_pairs=cow,
                        prefill=[], budget=0)

    def _dispatch_decode(self, plan: StepPlan,
                         outs: List[RequestOutput]) -> None:
        """Execute a plan's decode half: fused megastep over the planned
        horizon, or the legacy per-token loop (same planner, same
        sampling kernel — the bitwise-equivalence oracle).  Only the
        plan's decodable slots are active: mid-prefill slots get device
        seq_len 0, so the decode KV scatter drops their writes."""
        if not plan.decode_slots:
            return
        t0 = time.perf_counter()
        if plan.cow_pairs:
            self.runner.copy_cow(plan.cow_pairs)
        # device tables carry EXACTLY the planned slots: everything else
        # (mid-prefill, or decodables a degenerate budget left out) gets
        # seq_len 0, so the decode KV scatter drops their writes
        self.runner.sync_tables({slot: self.scheduler.running[slot]
                                 for slot in plan.decode_slots})
        toks = np.zeros((self.max_slots,), np.int32)
        for slot in plan.decode_slots:
            toks[slot] = self.scheduler.running[slot].last_token
        if self.use_fused:
            active = np.zeros((self.max_slots,), bool)
            active[plan.decode_slots] = True
            out_np = self.runner.megastep(toks, self._slot_sampling(),
                                          active, plan.horizon)
            nxt_rows = {slot: out_np[:, slot].tolist()
                        for slot in plan.decode_slots}
        else:
            logits = self.runner.decode(toks)
            nxt = self.runner.sample(logits, self._slot_sampling())
            nxt_rows = {slot: [int(nxt[slot])] for slot in plan.decode_slots}
        self.metrics["host_syncs"] += 1
        self.metrics["decode_dispatches"] += 1
        self.metrics["decode_steps"] += plan.horizon
        now = time.perf_counter()
        for slot in plan.decode_slots:
            self._absorb(self.scheduler.running[slot], nxt_rows[slot],
                         now, outs)
        self._record_decode_time(time.perf_counter() - t0, plan.horizon)

    def _dispatch_unified(self, plan: StepPlan,
                          outs: List[RequestOutput]) -> None:
        """Execute a mixed plan (decodes at horizon <= 1 interleaved with
        prefill) as unified dispatches: the first fuses the decode step,
        the step's first prefill chunk AND all sampling into ONE donated
        device call with a single ``[max_slots + 1]`` token readback;
        further chunks (fresh-admission bursts) each dispatch alone.  In
        the steady mixed workload (one prompt chunking over a decoding
        batch) that is exactly one device dispatch per engine iteration
        — the two-call path pays a decode dispatch, a chunk dispatch and
        a first-token sample dispatch for the same work."""
        if plan.cow_pairs:
            self.runner.copy_cow(plan.cow_pairs)
        done: List[tuple] = []
        for d in plan.unified_dispatches():
            # device tables carry EXACTLY this dispatch's decode slots:
            # everything else gets seq_len 0, so the decode KV scatter
            # drops its writes (chunk-only dispatches decode nothing)
            self.runner.sync_tables({slot: self.scheduler.running[slot]
                                     for slot in d.decode_slots})
            toks = np.zeros((self.max_slots,), np.int32)
            active = np.zeros((self.max_slots,), bool)
            recs: List[Optional[RequestState]] = [None] * self.max_slots
            for slot in d.decode_slots:
                toks[slot] = self.scheduler.running[slot].last_token
                active[slot] = True
                recs[slot] = self.scheduler.running[slot].req
            c = d.chunk
            recs.append(c.seq.req)          # row max_slots: the chunk
            out = self.runner.unified_step(
                toks, self._sampling_rows(recs), active,
                c.seq.req.prompt, c.seq.block_ids, c.start, c.length)
            done.append((d, out))
            self.scheduler.complete_chunk(c)
            self.metrics["prefill_chunks"] += 1
            self.metrics["prompt_tokens"] += c.length
            if d.decode_slots:
                # decode bookkeeping rides the unified dispatch; its
                # *timing* is not recorded — decode_step_latency_us stays
                # a pure-decode figure (mixed dispatches include chunk
                # compute the two-call path never timed as decode)
                self.metrics["decode_dispatches"] += 1
                self.metrics["decode_steps"] += 1
        # the step's ONE blocking point: token buffers are absorbed after
        # every dispatch is in flight (an admission burst of several
        # chunks pipelines; the steady mixed state is a single dispatch)
        self.metrics["host_syncs"] += 1
        now = time.perf_counter()
        for d, out in done:
            out_np = np.asarray(out)         # one bulk transfer per buffer
            for slot in d.decode_slots:
                self._absorb(self.scheduler.running[slot],
                             [int(out_np[slot])], now, outs)
            if d.sample_chunk:
                self._absorb(d.chunk.seq, [int(out_np[self.max_slots])],
                             now, outs)

    # ------------------------------------------------------------ drive
    def step(self) -> List[RequestOutput]:
        """One engine iteration under the token budget: the scheduler
        plans decodes first (fused horizon when no prefill is pending,
        one interleaved token otherwise), then packs prefill chunks into
        the remaining budget; the runner executes both halves.  With
        ``enable_chunked_prefill=False`` the pre-budget stop-the-world
        behaviour is preserved as the parity oracle.  Returns the
        ``RequestOutput`` deltas produced by this iteration."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        outs: List[RequestOutput] = []
        d0 = self.runner.dispatches
        try:
            for req in self.scheduler.finish_at_capacity():
                self._emit(req, outs)  # free slots/blocks before admission
            if not self.chunked:
                admitted = self.scheduler.try_admit()
                if admitted:
                    self._run_prefill_oracle(admitted, outs)
                for req in self.scheduler.finish_at_capacity():
                    self._emit(req, outs)  # a fresh exactly-cap prefill
                if not self.scheduler.running:  # may be at the boundary
                    return outs
                plan = self._prepare_dispatch(
                    self.max_horizon if self.use_fused else 1)
                self._dispatch_decode(plan, outs)
                return outs
            plan = self.scheduler.plan_step(
                self.max_num_batched_tokens,
                max_horizon=self.max_horizon if self.use_fused else 1)
            if self.unified and plan.prefill and plan.horizon <= 1:
                self._dispatch_unified(plan, outs)
            else:
                # pure-decode plans keep the fused megastep (already one
                # dispatch per multi-token horizon); with
                # enable_unified_step=False this two-phase execute is the
                # unified path's parity oracle
                self._dispatch_decode(plan, outs)
                if plan.prefill:
                    self._run_prefill_chunks(plan.prefill, outs)
            if plan.used:
                self.metrics["plan_steps"] += 1
                self.metrics["budget_tokens_used"] += plan.used
            return outs
        finally:
            used = self.runner.dispatches - d0
            if used:
                self.metrics["device_dispatches"] += used
                self.metrics["work_steps"] += 1

    def stream(self, max_steps: int = 100000) -> Iterator[RequestOutput]:
        """Yield ``RequestOutput`` deltas as horizons complete — callers
        see first tokens while the batch is still running, and may keep
        calling ``add`` / ``add_request`` between events."""
        steps = 0
        while self.scheduler.has_work() and steps < max_steps:
            yield from self.step()
            steps += 1

    def run_until_done(self, max_steps: int = 10000) -> Dict[str, float]:
        steps = 0
        while self.scheduler.has_work() and steps < max_steps:
            self.step()
            steps += 1
        return self.report()

    def reset_dispatch_window(self) -> None:
        """Zero the device-dispatch counters so ``report()``'s
        ``device_dispatches_per_step`` covers only what follows — e.g.
        the steady mixed-workload window after warm-up (compile steps
        and one-off CoW copies land in the warm-up bucket)."""
        self.metrics["device_dispatches"] = 0
        self.metrics["work_steps"] = 0

    def reset_itl_window(self) -> None:
        """Drop accumulated inter-token-latency samples so ``report()``'s
        ITL percentiles cover only what follows — e.g. a steady-state
        window after warm-up/compile steps.  Live requests keep their
        last-event timestamps: a stall in progress still lands in the
        first post-reset sample."""
        self._itl_samples.clear()

    def report(self) -> Dict[str, float]:
        """The paper's three numbers (+ fast-path and streaming counters)."""
        t1 = time.perf_counter()
        wall = max(t1 - (self._t0 or t1), 1e-9)
        fin = self.scheduler.finished
        n = len(fin)
        lat = float(np.mean([r.done_t - r.arrival for r in fin])) \
            if n else float("nan")
        ttft = float(np.mean([r.first_token_t - r.arrival for r in fin
                              if r.first_token_t is not None])) \
            if n else float("nan")
        total_toks = self.metrics["prompt_tokens"] + self.metrics["gen_tokens"]
        d_steps = max(self.metrics["decode_steps"], 1)
        # prefer warm (post-compile) per-step latency when measurable
        if self.metrics["decode_warm_steps"]:
            step_lat = (self.metrics["decode_warm_time_s"]
                        / self.metrics["decode_warm_steps"])
        else:
            step_lat = self.metrics["decode_time_s"] / d_steps
        # inter-token latency percentiles over per-event gaps: under
        # stop-the-world prefill the p99 carries the "one long prompt
        # stalls everyone" spikes the chunked planner bounds at O(chunk)
        itl = np.asarray(self._itl_samples, np.float64)
        itl_p50 = float(np.percentile(itl, 50)) if itl.size else float("nan")
        itl_p99 = float(np.percentile(itl, 99)) if itl.size else float("nan")
        plan_steps = self.metrics["plan_steps"]
        budget_util = (self.metrics["budget_tokens_used"]
                       / (plan_steps * self.max_num_batched_tokens)) \
            if plan_steps else float("nan")
        return {
            "latency_s": lat,
            "ttft_s": ttft,
            "itl_p50_ms": itl_p50 * 1e3,
            "itl_p99_ms": itl_p99 * 1e3,
            "prefill_chunks": self.metrics["prefill_chunks"],
            "prefill_compiles": self.runner.prefill_compiles(),
            # device calls per engine iteration (1.0 in the unified
            # steady mixed state; ~2-3 on the two-call path)
            "device_dispatches_per_step":
                (self.metrics["device_dispatches"]
                 / self.metrics["work_steps"])
                if self.metrics["work_steps"] else float("nan"),
            "budget_utilization": budget_util,
            "throughput_req_s": n / wall,
            "throughput_tok_s": total_toks / wall,
            "generate_tok_s": self.metrics["gen_tokens"] / wall,
            "preemptions": self.metrics["preemptions"],
            "block_utilization": self.alloc.utilization(),
            "blocks_reused": self.alloc.stats["reused"],
            # pool memory: the figure kv_cache_dtype="int8" halves vs bf16
            "kv_pool_bytes": self.runner.kv_pool_bytes(),
            "kv_bytes_per_token": self.runner.kv_bytes_per_token(),
            "wall_s": wall,
            "host_syncs": self.metrics["host_syncs"],
            "decode_dispatches": self.metrics["decode_dispatches"],
            "decode_steps": self.metrics["decode_steps"],
            "decode_step_latency_us": step_lat * 1e6,
            # decode-path syncs only (one per dispatch): prefill-wave syncs
            # are excluded, so legacy reads exactly 1.0 and fused 1/horizon
            "syncs_per_decode_step":
                self.metrics["decode_dispatches"] / d_steps,
        }
