"""Continuous-batching serving engine facade (the vLLM role in the paper).

The engine is a thin conductor over two halves:

* ``serving.scheduler.Scheduler`` — pure host policy: admission
  (watermark + prompt clamping), slot/block accounting, recompute-style
  preemption, capacity force-finishing, and the per-iteration token
  budget plan (``plan_step``): running decodes packed first
  (decode-priority, so inter-token latency stays bounded at O(chunk)
  instead of O(longest prompt)), then prefill *chunks* of
  partially-admitted prompts into the remaining
  ``max_num_batched_tokens``, with KV blocks allocated incrementally
  per chunk;
* ``serving.model_runner.ModelRunner`` — the device: paged KV pools,
  the fixed-shape ``[1, chunk_tokens]`` chunk-prefill executable
  (compiled ONCE regardless of prompt length or wave composition),
  jitted per-token decode / fused megastep, CoW block copies,
  on-device per-slot sampling.

``enable_chunked_prefill=False`` (or an arch whose prefill state cannot
yet re-enter mid-prompt: SSM / recurrent / sliding-ring stacks)
restores the stop-the-world whole-prompt wave — retained as the parity
oracle: chunked greedy serving is token-exact against it on the
reduced configs for both the bf16 and int8 KV pools.

With ``enable_unified_step=True`` (default; needs chunked mode and
``use_fused``) a mixed iteration — decodes interleaved with a prefill
chunk — executes as ONE donated device dispatch: the decode step, the
chunk (through the dynamic-offset chunk-flash path) and every row's
sampling fused under one jit, one ``[max_slots + 1]`` token readback.
``enable_unified_step=False`` keeps the two-call execute (decode
dispatch, then chunk dispatch(es), then a first-token sample dispatch)
as the unified path's token-exact / bitwise-sampling parity oracle;
``report()['device_dispatches_per_step']`` shows the difference
(1.0 unified vs ~2-3 two-call in the steady mixed state).

``enable_async_step=True`` (default; rides the unified executable)
pipelines the loop one step deep: an iteration plans and ENQUEUES its
unified dispatch chained on the previous, still in-flight one — the
decode feed tokens are gathered on device from that dispatch's output
buffer — and only then reads the previous step's tokens back, so every
host millisecond (plan, absorb, detokenize via the background worker,
bookkeeping) overlaps device execution.  The scheduler plans
speculatively (``Sequence.speculated``: in-flight tokens counted into
``seq_len`` but not ``req.output``) and reconciles at readback;
finish/abort/preemption during the flight discards the speculated
token, which recompute replay regenerates token-exactly.  All donating
dispatches (megastep, CoW, chunk bursts, the two-call oracle) flush
the pipeline first.  ``enable_async_step=False`` keeps the
read-back-every-step engine as the pipeline's parity oracle.

Requests enter with a ``SamplingParams`` (temperature / top_k / top_p /
seed / stop token ids / max_tokens) that is lowered to padded per-slot
device arrays, so one batch freely mixes greedy, temperature and
top-k/top-p requests — through *both* the legacy per-token loop
(``use_fused=False``, the bitwise-equivalence oracle) and the fused
decode megastep (default; one buffer-donated device call per multi-token
horizon, one host↔device round trip per dispatch).

Results stream back as ``RequestOutput`` deltas: ``step()`` returns the
events produced by that iteration and ``stream()`` yields them as
horizons complete, so callers see tokens long before the batch drains —
and ``add_request`` / ``add`` may be called while streaming (continuous
intake). ``run_until_done`` is retained as the drain-everything driver.

The pre-redesign surface — ``ServingEngine(cfg, params)`` plus the bare
``Request(prompt, max_new_tokens, temperature)`` — keeps working as a
deprecation shim for one release; new code should construct via
``serving.llm.LLM`` and speak ``SamplingParams`` / ``RequestOutput``.
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence as SeqT

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.paged_cache import BlockAllocator
from repro.models import transformer as T
from repro.obs.metrics import MetricsDict, MetricsRegistry
from repro.obs.trace import SpanTracer, attribute_steps
from repro.runtime.fault import StragglerDetector
from repro.serving.detok import DetokWorker
from repro.serving.faults import (FaultInjector, PoisonedDispatchError,
                                  TransientDeviceError)
from repro.serving.model_runner import ModelRunner
from repro.serving.params import (FINISH_ABORT, FINISH_ERROR, FINISH_LENGTH,
                                  FINISH_SHED, FINISH_STOP, RequestOutput,
                                  SamplingParams)
from repro.serving.scheduler import (PrefillChunk, RequestState, Scheduler,
                                     Sequence, StepPlan, UnifiedDispatch)


class EngineOverloadedError(RuntimeError):
    """``add`` refused a request: the waiting queue is at ``max_waiting``
    and the engine's shed policy is "reject"."""


@dataclass
class Request:
    """Deprecated pre-``SamplingParams`` request record (one-release shim).

    Use ``engine.add(prompt, SamplingParams(...))`` instead; this maps
    onto it via ``add_request`` and keeps filling ``output`` in place.
    """
    rid: int
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    arrival: float = 0.0
    output: List[int] = field(default_factory=list)
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None


@dataclass
class _Flight:
    """One in-flight (enqueued, not yet read back) unified dispatch.

    ``out`` is the dispatch's device-side ``[max_slots + 1]`` token
    buffer — the NEXT dispatch gathers its feed tokens from it on
    device, and the host reads it back one step later.  ``decode_rows``
    / ``chunk_seq`` name the sequences whose sampled token the buffer
    carries; ``source_row`` maps ``id(Sequence)`` to its row so the
    successor dispatch can chain on it (row ``max_slots`` is the chunk
    sample).  Holding the Sequence *objects* (not slots) lets the
    collect path detect finish/abort/preemption-and-readmission during
    the flight by identity."""
    out: object
    decode_rows: List[tuple] = field(default_factory=list)
    chunk_seq: Optional[Sequence] = None
    source_row: Dict[int, int] = field(default_factory=dict)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 8,
                 num_blocks: int = 512, max_blocks_per_seq: int = 64,
                 prefill_bucket: int = 64, rt: Optional[dict] = None,
                 seed: int = 0, use_fused: bool = True,
                 max_horizon: int = 8, detokenizer=None,
                 kv_cache_dtype: str = "bf16",
                 max_num_batched_tokens: int = 256,
                 enable_chunked_prefill: bool = True,
                 enable_unified_step: bool = True,
                 enable_async_step: bool = True,
                 max_waiting: Optional[int] = None,
                 shed_policy: str = "reject",
                 enable_guards: bool = True,
                 fault_injector: Optional[FaultInjector] = None,
                 max_dispatch_retries: int = 2,
                 retry_backoff_s: float = 0.0,
                 enable_telemetry: bool = True,
                 trace_capacity: int = 65536,
                 profile_labels: bool = False):
        if shed_policy not in ("reject", "shed-oldest"):
            raise ValueError(f"shed_policy {shed_policy!r}: expected "
                             "'reject' or 'shed-oldest'")
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.mb = max_blocks_per_seq
        self.prefill_bucket = prefill_bucket
        self.use_fused = use_fused
        self.max_horizon = max(1, max_horizon)
        self.detokenizer = detokenizer
        self.seed = seed
        # ---- observability (tentpole: see docs/OBSERVABILITY.md) ----
        # the registry is the single source of truth for every number
        # report()/health() expose; the historical ``self.metrics`` dict
        # survives as a MutableMapping facade over registry counters, so
        # engine and scheduler call sites are unchanged.  The span
        # tracer is the only piece ``enable_telemetry`` gates: metrics
        # are core accounting (report()'s contract) and stay on.
        self.obs = MetricsRegistry()
        self.tracer = SpanTracer(capacity=trace_capacity,
                                 enabled=enable_telemetry)
        self.metrics: Dict[str, float] = MetricsDict(self.obs, initial={
            "prompt_tokens": 0, "gen_tokens": 0, "preemptions": 0,
            "host_syncs": 0, "decode_dispatches": 0, "decode_steps": 0,
            "decode_time_s": 0.0, "truncated_prompts": 0,
            # dispatches after the first: excludes jit compile of the step
            "decode_warm_steps": 0, "decode_warm_time_s": 0.0,
            "timed_decode_dispatches": 0,
            "prefill_chunks": 0, "plan_steps": 0, "budget_tokens_used": 0,
            # device calls per engine iteration (the unified-dispatch
            # figure): work_steps counts iterations that dispatched at all
            "device_dispatches": 0, "work_steps": 0,
            # robustness counters (see docs/API.md "Fault tolerance")
            "dispatch_retries": 0, "quarantined": 0, "shed": 0,
            "aborted": 0, "deadline_expired": 0, "slow_steps": 0,
            # iterations that ran pipelined: enqueued their dispatch
            # chained on an in-flight one instead of blocking on it
            "async_steps": 0})
        # per-request latency decompositions, derived from lifecycle
        # events (arrival -> admitted -> first token -> finish)
        self._h_queue_wait = self.obs.histogram(
            "repro_request_queue_wait_ms",
            help="arrival to first admission (slot assigned)")
        self._h_ttft = self.obs.histogram(
            "repro_request_ttft_ms",
            help="arrival to first sampled token")
        # bounded percentile window: a long-lived streaming engine must
        # not grow a sample per token forever; 64k recent gaps is plenty
        # for p99 (the cumulative buckets keep the full history)
        self._h_itl = self.obs.histogram(
            "repro_itl_ms", sample_maxlen=65536,
            help="inter-token latency (per-event gaps, TTFT excluded)")
        self._g_waiting = self.obs.gauge(
            "repro_waiting", help="requests queued for admission")
        self._g_running = self.obs.gauge(
            "repro_running", help="requests holding a decode slot")
        self._g_free_blocks = self.obs.gauge(
            "repro_free_blocks", help="free KV pool blocks")
        self._g_step_ema = self.obs.gauge(
            "repro_step_time_ema_ms",
            help="straggler watchdog's EMA of work-step wall time")
        # sliding-window-only archs use a fixed ring cache: no block growth
        ring_only = bool(cfg.sliding_window) and not any(
            cfg.layer_kind(i) == "full" for i in range(cfg.num_layers))
        # chunked prefill needs every layer's prefill state to live in the
        # paged pool; SSM / recurrent / ring archs keep the oracle path
        self.chunked = bool(enable_chunked_prefill) \
            and T.supports_chunked_prefill(cfg)
        alloc = BlockAllocator(
            num_blocks, cfg.paging.block_size,
            enable_prefix_reuse=cfg.paging.enable_prefix_reuse,
            watermark_frac=cfg.paging.watermark_frac)
        self.scheduler = Scheduler(alloc, max_slots=max_slots,
                                   max_blocks_per_seq=max_blocks_per_seq,
                                   ring_only=ring_only, metrics=self.metrics)
        self.max_num_batched_tokens = int(max_num_batched_tokens)
        if self.chunked and self.max_num_batched_tokens <= max_slots:
            raise ValueError(
                f"max_num_batched_tokens={max_num_batched_tokens} must "
                f"exceed max_slots={max_slots}: a step of all-decode slots "
                "would otherwise leave prefill no budget (starvation)")
        # the chunk executable's fixed token width: a chunk can never be
        # longer than the budget, nor than a sequence's KV capacity
        chunk_tokens = min(self.max_num_batched_tokens,
                           self.scheduler.cap_tokens) if self.chunked \
            else None
        # unified single-dispatch step: decode + the step's prefill chunk
        # + sampling fused under one jit.  Needs the chunk executable
        # (chunked mode) and the fused on-device sampling contract
        # (use_fused) — the two-call path survives behind
        # ``enable_unified_step=False`` as the parity oracle.
        self.unified = bool(enable_unified_step) and self.chunked \
            and use_fused
        # async pipelined step (default; needs the unified executable):
        # a mixed iteration ENQUEUES its unified dispatch chained on the
        # previous (still in-flight) one and reads tokens back exactly
        # one step late, so the whole host side of a step — plan,
        # absorb, detokenize, bookkeeping — overlaps device execution.
        # ``enable_async_step=False`` keeps the read-back-every-step
        # engine as the pipeline's token-exactness oracle.
        self.async_step = bool(enable_async_step) and self.unified
        # the per-step non-finite logit guard is a *static* flag baked
        # into the jitted executables at trace time: guards-off builds
        # trace byte-identical programs to a build that never heard of
        # guards (zero overhead when disabled), guards-on adds one
        # isfinite-reduce + select per sampled row
        self.guards = bool(enable_guards)
        rt = dict(rt or {})
        if self.guards:
            rt["sampling_guard"] = True
        self.runner = ModelRunner(cfg, params, max_slots=max_slots,
                                  num_blocks=num_blocks,
                                  max_blocks_per_seq=max_blocks_per_seq,
                                  rt=rt, max_horizon=self.max_horizon,
                                  kv_cache_dtype=kv_cache_dtype,
                                  chunk_tokens=chunk_tokens,
                                  unified=self.unified,
                                  tracer=self.tracer,
                                  profile_labels=profile_labels)
        self.kv_cache_dtype = self.runner.kv_cache_dtype
        self._t0: Optional[float] = None
        self._next_rid = 0
        # ---- robustness state (tentpole: see docs/API.md) ----
        self.max_waiting = None if max_waiting is None else int(max_waiting)
        self.shed_policy = shed_policy
        self.faults = fault_injector
        self.max_dispatch_retries = int(max_dispatch_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        # serving watchdog: EMA step-time monitor over work steps (the
        # training stack's detector, reused verbatim)
        self._straggler = StragglerDetector()
        # poisoned-dispatch bisection: rid groups awaiting probation, and
        # the group currently admitted in isolation (allowed_rids)
        self._suspects: deque = deque()
        self._probing: Optional[List[int]] = None
        # events produced outside step() (abort / shed): drained first
        # by the next step so stream()/run_until_done surface them
        self._pending: List[RequestOutput] = []
        # ---- async pipeline state (see docs/PERF.md "Async pipeline") ----
        # the un-collected in-flight dispatch, and the background worker
        # every async-mode emission (tokens, aborts, sheds) routes
        # through so detokenization overlaps the next device dispatch
        # while per-request event order is preserved (FIFO worker)
        self._flight: Optional[_Flight] = None
        self._detok: Optional[DetokWorker] = \
            DetokWorker(detokenizer, self.tracer) if self.async_step \
            else None

    # ---------------------------------------------------- facade views
    @property
    def alloc(self) -> BlockAllocator:
        return self.scheduler.alloc

    @property
    def waiting(self) -> List[RequestState]:
        return self.scheduler.waiting

    @property
    def running(self) -> Dict[int, Sequence]:
        return self.scheduler.running

    @property
    def finished(self) -> List[RequestState]:
        return self.scheduler.finished

    @property
    def state(self):
        return self.runner.state

    @property
    def rt(self) -> dict:
        return self.runner.rt

    # ------------------------------------------------------------ intake
    def _base_key(self, rid: int, sp: SamplingParams) -> np.ndarray:
        """Per-request PRNG stream root: explicit seed wins; otherwise a
        stream derived from (engine seed, request id)."""
        if sp.seed is not None:
            k = jax.random.PRNGKey(sp.seed)
        else:
            k = jax.random.fold_in(jax.random.PRNGKey(self.seed), rid)
        return np.asarray(k, np.uint32)

    def add(self, prompt: SeqT[int],
            sampling_params: Optional[SamplingParams] = None,
            request_id: Optional[int] = None) -> int:
        """Queue a request (allowed while running / streaming). Returns
        the request id used in its ``RequestOutput`` events.

        With ``max_waiting`` set the waiting queue is bounded: a full
        queue either raises ``EngineOverloadedError`` (shed_policy
        "reject" — the caller backs off) or finishes the OLDEST waiting
        request with finish_reason "shed" to make room ("shed-oldest" —
        staleness-bounded queues; running requests are never shed)."""
        if self.max_waiting is not None \
                and len(self.scheduler.waiting) >= self.max_waiting:
            self.metrics["shed"] += 1
            if self.shed_policy == "reject":
                raise EngineOverloadedError(
                    f"waiting queue at max_waiting={self.max_waiting}")
            victim = self.scheduler.waiting[0]
            self.scheduler.abort(victim.rid, FINISH_SHED)
            self._emit(victim, self._pending)
        sp = sampling_params or SamplingParams()
        rid = self._next_rid if request_id is None else request_id
        self._next_rid = max(self._next_rid, rid) + 1
        rec = RequestState(rid=rid, prompt=list(prompt), sampling=sp,
                           base_key=self._base_key(rid, sp))
        self.scheduler.add(rec)
        self.tracer.instant("req.arrival", cat="request",
                            args={"rid": rid, "prompt_len": len(rec.prompt)})
        return rid

    def add_request(self, req: Request) -> None:
        """Deprecated: wrap a legacy ``Request``; its ``output`` list is
        shared with the engine so old call sites keep reading results."""
        warnings.warn(
            "ServingEngine.add_request(Request(...)) is deprecated; use "
            "engine.add(prompt, SamplingParams(...)) or serving.llm.LLM",
            DeprecationWarning, stacklevel=2)
        sp = SamplingParams(temperature=req.temperature,
                            max_tokens=req.max_new_tokens)
        rec = RequestState(rid=req.rid, prompt=req.prompt, sampling=sp,
                           output=req.output, shim=req,
                           base_key=self._base_key(req.rid, sp))
        self._next_rid = max(self._next_rid, req.rid + 1)
        self.scheduler.add(rec)
        req.arrival = rec.arrival

    # ------------------------------------------------------------ lifecycle
    def abort(self, request_id: int) -> bool:
        """Cancel a request wherever it is — waiting, mid-prefill-chunk,
        or decoding.  Its KV blocks, hash registrations and slot are
        released the same call (refcount-audited: ``alloc.audit()``
        stays clean).  The finish event (finish_reason "aborted",
        partial output kept) surfaces with the next ``step()``.  Returns
        False if the id is unknown or already finished."""
        req = self.scheduler.abort(request_id, FINISH_ABORT)
        if req is None:
            return False
        self.metrics["aborted"] += 1
        self.tracer.instant("req.abort", cat="request",
                            args={"rid": request_id})
        self._emit(req, self._pending)
        return True

    def _mark_admitted(self, reqs: SeqT[RequestState], now: float) -> None:
        """First-admission lifecycle mark: the queue-wait histogram
        sample (arrival -> slot assigned) plus a trace instant.
        Re-admissions after preemption keep the original mark — queue
        wait measures a request's first trip through the queue."""
        for req in reqs:
            if req.admitted_t is None:
                req.admitted_t = now
                self._h_queue_wait.observe((now - req.arrival) * 1e3)
                self.tracer.instant("req.admitted", cat="request",
                                    args={"rid": req.rid})

    # ------------------------------------------------------------ outputs
    def _emit(self, req: RequestState, outs: List[RequestOutput]) -> None:
        new = list(req.output[req.emitted:])
        finished = req.finish_reason is not None
        if not new and not finished:
            return
        if self._detok is not None:
            # async mode: EVERY emission (tokens, abort, shed, deadline)
            # routes through the FIFO worker, so per-request event order
            # is preserved while detokenization overlaps the in-flight
            # dispatch.  The job snapshots its data here, on the engine
            # thread; ``step()`` surfaces the built outputs one step of
            # slack later.
            if finished:
                self.tracer.instant("req.finish", cat="request",
                                    args={"rid": req.rid,
                                          "reason": req.finish_reason,
                                          "tokens": len(req.output)})
            self._detok.submit(req, new, finished, req.finish_reason)
            req.emitted = len(req.output)
            return
        if req.shim is not None:     # legacy Request: mirror timestamps
            req.shim.first_token_t = req.first_token_t
            req.shim.done_t = req.done_t
        text = new_text = ""
        if self.detokenizer is not None:
            # incremental: only the delta is detokenized per event, the
            # cumulative text accumulates on the request record
            with self.tracer.span("detokenize", cat="host"):
                new_text = self.detokenizer(new) if new else ""
            req.text += new_text
            text = req.text
        if finished:
            self.tracer.instant("req.finish", cat="request",
                                args={"rid": req.rid,
                                      "reason": req.finish_reason,
                                      "tokens": len(req.output)})
        outs.append(RequestOutput(
            request_id=req.rid, prompt_token_ids=req.prompt_token_ids,
            token_ids=list(req.output), new_token_ids=new,
            finished=finished, finish_reason=req.finish_reason,
            text=text, new_text=new_text))
        req.emitted = len(req.output)

    def _absorb(self, s: Sequence, toks, now: float,
                outs: List[RequestOutput]) -> None:
        """Fold sampled tokens into a sequence, honouring stop token ids
        and the max_tokens budget; finishing frees KV blocks immediately
        (tokens past a stop are discarded). Emits the delta event."""
        req = s.req
        if toks:
            # inter-token latency sample: gap between this token-bearing
            # event and the request's previous one (TTFT excluded)
            if req.last_event_t is not None:
                self._h_itl.observe((now - req.last_event_t) * 1e3)
            req.last_event_t = now
        for tok in toks:
            if int(tok) < 0:
                # the on-device non-finite guard sampled -1: this ROW's
                # logits went NaN/inf.  Quarantine just this request —
                # everything sampled before the -1 is kept, everything
                # after it (fused horizons feed a clamped placeholder
                # forward) is garbage and discarded with the sequence.
                self.metrics["quarantined"] += 1
                self.tracer.instant("req.quarantine", cat="request",
                                    args={"rid": req.rid, "site": "nan_row"})
                if self.faults is not None:
                    self.faults.forgive(req.rid)
                self.scheduler.finish(s, FINISH_ERROR)
                break
            req.output.append(int(tok))
            s.last_token = int(tok)
            s.seq_len += 1
            self.metrics["gen_tokens"] += 1
            if req.first_token_t is None:
                req.first_token_t = now
                self._h_ttft.observe((now - req.arrival) * 1e3)
                self.tracer.instant("req.first_token", cat="request",
                                    args={"rid": req.rid})
            if int(tok) in req.sampling.stop:
                self.scheduler.finish(s, FINISH_STOP)
                break
            if req.tokens_remaining() <= 0:
                self.scheduler.finish(s, FINISH_LENGTH)
                break
        self._emit(req, outs)

    # ------------------------------------------------------------ recovery
    def _protected(self, rids: List[int], fn):
        """Run one device-dispatch thunk under the transient-fault guard:
        consult the injector BEFORE issuing the dispatch (donated buffers
        are never left half-dead, so a retry is always safe), retry with
        bounded exponential backoff, then escalate to
        ``PoisonedDispatchError`` carrying the batch's request ids for
        the bisection path.  One ``is None`` check when no injector is
        attached."""
        attempt = 0
        while True:
            try:
                if self.faults is not None:
                    self.faults.check_dispatch(rids)
                return fn()
            except TransientDeviceError as e:
                attempt += 1
                self.metrics["dispatch_retries"] += 1
                if attempt > self.max_dispatch_retries:
                    raise PoisonedDispatchError(rids, str(e)) from e
                if self.retry_backoff_s:
                    time.sleep(self.retry_backoff_s * (2 ** (attempt - 1)))

    def _quarantine(self, rid: int, outs: List[RequestOutput]) -> None:
        self.metrics["quarantined"] += 1
        self.tracer.instant("req.quarantine", cat="request",
                            args={"rid": rid, "site": "dispatch"})
        if self.faults is not None:
            self.faults.forgive(rid)
        req = self.scheduler.abort(rid, FINISH_ERROR)
        if req is not None:
            self._emit(req, outs)

    def _advance_probe(self) -> None:
        """Move the bisection forward: pop the next suspect group into
        probation (the scheduler admits ONLY its rids until it clears),
        or lift the allow-set entirely once no suspects remain."""
        if self._probing is None and self._suspects:
            self._probing = list(self._suspects.popleft())
            self.scheduler.allowed_rids = set(self._probing)
        elif self._probing is None:
            self.scheduler.allowed_rids = None

    def _recover(self, e: PoisonedDispatchError,
                 outs: List[RequestOutput]) -> None:
        """Poisoned-dispatch recovery.  Every request in the failing
        batch is requeued recompute-style (the same fold-and-replay that
        preemption uses, so survivors stay token-exact); a single-request
        batch has found its offender and is quarantined with
        finish_reason "error"; a larger batch is bisected into two
        probation groups the scheduler will re-admit in isolation —
        log2(batch) failing dispatches later the offender is cornered
        while every innocent request has cleared and kept decoding."""
        live = [rid for rid in e.rids
                if self.scheduler.preempt_request(rid) is not None]
        if len(live) == 1:
            self._quarantine(live[0], outs)
        elif len(live) > 1:
            mid = len(live) // 2
            self._suspects.append(live[:mid])
            self._suspects.append(live[mid:])
        self._probing = None
        self._advance_probe()

    # ------------------------------------------------------------ prefill
    def _sampling_rows(self, recs: List[RequestState],
                       live: Optional[set] = None) -> Dict[str, np.ndarray]:
        """Stack per-request SamplingParams into padded device-ready rows.

        ``live`` — rids whose sampled token this dispatch actually
        consumes (decode rows absorb every row they compute, but a mixed
        dispatch also computes throwaway samples for mid-prefill slots
        and non-final chunk rows).  The nan fault site is consulted only
        for live rows, so a scheduled fault cannot burn itself on a
        sample nobody reads.  None = every non-pad row is live."""
        B = len(recs)
        arr = {"keys": np.zeros((B, 2), np.uint32),
               "counts": np.zeros((B,), np.int32),
               "temps": np.zeros((B,), np.float32),
               "top_ks": np.zeros((B,), np.int32),
               "top_ps": np.ones((B,), np.float32)}
        for i, r in enumerate(recs):
            if r is None:
                continue
            arr["keys"][i] = r.base_key
            arr["counts"][i] = len(r.output)
            arr["temps"][i] = r.sampling.temperature
            arr["top_ks"][i] = r.sampling.top_k
            arr["top_ps"][i] = r.sampling.top_p
        # nan-site fault injection: a NaN bias row added to the chosen
        # requests' logits ON DEVICE, so the non-finite guard is
        # exercised end to end.  The "poison" key is present only when a
        # spec fires (its presence is static per trace, so fault-free
        # serving never traces a poisoned executable).
        eligible = [r.rid for r in recs if r is not None
                    and (live is None or r.rid in live)]
        nan = self.faults.nan_rids(eligible) \
            if self.faults is not None else ()
        if nan:
            rows = [i for i, r in enumerate(recs)
                    if r is not None and r.rid in nan]
            if rows:
                p = np.zeros((B,), np.float32)
                p[rows] = np.nan
                arr["poison"] = p
        return arr

    def _slot_sampling(self, live: Optional[set] = None
                       ) -> Dict[str, np.ndarray]:
        recs: List[Optional[RequestState]] = [None] * self.max_slots
        for slot, s in self.scheduler.running.items():
            recs[slot] = s.req
        return self._sampling_rows(recs, live=live)

    def _run_prefill_oracle(self, seqs: List[Sequence],
                            outs: List[RequestOutput]) -> None:
        """Stop-the-world wave prefill — retained ONLY as the parity
        oracle behind ``enable_chunked_prefill=False`` (and for archs the
        chunk executable cannot serve): pads the whole wave to a
        ``prefill_bucket`` multiple, so it recompiles per (wave size,
        bucket) pair and stalls every running sequence for the duration
        of the longest prompt."""
        b = self.prefill_bucket
        maxlen = max(s.seq_len for s in seqs)
        maxlen = min(((maxlen + b - 1) // b) * b, self.scheduler.cap_tokens)
        rids = [s.req.rid for s in seqs]
        logits = self._protected(rids,
                                 lambda: self.runner.prefill(seqs, maxlen))
        # register-on-write: the wave's device write is now confirmed, so
        # its full prompt blocks become content-addressable
        for s in seqs:
            self.scheduler.register_written(s)
        self.metrics["prompt_tokens"] += sum(s.seq_len for s in seqs)
        # first sampled token, per-request sampling streams
        nxt = self._protected(rids, lambda: self.runner.sample(
            logits, self._sampling_rows([s.req for s in seqs])))
        self.metrics["host_syncs"] += 1
        now = time.perf_counter()
        for i, s in enumerate(seqs):
            self._absorb(s, [int(nxt[i])], now, outs)
        # leave device tables consistent with the host bookkeeping
        # (slots just prefilled or freed) instead of relying on the next
        # decode's sync.
        self.runner.sync_tables(self.scheduler.running)

    def _run_prefill_chunks(self, chunks: List[PrefillChunk],
                            outs: List[RequestOutput]) -> None:
        """Execute the plan's prefill chunks through the fixed-shape
        executable.  Logits stay on device; prompts completing this step
        have their first token sampled in ONE batched call (a single
        host sync for any number of finishing prompts)."""
        final: List[tuple] = []
        try:
            for c in chunks:
                logits = self._protected(
                    [c.seq.req.rid],
                    lambda c=c: self.runner.prefill_chunk(c.seq, c.start,
                                                          c.length))
                self.scheduler.complete_chunk(c)
                self.metrics["prefill_chunks"] += 1
                self.metrics["prompt_tokens"] += c.length
                if c.last:
                    final.append((c.seq, logits))
        except PoisonedDispatchError as e:
            # prompts that completed prefill this step but whose
            # first-token sample never ran cannot decode token-exactly:
            # requeue them alongside the failing dispatch (recompute
            # replays them; as innocents they clear probation fast)
            raise PoisonedDispatchError(
                set(e.rids) | {s.req.rid for s, _ in final}) from e
        if not final:
            return
        # pad to max_slots rows so this sample executable compiles once
        # regardless of how many prompts finish in a step (and shares its
        # shape with the legacy decode path's per-slot sample)
        pad = self.max_slots - len(final)
        stacked = jnp.concatenate(
            [lg for _, lg in final]
            + ([jnp.zeros((pad,) + final[0][1].shape[1:],
                          final[0][1].dtype)] if pad else []), axis=0)
        nxt = self._protected(
            [s.req.rid for s, _ in final],
            lambda: self.runner.sample(stacked, self._sampling_rows(
                [s.req for s, _ in final] + [None] * pad)))
        self.metrics["host_syncs"] += 1
        now = time.perf_counter()
        for i, (s, _) in enumerate(final):
            self._absorb(s, [int(nxt[i])], now, outs)

    # ------------------------------------------------------------ readback
    def _readback(self, out) -> np.ndarray:
        """The host<->device sync boundary: one bulk transfer of a
        dispatch's token buffer.  The span is cat="device" — the host is
        blocked on the device stream, not doing host work — and lands in
        the step that COLLECTS the tokens: under async pipelining that
        is one step after the dispatch was enqueued, so its duration is
        whatever device time the overlapped host work failed to hide
        (near-zero in the steady state; see docs/OBSERVABILITY.md).
        The single shared np.asarray sink for the sync unified dispatch
        and the async collect (one justified R1 baseline entry)."""
        with self.tracer.span("readback", cat="device"):
            return np.asarray(out)

    # ------------------------------------------------------------ decode
    def _record_decode_time(self, dt: float, steps: int) -> None:
        self.metrics["decode_time_s"] += dt
        # warm = past the megastep/decode compile call.  Gated on the
        # count of *timed* decode dispatches, not decode_dispatches: an
        # earlier unified mixed dispatch (never timed here) must not make
        # the first pure-decode dispatch — the compile — read as warm.
        self.metrics["timed_decode_dispatches"] += 1
        if self.metrics["timed_decode_dispatches"] > 1:
            self.metrics["decode_warm_time_s"] += dt
            self.metrics["decode_warm_steps"] += steps

    def _prepare_dispatch(self, horizon: int) -> StepPlan:
        """Oracle-mode planning: horizon + block growth for all running
        (= all decodable) sequences, as one degenerate StepPlan."""
        h = self.scheduler.plan_horizon(horizon)
        cow = self.scheduler.grow_for_horizon(h) if h else []
        return StepPlan(decode_slots=sorted(self.scheduler.decodable())
                        if h else [], horizon=h, cow_pairs=cow,
                        prefill=[], budget=0)

    def _dispatch_decode(self, plan: StepPlan,
                         outs: List[RequestOutput]) -> None:
        """Execute a plan's decode half: fused megastep over the planned
        horizon, or the legacy per-token loop (same planner, same
        sampling kernel — the bitwise-equivalence oracle).  Only the
        plan's decodable slots are active: mid-prefill slots get device
        seq_len 0, so the decode KV scatter drops their writes."""
        if not plan.decode_slots:
            return
        t0 = time.perf_counter()
        if plan.cow_pairs:
            self.runner.copy_cow(plan.cow_pairs)
        # device tables carry EXACTLY the planned slots: everything else
        # (mid-prefill, or decodables a degenerate budget left out) gets
        # seq_len 0, so the decode KV scatter drops their writes
        self.runner.sync_tables({slot: self.scheduler.running[slot]
                                 for slot in plan.decode_slots})
        toks = np.zeros((self.max_slots,), np.int32)
        for slot in plan.decode_slots:
            toks[slot] = self.scheduler.running[slot].last_token
        rids = [self.scheduler.running[sl].req.rid
                for sl in plan.decode_slots]
        if self.use_fused:
            active = np.zeros((self.max_slots,), bool)
            active[plan.decode_slots] = True
            out_np = self._protected(rids, lambda: self.runner.megastep(
                toks, self._slot_sampling(live=set(rids)), active,
                plan.horizon))
            nxt_rows = {slot: out_np[:, slot].tolist()
                        for slot in plan.decode_slots}
        else:
            def _decode_and_sample():
                logits = self.runner.decode(toks)
                return self.runner.sample(
                    logits, self._slot_sampling(live=set(rids)))
            nxt = self._protected(rids, _decode_and_sample)
            nxt_rows = {slot: [int(nxt[slot])] for slot in plan.decode_slots}
        self.metrics["host_syncs"] += 1
        self.metrics["decode_dispatches"] += 1
        self.metrics["decode_steps"] += plan.horizon
        now = time.perf_counter()
        for slot in plan.decode_slots:
            self._absorb(self.scheduler.running[slot], nxt_rows[slot],
                         now, outs)
        self._record_decode_time(time.perf_counter() - t0, plan.horizon)

    def _dispatch_unified(self, plan: StepPlan,
                          outs: List[RequestOutput]) -> None:
        """Execute a mixed plan (decodes at horizon <= 1 interleaved with
        prefill) as unified dispatches: the first fuses the decode step,
        the step's first prefill chunk AND all sampling into ONE donated
        device call with a single ``[max_slots + 1]`` token readback;
        further chunks (fresh-admission bursts) each dispatch alone.  In
        the steady mixed workload (one prompt chunking over a decoding
        batch) that is exactly one device dispatch per engine iteration
        — the two-call path pays a decode dispatch, a chunk dispatch and
        a first-token sample dispatch for the same work."""
        if plan.cow_pairs:
            self.runner.copy_cow(plan.cow_pairs)
        done: List[tuple] = []
        try:
            for d in plan.unified_dispatches():
                # device tables carry EXACTLY this dispatch's decode slots:
                # everything else gets seq_len 0, so the decode KV scatter
                # drops its writes (chunk-only dispatches decode nothing)
                self.runner.sync_tables({slot: self.scheduler.running[slot]
                                         for slot in d.decode_slots})
                toks = np.zeros((self.max_slots,), np.int32)
                active = np.zeros((self.max_slots,), bool)
                recs: List[Optional[RequestState]] = [None] * self.max_slots
                rids = []
                for slot in d.decode_slots:
                    toks[slot] = self.scheduler.running[slot].last_token
                    active[slot] = True
                    recs[slot] = self.scheduler.running[slot].req
                    rids.append(recs[slot].rid)
                c = d.chunk
                recs.append(c.seq.req)          # row max_slots: the chunk
                live = set(rids) | ({c.seq.req.rid} if d.sample_chunk
                                    else set())
                out = self._protected(
                    rids + [c.seq.req.rid],
                    lambda: self.runner.unified_step(
                        toks, self._sampling_rows(recs, live=live), active,
                        c.seq.req.prompt, c.seq.block_ids, c.start,
                        c.length))
                done.append((d, out))
                self.scheduler.complete_chunk(c)
                self.metrics["prefill_chunks"] += 1
                self.metrics["prompt_tokens"] += c.length
                if d.decode_slots:
                    # decode bookkeeping rides the unified dispatch; its
                    # *timing* is not recorded — decode_step_latency_us
                    # stays a pure-decode figure (mixed dispatches include
                    # chunk compute the two-call path never timed as
                    # decode)
                    self.metrics["decode_dispatches"] += 1
                    self.metrics["decode_steps"] += 1
        finally:
            # the step's ONE blocking point: token buffers are absorbed
            # after every dispatch is in flight (an admission burst of
            # several chunks pipelines; the steady mixed state is a
            # single dispatch).  On a poisoned later dispatch this still
            # runs before recovery, so completed dispatches' tokens are
            # banked and survive the fold-and-requeue token-exactly.
            if done:
                self.metrics["host_syncs"] += 1
                now = time.perf_counter()
                for d, out in done:
                    out_np = self._readback(out)
                    for slot in d.decode_slots:
                        self._absorb(self.scheduler.running[slot],
                                     [int(out_np[slot])], now, outs)
                    if d.sample_chunk:
                        self._absorb(d.chunk.seq,
                                     [int(out_np[self.max_slots])],
                                     now, outs)

    # ------------------------------------------------------------ pipeline
    def _enqueue_unified(self, d: UnifiedDispatch,
                         outs: List[RequestOutput]) -> _Flight:
        """Enqueue one unified dispatch WITHOUT reading it back, chained
        on the in-flight dispatch's output buffer (the tentpole's device
        half).  A decode row whose feed token is still in flight is fed
        by a device-side gather (``use_prev``/``chain_idx`` into the
        previous ``[max_slots + 1]`` buffer); rows whose token the host
        already holds (pipeline restart after a flush) feed the host
        value.  Host bookkeeping — tables, PRNG counts, chunk
        completion, the speculative seq_len bumps — is identical to what
        the synchronous engine would have done AFTER absorbing the
        in-flight tokens, so planning and device state never diverge
        from the oracle."""
        sched = self.scheduler
        prev = self._flight
        # device tables: each slot's seq_len already counts its
        # speculated token (the one this dispatch feeds and whose KV it
        # writes at seq_len - 1) — exactly the sync post-absorb state
        self.runner.sync_tables({slot: sched.running[slot]
                                 for slot in d.decode_slots})
        toks = np.zeros((self.max_slots,), np.int32)
        chain_idx = np.zeros((self.max_slots,), np.int32)
        use_prev = np.zeros((self.max_slots,), bool)
        active = np.zeros((self.max_slots,), bool)
        recs: List[Optional[RequestState]] = [None] * self.max_slots
        rids = []
        for slot in d.decode_slots:
            s = sched.running[slot]
            active[slot] = True
            recs[slot] = s.req
            rids.append(s.req.rid)
            row = prev.source_row.get(id(s)) if prev is not None else None
            if row is None:
                toks[slot] = s.last_token     # host-known feed
            else:
                use_prev[slot] = True         # gather from in-flight buffer
                chain_idx[slot] = row
        c = d.chunk
        recs.append(c.seq.req)                # row max_slots: the chunk
        live = set(rids) | ({c.seq.req.rid} if d.sample_chunk else set())
        sp = self._sampling_rows(recs, live=live)
        for slot in d.decode_slots:
            # the PRNG stream position counts every token SAMPLED so
            # far — including the in-flight one this dispatch feeds,
            # which req.output does not hold yet
            sp["counts"][slot] += sched.running[slot].speculated
        try:
            out = self._protected(
                rids + [c.seq.req.rid],
                lambda: self.runner.unified_step_chained(
                    prev.out if prev is not None else None,
                    chain_idx, use_prev, toks, sp, active,
                    c.seq.req.prompt, c.seq.block_ids, c.start, c.length))
        except PoisonedDispatchError:
            # bank the PREVIOUS dispatch's (completed, valid) tokens
            # before recovery requeues this batch — survivors keep them
            # and the fold-and-replay stays token-exact
            self._collect_flight(outs)
            raise
        sched.complete_chunk(c)
        self.metrics["prefill_chunks"] += 1
        self.metrics["prompt_tokens"] += c.length
        if d.decode_slots:
            self.metrics["decode_dispatches"] += 1
            self.metrics["decode_steps"] += 1
        # speculation bumps AFTER the successful enqueue: every row
        # whose sample this dispatch's buffer carries
        flight = _Flight(out=out)
        for slot in d.decode_slots:
            s = sched.running[slot]
            sched.speculate(s)
            flight.decode_rows.append((slot, s))
            flight.source_row[id(s)] = slot
        if d.sample_chunk:
            sched.speculate(c.seq)
            flight.chunk_seq = c.seq
            flight.source_row[id(c.seq)] = self.max_slots
        return flight

    def _collect_flight(self, outs: List[RequestOutput]) -> None:
        """Read back the in-flight dispatch — the step's one blocking
        point, deferred exactly one step — then reconcile and absorb its
        tokens.  A row whose Sequence finished, aborted, expired, or was
        preempted (even re-admitted into the same slot as a NEW record:
        object identity catches it) while in flight is discarded with
        the dead record; recompute replay regenerates the token
        token-exactly via the counts-indexed sampling stream if the
        request ever runs again.  No-op when nothing is in flight, so it
        doubles as the pipeline flush every donating fallback dispatch
        (megastep, CoW, chunk bursts, the two-call oracle) requires."""
        fl = self._flight
        if fl is None:
            return
        self._flight = None
        out_np = self._readback(fl.out)
        self.metrics["host_syncs"] += 1
        now = time.perf_counter()
        rows = list(fl.decode_rows)
        if fl.chunk_seq is not None:
            rows.append((self.max_slots, fl.chunk_seq))
        for row, s in rows:
            if s.req.finish_reason is not None \
                    or self.scheduler.running.get(s.slot) is not s:
                continue
            self.scheduler.reconcile(s)
            self._absorb(s, [int(out_np[row])], now, outs)

    def _prune_plan(self, plan: StepPlan) -> None:
        """Drop plan rows a pipeline flush invalidated: absorbing the
        in-flight tokens can finish a planned decode slot (stop token,
        quarantined NaN row) whose Sequence the dispatch path would then
        look up.  Chunks never die here — mid-prefill slots have no
        in-flight sample — and a freed slot's pending CoW copy lands in
        a free block nothing reads before it is rewritten."""
        plan.decode_slots = [sl for sl in plan.decode_slots
                             if sl in self.scheduler.running]

    def _dispatch_fallback(self, plan: StepPlan,
                           outs: List[RequestOutput]) -> None:
        """The synchronous dispatch selection (also the async engine's
        non-pipelined fallback, after a flush): unified one-dispatch
        mixed steps, else megastep + chunk walk."""
        if self.unified and plan.prefill and plan.horizon <= 1:
            self._dispatch_unified(plan, outs)
        else:
            # pure-decode plans keep the fused megastep (already one
            # dispatch per multi-token horizon); with
            # enable_unified_step=False this two-phase execute is the
            # unified path's parity oracle
            self._dispatch_decode(plan, outs)
            if plan.prefill:
                self._run_prefill_chunks(plan.prefill, outs)

    # ------------------------------------------------------------ drive
    def step(self) -> List[RequestOutput]:
        """One engine iteration under the token budget: the scheduler
        plans decodes first (fused horizon when no prefill is pending,
        one interleaved token otherwise), then packs prefill chunks into
        the remaining budget; the runner executes both halves.  With
        ``enable_chunked_prefill=False`` the pre-budget stop-the-world
        behaviour is preserved as the parity oracle.  Returns the
        ``RequestOutput`` deltas produced by this iteration.

        Robustness rides the same loop: deadlines expire before
        planning, fault-injection sites are consulted at their natural
        points (dispatch wrappers, sampling rows, admission headroom,
        the step wall-clock), a poisoned dispatch lands in the recovery
        path instead of crashing the engine, and the straggler watchdog
        observes every work step's wall time.

        Telemetry rides it too (``enable_telemetry``, default on): the
        whole iteration is an ``engine.step`` span with plan / dispatch
        / readback / detokenize children on ``self.tracer``, which is
        what ``attribution()`` decomposes into per-step host vs device
        milliseconds — see docs/OBSERVABILITY.md.

        With ``enable_async_step`` (default, unified mode) the step is
        PIPELINED: it plans and enqueues its dispatch chained on the
        previous (still in-flight) one, then reads the previous step's
        tokens back — so the returned events run one step behind the
        device, and an extra ``step()`` or two after the scheduler
        drains surfaces the tail (``stream`` / ``run_until_done`` /
        ``close`` handle that)."""
        with self.tracer.span("engine.step", cat="step"):
            if self._detok is not None:
                # async: this step's emissions land on the worker; what
                # surfaces NOW is everything submitted before this step
                # began — one step of slack hides detokenize latency
                # under the in-flight dispatch
                n0 = self._detok.submitted
                tail = self._step_impl()
                outs = self._detok.collect_upto(n0) + tail
            else:
                outs = self._step_impl()
        self._update_gauges()
        return outs

    def _update_gauges(self) -> None:
        """Refresh the point-in-time gauges the ``/metrics`` endpoint
        exposes (plain host floats; never dispatches)."""
        self._g_waiting.set(len(self.scheduler.waiting))
        self._g_running.set(len(self.scheduler.running))
        self._g_free_blocks.set(self.alloc.num_free)
        if self._straggler.ema is not None:
            self._g_step_ema.set(self._straggler.ema * 1e3)

    def _step_impl(self) -> List[RequestOutput]:
        if self._t0 is None:
            self._t0 = time.perf_counter()
        outs: List[RequestOutput] = self._pending  # abort/shed events first
        self._pending = []
        alloc_blocked = False
        if self.faults is not None:
            self.faults.step_begin()
            alloc_blocked = self.faults.alloc_blocked()
        for req in self.scheduler.expire_deadlines():
            self.metrics["deadline_expired"] += 1
            self._emit(req, outs)
        self._advance_probe()
        d0 = self.runner.dispatches
        t_work = time.perf_counter()
        if self.faults is not None:
            stall = self.faults.stall_seconds()
            if stall:           # inside the timed window: the watchdog
                time.sleep(stall)  # must see the stall, like a real one
        try:
            for req in self.scheduler.finish_at_capacity():
                self._emit(req, outs)  # free slots/blocks before admission
            if not self.chunked:
                admitted = self.scheduler.try_admit(alloc_blocked)
                self._mark_admitted([s.req for s in admitted],
                                    time.perf_counter())
                if admitted:
                    self._run_prefill_oracle(admitted, outs)
                for req in self.scheduler.finish_at_capacity():
                    self._emit(req, outs)  # a fresh exactly-cap prefill
                if not self.scheduler.running:  # may be at the boundary
                    return outs
                with self.tracer.span("plan", cat="host"):
                    plan = self._prepare_dispatch(
                        self.max_horizon if self.use_fused else 1)
                self._dispatch_decode(plan, outs)
                return outs
            with self.tracer.span("plan", cat="host"):
                plan = self.scheduler.plan_step(
                    self.max_num_batched_tokens,
                    max_horizon=self.max_horizon if self.use_fused else 1,
                    alloc_blocked=alloc_blocked)
            self._mark_admitted([c.seq.req for c in plan.prefill],
                                time.perf_counter())
            if self.async_step:
                ds = plan.unified_dispatches()
                if len(ds) == 1 and not plan.cow_pairs:
                    # the tentpole fast path (the steady mixed state):
                    # enqueue this step's single unified dispatch chained
                    # on the in-flight one, THEN read the previous step's
                    # tokens back — the new dispatch executes on device
                    # while the host absorbs, plans and detokenizes
                    flight = self._enqueue_unified(ds[0], outs)
                    self._collect_flight(outs)
                    self._flight = flight
                    self.metrics["async_steps"] += 1
                else:
                    # leaving the pipelined regime (pure-decode megastep,
                    # a multi-chunk admission burst, CoW copies, or no
                    # schedulable work): every fallback dispatch donates
                    # its inputs, so the in-flight dispatch is collected
                    # first — and absorbing its tokens may finish
                    # sequences the plan still references, so the plan is
                    # pruned to the survivors
                    if self._flight is not None:
                        self._collect_flight(outs)
                        self._prune_plan(plan)
                    self._dispatch_fallback(plan, outs)
            else:
                self._dispatch_fallback(plan, outs)
            if plan.used:
                self.metrics["plan_steps"] += 1
                self.metrics["budget_tokens_used"] += plan.used
            return outs
        except PoisonedDispatchError as e:
            self._recover(e, outs)
            return outs
        finally:
            used = self.runner.dispatches - d0
            if used:
                self.metrics["device_dispatches"] += used
                self.metrics["work_steps"] += 1
                # the first work step is the jit-compile step: feeding it
                # to the watchdog would seed the EMA ~100x too high and
                # mask every real stall for dozens of steps (the same
                # warm-vs-cold split the decode timers make)
                if self.metrics["work_steps"] > 1:
                    verdict = self._straggler.observe(
                        int(self.metrics["work_steps"]),
                        time.perf_counter() - t_work)
                    if verdict != "ok":
                        self.metrics["slow_steps"] += 1
            # probation clears once every probed rid has made it out of
            # the waiting queue through a CLEAN dispatch (a rid-targeted
            # fault would have failed that dispatch): move to the next
            # suspect group, or lift the allow-set
            if self._probing is not None:
                probe = set(self._probing)
                if not any(r.rid in probe for r in self.scheduler.waiting):
                    self._probing = None
                    self._advance_probe()

    def _work_pending(self) -> bool:
        """Drain condition for ``stream``/``run_until_done``: scheduler
        work, an un-collected in-flight dispatch, or detokenize-worker
        events not yet surfaced through ``step()`` — the async pipeline
        runs the event stream one step behind the device, so the last
        couple of steps exist purely to flush it."""
        return self.scheduler.has_work() or self._flight is not None \
            or bool(self._detok is not None and self._detok.pending())

    def stream(self, max_steps: int = 100000) -> Iterator[RequestOutput]:
        """Yield ``RequestOutput`` deltas as horizons complete — callers
        see first tokens while the batch is still running, and may keep
        calling ``add`` / ``add_request`` between events."""
        steps = 0
        while self._work_pending() and steps < max_steps:
            yield from self.step()
            steps += 1

    def run_until_done(self, max_steps: int = 10000) -> Dict[str, float]:
        steps = 0
        while self._work_pending() and steps < max_steps:
            self.step()
            steps += 1
        return self.report()

    # ------------------------------------------------------------ shutdown
    def close(self) -> List[RequestOutput]:
        """Shut the pipeline down cleanly: read back any in-flight
        dispatch (banking its tokens), drain and join the detokenize
        worker, and return every event not yet surfaced through
        ``step()`` (empty for a drained or synchronous engine).
        Idempotent.  The engine is a context manager — ``with`` calls
        this on exit — and ``launch/serve.py`` calls it on shutdown so
        the worker thread and the in-flight dispatch never outlive the
        server loop."""
        outs: List[RequestOutput] = []
        try:
            self._collect_flight(outs)
        finally:
            if self._detok is not None:
                worker, self._detok = self._detok, None
                outs.extend(worker.close())
        if self._pending:
            outs = self._pending + outs
            self._pending = []
        return outs

    def __enter__(self) -> "ServingEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def reset_dispatch_window(self) -> None:
        """Zero the device-dispatch counters so ``report()``'s
        ``device_dispatches_per_step`` covers only what follows — e.g.
        the steady mixed-workload window after warm-up (compile steps
        and one-off CoW copies land in the warm-up bucket)."""
        self.metrics["device_dispatches"] = 0
        self.metrics["work_steps"] = 0

    def reset_itl_window(self) -> None:
        """Drop accumulated inter-token-latency samples so ``report()``'s
        ITL percentiles cover only what follows — e.g. a steady-state
        window after warm-up/compile steps.  Live requests keep their
        last-event timestamps: a stall in progress still lands in the
        first post-reset sample.  Only the percentile window resets; the
        cumulative ``repro_itl_ms`` histogram buckets on ``/metrics``
        keep the full history."""
        self._h_itl.clear_samples()

    def attribution(self, window: int = 50) -> Dict[str, float]:
        """Steady-state host-vs-device wall-time split per engine step.

        Decomposes the last ``window`` *work* steps (steps that issued
        at least one device dispatch) from the span ring: ``device_ms``
        is dispatch issue + the token-readback sync boundary,
        ``host_ms`` is everything else the step did (plan, absorb,
        detokenize, bookkeeping).  This is the measured form of the
        ROADMAP item 1 diagnosis — the serialized host share the async
        engine has to overlap away.  All-NaN (``steps == 0``) when
        telemetry is disabled or nothing dispatched yet."""
        return attribute_steps(self.tracer.spans(), window=window)

    def _shared_snapshot(self) -> Dict[str, float]:
        """The fields ``report()`` and ``health()`` both expose, computed
        ONCE from the obs registry (the single source of truth) so the
        two views can never drift apart.  Key names are the historical
        ones — both public dicts splat this in unchanged."""
        m = self.metrics
        ema = self._straggler.ema
        return {
            "step_time_ema_ms": ema * 1e3 if ema is not None
            else float("nan"),
            "slow_steps": float(m["slow_steps"]),
            "dispatch_retries": float(m["dispatch_retries"]),
            "quarantined": float(m["quarantined"]),
            "shed": float(m["shed"]),
            "aborted": float(m["aborted"]),
            "deadline_expired": float(m["deadline_expired"]),
            "block_utilization": self.alloc.utilization(),
        }

    def health(self) -> Dict[str, float]:
        """O(1) liveness snapshot for load balancers / operators: queue
        depth, pool pressure, and the robustness counters.  Never
        dispatches, never blocks — safe to poll every step (and what
        the ``/health`` endpoint in ``launch/serve.py`` serves)."""
        return {
            "waiting": float(len(self.scheduler.waiting)),
            "running": float(len(self.scheduler.running)),
            "max_waiting": float(self.max_waiting)
            if self.max_waiting is not None else float("inf"),
            "free_blocks": float(self.alloc.num_free),
            "watermark_blocks": float(self.alloc.watermark),
            **self._shared_snapshot(),
            # rids still under poisoned-dispatch probation (0 = healthy)
            "probing_rids": float(len(self._probing or [])
                                  + sum(len(g) for g in self._suspects)),
        }

    def report(self) -> Dict[str, float]:
        """The paper's three numbers (+ fast-path and streaming counters)."""
        t1 = time.perf_counter()
        wall = max(t1 - (self._t0 or t1), 1e-9)
        fin = self.scheduler.finished
        n = len(fin)
        lat = float(np.mean([r.done_t - r.arrival for r in fin])) \
            if n else float("nan")
        ttft = float(np.mean([r.first_token_t - r.arrival for r in fin
                              if r.first_token_t is not None])) \
            if n else float("nan")
        total_toks = self.metrics["prompt_tokens"] + self.metrics["gen_tokens"]
        d_steps = max(self.metrics["decode_steps"], 1)
        # prefer warm (post-compile) per-step latency when measurable
        if self.metrics["decode_warm_steps"]:
            step_lat = (self.metrics["decode_warm_time_s"]
                        / self.metrics["decode_warm_steps"])
        else:
            step_lat = self.metrics["decode_time_s"] / d_steps
        # inter-token latency percentiles over per-event gaps: under
        # stop-the-world prefill the p99 carries the "one long prompt
        # stalls everyone" spikes the chunked planner bounds at O(chunk)
        itl = np.asarray(self._h_itl.samples(), np.float64)   # already ms
        itl_p50 = float(np.percentile(itl, 50)) if itl.size else float("nan")
        itl_p99 = float(np.percentile(itl, 99)) if itl.size else float("nan")
        plan_steps = self.metrics["plan_steps"]
        budget_util = (self.metrics["budget_tokens_used"]
                       / (plan_steps * self.max_num_batched_tokens)) \
            if plan_steps else float("nan")
        return {
            "latency_s": lat,
            "ttft_s": ttft,
            "itl_p50_ms": itl_p50,
            "itl_p99_ms": itl_p99,
            "queue_wait_p50_ms": self._h_queue_wait.percentile(50),
            "prefill_chunks": self.metrics["prefill_chunks"],
            "prefill_compiles": self.runner.prefill_compiles(),
            # device calls per engine iteration (1.0 in the unified
            # steady mixed state; ~2-3 on the two-call path)
            "device_dispatches_per_step":
                (self.metrics["device_dispatches"]
                 / self.metrics["work_steps"])
                if self.metrics["work_steps"] else float("nan"),
            "budget_utilization": budget_util,
            "throughput_req_s": n / wall,
            "throughput_tok_s": total_toks / wall,
            "generate_tok_s": self.metrics["gen_tokens"] / wall,
            "preemptions": self.metrics["preemptions"],
            # robustness: the same registry-backed block health() serves
            **self._shared_snapshot(),
            "blocks_reused": self.alloc.stats["reused"],
            # pool memory: the figure kv_cache_dtype="int8" halves vs bf16
            "kv_pool_bytes": self.runner.kv_pool_bytes(),
            "kv_bytes_per_token": self.runner.kv_bytes_per_token(),
            "wall_s": wall,
            # iterations that ran pipelined (enqueue-then-collect): > 0
            # proves the async path actually engaged in a bench window
            "async_steps": self.metrics["async_steps"],
            "host_syncs": self.metrics["host_syncs"],
            "decode_dispatches": self.metrics["decode_dispatches"],
            "decode_steps": self.metrics["decode_steps"],
            "decode_step_latency_us": step_lat * 1e6,
            # decode-path syncs only (one per dispatch): prefill-wave syncs
            # are excluded, so legacy reads exactly 1.0 and fused 1/horizon
            "syncs_per_decode_step":
                self.metrics["decode_dispatches"] / d_steps,
        }
