"""``LLM`` — one-line construction of a (quantized) serving stack.

Wires the pieces the paper's system section assembles by hand —
``configs.registry`` (architecture resolution), ``checkpoint.checkpointer``
(weight restore), ``models.quantize`` (RTN / GPTQ int4 artifacts) and the
continuous-batching ``ServingEngine`` — behind a vLLM-shaped facade::

    from repro.serving import LLM, SamplingParams

    llm = LLM.load("qwen2-1.5b", quant="gptq-int4", reduced=True)
    outs = llm.generate(prompts, SamplingParams(top_k=40, stop=[eos]))
    for out in llm.stream(more_prompts, SamplingParams(temperature=0.8)):
        print(out.request_id, out.new_token_ids, out.finish_reason)

Prompts are token-id lists (the repo has no tokenizer); pass
``detokenizer=`` a ``List[int] -> str`` callable to get ``text`` fields.
"""
from __future__ import annotations

from typing import Callable, Iterator, List, Optional, Sequence, Union

import jax
import numpy as np

from repro.configs.base import ModelConfig, QuantConfig
from repro.configs.registry import get_config, get_reduced
from repro.models import transformer as T
from repro.serving.engine import ServingEngine
from repro.serving.params import RequestOutput, SamplingParams

QUANT_MODES = (None, "rtn-int4", "gptq-int4")

Prompt = Sequence[int]


def _synthetic_calib(cfg: ModelConfig, key, n_batches: int = 2,
                     batch: int = 2, seq: int = 32) -> List[dict]:
    """Random-token calibration batches for GPTQ when none are supplied
    (good enough for smoke-scale models; pass real data for quality)."""
    return [{"tokens": jax.random.randint(jax.random.fold_in(key, i),
                                          (batch, seq), 0, cfg.vocab_size)}
            for i in range(n_batches)]


class LLM:
    """Facade owning a config, (possibly quantized) params and an engine."""

    def __init__(self, cfg: ModelConfig, params, *,
                 detokenizer: Optional[Callable[[List[int]], str]] = None,
                 **engine_kw):
        self.cfg = cfg
        self.params = params
        self.engine = ServingEngine(cfg, params, detokenizer=detokenizer,
                                    **engine_kw)

    # ------------------------------------------------------------ builder
    @classmethod
    def load(cls, config_name: str, *, quant: Optional[str] = None,
             kv_cache_dtype: str = "bf16",
             checkpoint: Optional[str] = None, reduced: bool = False,
             overrides: Optional[dict] = None, seed: int = 0,
             quant_group_size: int = 32, calib_batches: Optional[list] = None,
             **engine_kw) -> "LLM":
        """Build a ready-to-serve ``LLM`` from a registry config name.

        quant:      None | "rtn-int4" (round-to-nearest int4 of every
                    matmul weight, any family) | "gptq-int4" (Hessian
                    OBQ over calibration data, dense-family models).
        kv_cache_dtype: "bf16" (dense pool, the parity oracle) | "int8"
                    (quantized paged KV pool: int8 values + per-block-
                    per-head f32 scales, ~2x lower KV bytes/token vs
                    bf16; greedy outputs match bf16 within quantization
                    tolerance — see docs/API.md).
        checkpoint: a ``checkpoint.Checkpointer`` directory; the latest
                    step's ``params`` tree replaces the random init
                    (quantization, if any, runs after the restore).
        reduced:    use the tiny same-family CPU config (tests/demos).
        overrides:  ``ModelConfig.replace`` fields applied after config
                    resolution (e.g. ``num_layers``, ``num_kv_heads``).
        seed:       param init (when no checkpoint) and the engine's
                    default per-request sampling streams.
        engine_kw:  forwarded to ``ServingEngine`` (max_slots,
                    num_blocks, max_blocks_per_seq,
                    max_num_batched_tokens, enable_chunked_prefill,
                    enable_unified_step, enable_async_step,
                    prefill_bucket [oracle path only], rt, use_fused,
                    max_horizon, detokenizer via __init__; robustness:
                    max_waiting, shed_policy, enable_guards,
                    fault_injector, max_dispatch_retries,
                    retry_backoff_s — see docs/API.md "Fault
                    tolerance"; observability: enable_telemetry,
                    trace_capacity, profile_labels — see
                    docs/OBSERVABILITY.md).
                    ``max_num_batched_tokens`` caps the tokens one
                    engine step may batch (decodes first, then prefill
                    chunks); ``enable_chunked_prefill=False`` restores
                    the stop-the-world whole-prompt prefill (the parity
                    oracle); ``enable_unified_step=False`` restores the
                    two-call mixed step (separate decode / chunk /
                    sample dispatches) instead of the default fused
                    single-dispatch iteration;
                    ``enable_async_step=False`` restores the
                    read-back-every-step loop instead of the default
                    one-step-deferred async pipeline (see docs/PERF.md
                    "Async pipeline").
        """
        if quant not in QUANT_MODES:
            raise ValueError(f"unknown quant mode {quant!r}; "
                             f"expected one of {QUANT_MODES}")
        cfg = (get_reduced(config_name, **(overrides or {})) if reduced
               else get_config(config_name))
        if overrides and not reduced:
            cfg = cfg.replace(**overrides)
        key = jax.random.PRNGKey(seed)
        if checkpoint is not None:
            from repro.checkpoint.checkpointer import Checkpointer
            ckpt = Checkpointer(checkpoint)
            step = ckpt.latest_step()
            if step is None:
                raise FileNotFoundError(
                    f"no step_* checkpoints under {checkpoint!r}")
            template = jax.eval_shape(lambda: T.init_params(cfg, key))
            restored, _extra = ckpt.restore(step, {"params": template})
            params = restored["params"]
        else:
            params = T.init_params(cfg, key)

        if quant == "rtn-int4":
            from repro.models.quantize import quantize_params_rtn
            params = quantize_params_rtn(params, cfg,
                                         group_size=quant_group_size)
        elif quant == "gptq-int4":
            from repro.models.quantize import gptq_quantize_model
            if cfg.family not in ("dense", "vlm", "audio"):
                raise ValueError(
                    f"gptq-int4 supports dense-family models, not "
                    f"{cfg.family!r} ({cfg.name}); use quant='rtn-int4'")
            calib = calib_batches or _synthetic_calib(
                cfg, jax.random.fold_in(key, 1))
            params = gptq_quantize_model(
                cfg, params, calib,
                QuantConfig(bits=4, group_size=quant_group_size))
        return cls(cfg, params, seed=seed, kv_cache_dtype=kv_cache_dtype,
                   **engine_kw)

    # ------------------------------------------------------------ serving
    @staticmethod
    def _as_prompt_list(prompts: Union[Prompt, Sequence[Prompt]]
                        ) -> List[List[int]]:
        if prompts and isinstance(prompts[0], (int, np.integer)):
            return [[int(t) for t in prompts]]   # a single prompt
        return [[int(t) for t in p] for p in prompts]

    def _submit(self, prompts, sampling_params) -> List[int]:
        plist = self._as_prompt_list(prompts)
        if sampling_params is None or isinstance(sampling_params,
                                                 SamplingParams):
            sps = [sampling_params] * len(plist)
        else:
            sps = list(sampling_params)
            if len(sps) != len(plist):
                raise ValueError(f"{len(plist)} prompts but "
                                 f"{len(sps)} sampling params")
        return [self.engine.add(p, sp) for p, sp in zip(plist, sps)]

    def generate(self, prompts: Union[Prompt, Sequence[Prompt]],
                 sampling_params: Union[SamplingParams,
                                        Sequence[SamplingParams],
                                        None] = None
                 ) -> List[RequestOutput]:
        """Run all prompts to completion; returns one finished
        ``RequestOutput`` per prompt, in submission order."""
        rids = self._submit(prompts, sampling_params)
        final = {}
        for out in self.engine.stream():
            if out.finished:
                final[out.request_id] = out
        missing = [r for r in rids if r not in final]
        if missing:
            raise RuntimeError(f"requests {missing} did not finish "
                               f"(engine stalled?)")
        return [final[r] for r in rids]

    def abort(self, request_id: int) -> bool:
        """Cancel a request by id (see ``ServingEngine.abort``): KV
        blocks and prefix-hash registrations are released immediately;
        the "aborted" finish event surfaces with the next engine step."""
        return self.engine.abort(request_id)

    def stream(self, prompts: Union[Prompt, Sequence[Prompt]],
               sampling_params: Union[SamplingParams,
                                      Sequence[SamplingParams],
                                      None] = None
               ) -> Iterator[RequestOutput]:
        """Submit prompts and yield ``RequestOutput`` deltas as horizons
        complete — first tokens arrive long before the batch drains. More
        prompts may be added concurrently via ``llm.engine.add``."""
        self._submit(prompts, sampling_params)
        yield from self.engine.stream()

    # ------------------------------------------------------------ shutdown
    def close(self) -> None:
        """Shut the engine down cleanly (flush the async pipeline, join
        the detokenize worker — see ``ServingEngine.close``).  Events
        still in flight are discarded here; drain with ``generate`` /
        ``stream`` first if they matter.  Idempotent; ``with LLM.load(
        ...) as llm:`` calls it automatically."""
        self.engine.close()

    def __enter__(self) -> "LLM":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
