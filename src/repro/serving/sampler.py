"""Token sampling: greedy / temperature / top-k / top-p.

``sample_from_logits`` (re-exported from ``repro.core.sampling``) is the
jit-friendly per-slot core used by both the fused decode megastep and the
legacy loop (via ``ModelRunner.sample``); ``sample`` is a host-facing
convenience wrapper over the legacy single-key batch sampler.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.sampling import sample_device, sample_from_logits

__all__ = ["sample", "sample_device", "sample_from_logits"]


def sample(logits: jnp.ndarray, key, temperatures: Sequence[float],
           top_k: int = 0) -> np.ndarray:
    """Host wrapper: python temperature list in, numpy token ids out."""
    t = jnp.asarray(list(temperatures), jnp.float32)
    return np.asarray(sample_device(logits, key, t, top_k))
