"""Token sampling: greedy / temperature / top-k.

``sample_device`` (re-exported from ``repro.core.sampling``) is the
jit-friendly core used inside the fused decode megastep; ``sample`` is the
host-facing wrapper the prefill path (and legacy per-token decode loop)
calls.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.sampling import sample_device

__all__ = ["sample", "sample_device"]


def sample(logits: jnp.ndarray, key, temperatures: Sequence[float],
           top_k: int = 0) -> np.ndarray:
    """Host wrapper: python temperature list in, numpy token ids out."""
    t = jnp.asarray(list(temperatures), jnp.float32)
    return np.asarray(sample_device(logits, key, t, top_k))
