"""Token sampling: greedy / temperature / top-k."""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def sample(logits: jnp.ndarray, key, temperatures: Sequence[float],
           top_k: int = 0) -> np.ndarray:
    """logits: [B, V]; per-sequence temperature (0 => greedy)."""
    t = jnp.asarray(list(temperatures), jnp.float32)[:, None]
    greedy = jnp.argmax(logits, axis=-1)
    scaled = logits / jnp.maximum(t, 1e-6)
    if top_k > 0:
        kth = jax.lax.top_k(scaled, top_k)[0][:, -1:]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    sampled = jax.random.categorical(key, scaled, axis=-1)
    return np.asarray(jnp.where(t[:, 0] <= 0.0, greedy, sampled))
