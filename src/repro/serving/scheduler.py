"""Host-side continuous-batching scheduler (no device, no jax arrays).

Owns everything the engine decides *about* — admission (watermark +
prompt clamping), slot assignment, block accounting against the
ref-counted ``BlockAllocator``, recompute-style preemption, capacity
force-finishing, and fused-horizon planning — and nothing the device
computes.  ``ModelRunner`` owns the other half.  The split makes every
scheduling policy unit-testable with a plain allocator and fake token
lists (``tests/test_scheduler.py``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.paged_cache import BlockAllocator
from repro.serving.params import FINISH_CAPACITY, SamplingParams


@dataclass
class RequestState:
    """Internal per-request record (host bookkeeping, shared output list).

    ``prompt`` is the *recompute* prompt: preemption folds generated
    tokens into it so re-admission replays them through prefill.
    ``prompt_len0`` keeps the original prompt length for reporting.
    """
    rid: int
    prompt: List[int]
    sampling: SamplingParams = field(default_factory=SamplingParams)
    arrival: float = 0.0
    output: List[int] = field(default_factory=list)
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None
    finish_reason: Optional[str] = None
    emitted: int = 0               # tokens already surfaced via RequestOutput
    folded: int = 0                # output tokens already folded into prompt
    prompt_len0: int = 0
    base_key: Optional[np.ndarray] = None   # [2] uint32 PRNG stream root
    shim: Optional[object] = None  # legacy Request to mirror timestamps to
    text: str = ""                 # detokenized output accumulated so far

    @property
    def prompt_token_ids(self) -> List[int]:
        return self.prompt[:self.prompt_len0 or len(self.prompt)]

    def tokens_remaining(self) -> int:
        return self.sampling.max_tokens - len(self.output)


@dataclass
class Sequence:
    """A running request bound to a decode slot + physical KV blocks."""
    req: RequestState
    slot: int
    block_ids: List[int]
    seq_len: int                   # tokens in cache (incl. last fed)
    last_token: int


class Scheduler:
    """Admission / preemption / horizon planning over a fixed slot set.

    Policies (unchanged from the monolithic engine):
    * prompts longer than the per-sequence KV capacity are clamped at
      admission (an exactly-cap prompt still prefills and yields one
      token before force-finishing);
    * admission is watermark-gated on free blocks, FIFO over ``waiting``;
    * out-of-blocks preempts the *youngest* running sequence back to the
      queue head with its generated tokens folded into the prompt
      (recompute-style, like vLLM);
    * ``plan_horizon`` returns steps-until-boundary: the longest horizon
      every running sequence can decode without host intervention.
    """

    def __init__(self, alloc: BlockAllocator, *, max_slots: int,
                 max_blocks_per_seq: int, ring_only: bool = False,
                 metrics: Optional[Dict[str, float]] = None):
        self.alloc = alloc
        self.max_slots = max_slots
        self.mb = max_blocks_per_seq
        self.ring_only = ring_only
        self.metrics = metrics if metrics is not None else {
            "preemptions": 0, "truncated_prompts": 0}
        self.waiting: List[RequestState] = []
        self.running: Dict[int, Sequence] = {}
        self.finished: List[RequestState] = []
        self.free_slots = list(range(max_slots - 1, -1, -1))
        # hard per-sequence KV capacity: the block table is mb entries wide
        self.cap_tokens = self.mb * self.alloc.block_size

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # ------------------------------------------------------------ intake
    def add(self, req: RequestState) -> None:
        if not req.arrival:
            req.arrival = time.perf_counter()
        if not req.prompt_len0:
            req.prompt_len0 = len(req.prompt)
        self.waiting.append(req)

    # ------------------------------------------------------------ admission
    def try_admit(self) -> List[Sequence]:
        """Admit FIFO while slots and (watermarked) blocks allow; returns
        the newly admitted sequences — the caller must prefill them."""
        admitted: List[Sequence] = []
        while self.waiting and self.free_slots:
            req = self.waiting[0]
            if len(req.prompt) > self.cap_tokens:
                # prompt would overflow the mb-wide block table: clamp it
                # instead of crashing the prefill scatter. Requeued
                # preempted sequences — whose prompt+output never exceeds
                # cap — are never clamped and keep their full context.
                req.prompt = req.prompt[:self.cap_tokens]
                # keep prompt_token_ids == the prompt actually served, so
                # a later preemption fold is never reported as prompt
                req.prompt_len0 = min(req.prompt_len0, self.cap_tokens)
                self.metrics["truncated_prompts"] += 1
            need = (len(req.prompt) + self.alloc.block_size - 1) \
                // self.alloc.block_size + 1
            if not self.alloc.can_allocate(need):
                break
            self.waiting.pop(0)
            block_ids, _reused = self.alloc.allocate_prompt(req.prompt)
            slot = self.free_slots.pop()
            seq = Sequence(req=req, slot=slot, block_ids=block_ids,
                           seq_len=len(req.prompt), last_token=req.prompt[-1])
            self.running[slot] = seq
            admitted.append(seq)
        return admitted

    # ------------------------------------------------------------ capacity
    def writes_left(self, s: Sequence) -> int:
        """Tokens the sequence can still decode before its block table is
        full (next write position is seq_len - 1)."""
        if self.ring_only:
            return 10 ** 9                        # ring slots wrap forever
        return self.cap_tokens - (s.seq_len - 1)

    def finish(self, s: Sequence, reason: str) -> RequestState:
        s.req.done_t = time.perf_counter()
        s.req.finish_reason = reason
        self.finished.append(s.req)
        self.alloc.free_sequence(s.block_ids)
        del self.running[s.slot]
        self.free_slots.append(s.slot)
        return s.req

    def finish_at_capacity(self) -> List[RequestState]:
        """Force-finish sequences whose next KV write would overflow the
        block table (output truncated, finish_reason "capacity")."""
        done = []
        for slot in list(self.running):
            s = self.running[slot]
            if self.writes_left(s) <= 0:
                done.append(self.finish(s, FINISH_CAPACITY))
        return done

    # ------------------------------------------------------------ preemption
    def preempt_youngest(self) -> RequestState:
        slot = max(self.running,
                   key=lambda sl: self.running[sl].req.arrival)
        s = self.running.pop(slot)
        self.alloc.free_sequence(s.block_ids)
        self.free_slots.append(slot)
        self.metrics["preemptions"] += 1
        # recompute-style preemption: requeue with prompt+generated prefix.
        # ``folded`` tracks how much of ``output`` a previous preemption
        # already folded in, so a second preemption replaces that suffix
        # instead of appending the generated tokens twice.
        base = len(s.req.prompt) - s.req.folded
        s.req.prompt = list(s.req.prompt[:base]) + list(s.req.output)
        s.req.folded = len(s.req.output)
        self.waiting.insert(0, s.req)
        return s.req

    # ------------------------------------------------------------ horizon
    def plan_horizon(self, max_horizon: int) -> int:
        """steps_until_boundary: the longest horizon every running sequence
        can decode without host intervention — bounded by tokens remaining
        (finish boundary) and by free KV blocks (allocation boundary).
        Preempts the youngest sequence if even a single step cannot fit."""
        while self.running:
            h = min(max_horizon,
                    min(min(s.req.tokens_remaining(), self.writes_left(s))
                        for s in self.running.values()))
            h = max(1, h)
            if self.ring_only:
                return h
            while h >= 1:
                need = sum(
                    self.alloc.blocks_needed(s.block_ids, s.seq_len - 1, h)
                    for s in self.running.values())
                if need <= self.alloc.num_free:
                    return h
                h -= 1                   # linear: blocks_needed is monotone
            self.preempt_youngest()
        return 0

    def grow_for_horizon(self, h: int) -> List[tuple]:
        """Pre-allocate every KV block an ``h``-step horizon will touch
        (cannot raise: ``plan_horizon`` budgeted it). Returns the CoW
        (src, dst) block pairs the device must copy."""
        cow_pairs = []
        if self.ring_only:
            return cow_pairs                     # ring cache: fixed blocks
        for slot in sorted(self.running):
            s = self.running[slot]
            pos = s.seq_len - 1                  # position the next write hits
            s.block_ids, cow = self.alloc.grow(s.block_ids, pos, h)
            if cow is not None:
                cow_pairs.append(cow)
        return cow_pairs
