"""Host-side continuous-batching scheduler (no device, no jax arrays).

Owns everything the engine decides *about* — admission (watermark +
prompt clamping), slot assignment, block accounting against the
ref-counted ``BlockAllocator``, recompute-style preemption, capacity
force-finishing, and fused-horizon planning — and nothing the device
computes.  ``ModelRunner`` owns the other half.  The split makes every
scheduling policy unit-testable with a plain allocator and fake token
lists (``tests/test_scheduler.py``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

import numpy as np

from repro.core.paged_cache import BlockAllocator
from repro.serving.params import (FINISH_CAPACITY, FINISH_DEADLINE,
                                  SamplingParams)


@dataclass
class RequestState:
    """Internal per-request record (host bookkeeping, shared output list).

    ``prompt`` is the *recompute* prompt: preemption folds generated
    tokens into it so re-admission replays them through prefill.
    ``prompt_len0`` keeps the original prompt length for reporting.
    """
    rid: int
    prompt: List[int]
    sampling: SamplingParams = field(default_factory=SamplingParams)
    arrival: float = 0.0
    output: List[int] = field(default_factory=list)
    first_token_t: Optional[float] = None
    admitted_t: Optional[float] = None    # first admission (queue-wait mark)
    done_t: Optional[float] = None
    finish_reason: Optional[str] = None
    emitted: int = 0               # tokens already surfaced via RequestOutput
    folded: int = 0                # output tokens already folded into prompt
    prompt_len0: int = 0
    base_key: Optional[np.ndarray] = None   # [2] uint32 PRNG stream root
    shim: Optional[object] = None  # legacy Request to mirror timestamps to
    text: str = ""                 # detokenized output accumulated so far
    last_event_t: Optional[float] = None  # previous token-bearing event (ITL)

    @property
    def prompt_token_ids(self) -> List[int]:
        return self.prompt[:self.prompt_len0 or len(self.prompt)]

    def tokens_remaining(self) -> int:
        return self.sampling.max_tokens - len(self.output)


@dataclass
class Sequence:
    """A running request bound to a decode slot + physical KV blocks.

    ``computed_len`` tracks how much of the prompt has been prefilled
    into the KV pool; while ``computed_len < len(req.prompt)`` the
    sequence is mid-prefill (chunked admission) and must not decode.
    Whole-prompt admission sets it to the full prompt length up front.
    """
    req: RequestState
    slot: int
    block_ids: List[int]
    seq_len: int                   # tokens in cache (incl. last fed)
    last_token: int
    computed_len: int = 0          # prompt tokens already in the KV pool
    hashed_blocks: int = 0         # full blocks already content-addressed
    # tokens sampled by an in-flight dispatch the host has not read back
    # yet (async pipelined engine; see Scheduler.speculate/reconcile).
    # Each one is counted into seq_len — the NEXT dispatch feeds it and
    # writes its KV — but not yet into req.output.
    speculated: int = 0

    @property
    def prefilling(self) -> bool:
        return self.computed_len < len(self.req.prompt)


@dataclass
class PrefillChunk:
    """One ``(sequence, chunk_start, chunk_len)`` prefill assignment."""
    seq: Sequence
    start: int                     # == seq.computed_len at planning time
    length: int

    @property
    def last(self) -> bool:
        return self.start + self.length >= len(self.seq.req.prompt)


@dataclass
class UnifiedDispatch:
    """One device dispatch of a unified-mode engine iteration.

    ``decode_slots`` are the rows whose decode sample the host absorbs
    (the unified executable always computes all ``max_slots`` rows; only
    these are live).  ``chunk`` is the dispatch's single prefill chunk.
    ``sample_chunk`` marks the chunk row (row ``max_slots`` of the
    output buffer) as carrying the prompt's first sampled token.
    """
    decode_slots: List[int]
    chunk: PrefillChunk
    sample_chunk: bool


@dataclass
class StepPlan:
    """One token-budget engine iteration, planned entirely on the host.

    ``decode_slots`` decode ``horizon`` tokens each (blocks already
    grown, ``cow_pairs`` pending on device); ``prefill`` chunks run
    after, newest admissions included.  ``used <= budget`` always.
    """
    decode_slots: List[int]
    horizon: int
    cow_pairs: List[tuple]
    prefill: List[PrefillChunk]
    budget: int

    @property
    def used(self) -> int:
        return (len(self.decode_slots) * self.horizon
                + sum(c.length for c in self.prefill))

    def unified_dispatches(self) -> List[UnifiedDispatch]:
        """The plan's unified-dispatch layout (deviceless, unit-testable).

        The FIRST dispatch fuses the step's decodes with the first
        prefill chunk (the single-dispatch steady state of a mixed
        workload: the planner emits at most one chunk per step while
        decodes are interleaving); any further chunks — bursts of fresh
        admissions — each get their own chunk-only dispatch, in plan
        order, with no decode rows.  Empty when the plan has no prefill
        (a pure-decode plan dispatches the fused megastep instead) or
        when the horizon exceeds 1 (never the case when prefill is
        pending — the planner pins it).
        """
        if not self.prefill or self.horizon > 1:
            return []
        return [UnifiedDispatch(
            decode_slots=list(self.decode_slots) if i == 0 else [],
            chunk=c, sample_chunk=c.last)
            for i, c in enumerate(self.prefill)]


class Scheduler:
    """Admission / preemption / horizon planning over a fixed slot set.

    Policies (unchanged from the monolithic engine):
    * prompts longer than the per-sequence KV capacity are clamped at
      admission (an exactly-cap prompt still prefills and yields one
      token before force-finishing);
    * admission is watermark-gated on free blocks, FIFO over ``waiting``;
    * out-of-blocks preempts the *youngest* running sequence back to the
      queue head with its generated tokens folded into the prompt
      (recompute-style, like vLLM);
    * ``plan_horizon`` returns steps-until-boundary: the longest horizon
      every running sequence can decode without host intervention.
    """

    def __init__(self, alloc: BlockAllocator, *, max_slots: int,
                 max_blocks_per_seq: int, ring_only: bool = False,
                 metrics: Optional[Dict[str, float]] = None):
        self.alloc = alloc
        self.max_slots = max_slots
        self.mb = max_blocks_per_seq
        self.ring_only = ring_only
        self.metrics = metrics if metrics is not None else {
            "preemptions": 0, "truncated_prompts": 0}
        self.metrics.setdefault("preemptions_mid_prefill", 0)
        self.waiting: List[RequestState] = []
        self.running: Dict[int, Sequence] = {}
        self.finished: List[RequestState] = []
        self.free_slots = list(range(max_slots - 1, -1, -1))
        # hard per-sequence KV capacity: the block table is mb entries wide
        self.cap_tokens = self.mb * self.alloc.block_size
        # admission allow-set: None admits everyone (the normal state);
        # a set restricts admission to those rids — the engine's
        # poisoned-dispatch bisection probes suspects in isolation while
        # cleared requests keep flowing
        self.allowed_rids: Optional[Set[int]] = None

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # ------------------------------------------------------------ intake
    def add(self, req: RequestState) -> None:
        if not req.arrival:
            req.arrival = time.perf_counter()
        if not req.prompt_len0:
            req.prompt_len0 = len(req.prompt)
        self.waiting.append(req)

    # ------------------------------------------------------------ admission
    def _clamp_prompt(self, req: RequestState) -> None:
        """Prompts longer than the per-sequence KV capacity are clamped at
        admission instead of crashing the prefill scatter.  Requeued
        preempted sequences — whose prompt+output never exceeds cap — are
        never clamped and keep their full context."""
        if len(req.prompt) > self.cap_tokens:
            req.prompt = req.prompt[:self.cap_tokens]
            # keep prompt_token_ids == the prompt actually served, so
            # a later preemption fold is never reported as prompt
            req.prompt_len0 = min(req.prompt_len0, self.cap_tokens)
            self.metrics["truncated_prompts"] += 1

    def _admissible_index(self) -> Optional[int]:
        """Index of the first waiting request the allow-set admits (FIFO
        among admissible; held-back requests are skipped, not overtaken
        — with no allow-set this is simply the queue head)."""
        if self.allowed_rids is None:
            return 0 if self.waiting else None
        for i, req in enumerate(self.waiting):
            if req.rid in self.allowed_rids:
                return i
        return None

    def try_admit(self, alloc_blocked: bool = False) -> List[Sequence]:
        """Whole-prompt admission (the stop-the-world parity oracle):
        admit FIFO while slots and (watermarked) blocks allow; returns
        the newly admitted sequences — the caller must prefill them.
        ``alloc_blocked`` simulates allocator exhaustion (fault
        injection): no admission this step.

        Blocks are content-addressed eagerly so requests admitted in the
        same wave share their common prefix.  Safe under faults: a
        reusing prompt always *rewrites* the shared block bit-identically
        rather than trusting its contents, and every failure path this
        engine has (abort, deadline, shed, poisoned-dispatch requeue)
        frees the blocks, which drops their hash entries at refcount 0 —
        no stale prefix-cache entry survives a failed wave."""
        admitted: List[Sequence] = []
        while self.free_slots and not alloc_blocked:
            idx = self._admissible_index()
            if idx is None:
                break
            req = self.waiting[idx]
            self._clamp_prompt(req)
            need = (len(req.prompt) + self.alloc.block_size - 1) \
                // self.alloc.block_size + 1
            if not self.alloc.can_allocate(need):
                break
            self.waiting.pop(idx)
            block_ids, _reused = self.alloc.allocate_prompt(req.prompt)
            slot = self.free_slots.pop()
            seq = Sequence(req=req, slot=slot, block_ids=block_ids,
                           seq_len=len(req.prompt), last_token=req.prompt[-1],
                           computed_len=len(req.prompt),
                           hashed_blocks=len(req.prompt)
                           // self.alloc.block_size)
            self.running[slot] = seq
            admitted.append(seq)
        return admitted

    def register_written(self, s: Sequence) -> None:
        """Content-address any full prompt block not yet hashed (no-op
        after eager admission registration; kept as the engine's
        post-write invariant hook for the whole-prompt oracle — the
        chunked path's equivalent is ``complete_chunk``)."""
        bs = self.alloc.block_size
        full = min(s.computed_len, len(s.req.prompt)) // bs
        for i in range(s.hashed_blocks, full):
            self.alloc.register_full_block(s.block_ids[i],
                                           s.req.prompt[:(i + 1) * bs])
        s.hashed_blocks = max(s.hashed_blocks, full)

    # ------------------------------------------------------------ capacity
    def writes_left(self, s: Sequence) -> int:
        """Tokens the sequence can still decode before its block table is
        full (next write position is seq_len - 1)."""
        if self.ring_only:
            return 10 ** 9                        # ring slots wrap forever
        return self.cap_tokens - (s.seq_len - 1)

    def finish(self, s: Sequence, reason: str) -> RequestState:
        s.req.done_t = time.perf_counter()
        s.req.finish_reason = reason
        self.finished.append(s.req)
        self.alloc.free_sequence(s.block_ids)
        del self.running[s.slot]
        self.free_slots.append(s.slot)
        return s.req

    def finish_at_capacity(self) -> List[RequestState]:
        """Force-finish sequences whose next KV write would overflow the
        block table (output truncated, finish_reason "capacity")."""
        done = []
        for slot in list(self.running):
            s = self.running[slot]
            if self.writes_left(s) <= 0 and not s.speculated:
                # a speculated slot at the capacity wall still has its
                # last token in flight: finishing now would discard it
                # (the synchronous engine absorbs that token *before*
                # this check runs).  The slot is decode-ineligible
                # (``decodable`` filters it), its token lands at the
                # next reconcile, and THIS check force-finishes it one
                # step later — same final output, token kept.
                done.append(self.finish(s, FINISH_CAPACITY))
        return done

    # ------------------------------------------------------------ deadlines
    def _deadline_hit(self, req: RequestState, now: float) -> bool:
        sp = req.sampling
        elapsed_ms = (now - req.arrival) * 1e3
        if sp.deadline_ms is not None and elapsed_ms > sp.deadline_ms:
            return True
        return (sp.ttft_deadline_ms is not None
                and req.first_token_t is None
                and elapsed_ms > sp.ttft_deadline_ms)

    def expire_deadlines(self) -> List[RequestState]:
        """Finish every request past its deadline (finish_reason
        "deadline"), wherever it is in the lifecycle: still waiting
        (just dequeued — it holds nothing), mid-prefill-chunk or decoding
        (KV blocks and slot released this step).  Partial output is
        kept."""
        now = time.perf_counter()
        done: List[RequestState] = []
        for req in [r for r in self.waiting if self._deadline_hit(r, now)]:
            self.waiting.remove(req)
            req.done_t = now
            req.finish_reason = FINISH_DEADLINE
            self.finished.append(req)
            done.append(req)
        for slot in list(self.running):
            s = self.running[slot]
            if self._deadline_hit(s.req, now):
                done.append(self.finish(s, FINISH_DEADLINE))
        return done

    # ------------------------------------------------------------ abort
    def abort(self, rid: int, reason: str) -> Optional[RequestState]:
        """Cancel a request by id, wherever it is: waiting (dequeued),
        mid-prefill-chunk or decoding (blocks + slot freed the same
        step, including partially-grown chunk blocks — ``block_ids``
        always reflects every grow).  Returns the finished record, or
        None if the rid is unknown / already finished."""
        for req in self.waiting:
            if req.rid == rid:
                self.waiting.remove(req)
                req.done_t = time.perf_counter()
                req.finish_reason = reason
                self.finished.append(req)
                return req
        for s in self.running.values():
            if s.req.rid == rid:
                return self.finish(s, reason)
        return None

    # ------------------------------------------------------------ preemption
    def _requeue(self, slot: int) -> RequestState:
        """Recompute-style requeue of a running sequence: free its KV
        blocks + slot, fold generated tokens into the prompt, and put it
        back at the queue head — re-admission replays everything through
        prefill (token-exact: the sampling stream position survives via
        ``counts``)."""
        s = self.running.pop(slot)
        self.alloc.free_sequence(s.block_ids)
        self.free_slots.append(slot)
        self.metrics["preemptions"] += 1
        if s.prefilling:
            # partially-computed prompt: blocks freed, and because the
            # Sequence record dies here, re-admission restarts the chunk
            # walk from computed_len = 0 (recompute-style, like decode)
            self.metrics["preemptions_mid_prefill"] += 1
        # recompute-style preemption: requeue with prompt+generated prefix.
        # ``folded`` tracks how much of ``output`` a previous preemption
        # already folded in, so a second preemption replaces that suffix
        # instead of appending the generated tokens twice.
        base = len(s.req.prompt) - s.req.folded
        s.req.prompt = list(s.req.prompt[:base]) + list(s.req.output)
        s.req.folded = len(s.req.output)
        self.waiting.insert(0, s.req)
        return s.req

    def preempt_youngest(self) -> RequestState:
        slot = max(self.running,
                   key=lambda sl: self.running[sl].req.arrival)
        return self._requeue(slot)

    def preempt_request(self, rid: int) -> Optional[RequestState]:
        """Targeted recompute-style requeue (the poisoned-dispatch
        recovery path): same machinery as ``preempt_youngest``, aimed at
        one request.  None if the rid is not currently running."""
        for slot, s in self.running.items():
            if s.req.rid == rid:
                return self._requeue(slot)
        return None

    # ------------------------------------------------------------ speculation
    def speculate(self, s: Sequence) -> None:
        """Mark one sampled-but-not-read-back token on ``s`` (async
        pipelined engine, at dispatch enqueue): the token is counted
        into ``seq_len`` immediately — the next dispatch feeds it and
        writes its KV at ``seq_len - 1``, so every planner position
        computation (block growth, writes_left, capacity) sees exactly
        the state the synchronous engine would after absorbing it —
        while ``speculated`` remembers it is not yet in ``req.output``
        (``decodable``/``plan_horizon`` subtract it from the tokens-
        remaining budget: plan as if no slot finishes)."""
        s.seq_len += 1
        s.speculated += 1

    def reconcile(self, s: Sequence) -> None:
        """Retire one speculated token at readback (just before the
        engine absorbs it): the absorb path re-increments ``seq_len``
        itself, so the speculative bump is unwound here and absorb stays
        the single source of truth for output/stop/finish bookkeeping.
        A sequence that finished, aborted, or was preempted mid-flight
        is never reconciled — its Sequence record (and the speculative
        bump with it) is already gone and the in-flight token is simply
        discarded."""
        s.seq_len -= 1
        s.speculated -= 1

    # ------------------------------------------------------------ horizon
    def decodable(self) -> Dict[int, Sequence]:
        """Running sequences whose prompt is fully in the KV pool — the
        only ones a decode dispatch may touch (mid-prefill sequences hold
        their slot and blocks but contribute no decode work).  Slots
        whose in-flight speculated token already exhausts their
        max_tokens budget or their block table sit out too: planning
        them would decode past the boundary the synchronous engine
        finishes at.  Both extra filters are scoped to speculated slots
        so non-speculating callers (the synchronous engine, the oracle
        path, standalone planner tests) see the historical behavior
        unchanged — there absorb and finish_at_capacity retire such
        slots before planning ever sees them."""
        return {sl: s for sl, s in self.running.items()
                if not s.prefilling
                and (not s.speculated
                     or (s.req.tokens_remaining() - s.speculated > 0
                         and self.writes_left(s) > 0))}

    def plan_horizon(self, max_horizon: int) -> int:
        """steps_until_boundary: the longest horizon every decodable
        sequence can decode without host intervention — bounded by tokens
        remaining (finish boundary, minus any in-flight speculated
        token) and by free KV blocks (allocation boundary).  Preempts
        the youngest *running* sequence (possibly a mid-prefill one) if
        even a single step cannot fit."""
        while True:
            dec = list(self.decodable().values())
            if not dec:
                return 0
            h = min(max_horizon,
                    min(min(s.req.tokens_remaining() - s.speculated,
                            self.writes_left(s))
                        for s in dec))
            h = max(1, h)
            if self.ring_only:
                return h
            while h >= 1:
                need = sum(
                    self.alloc.blocks_needed(s.block_ids, s.seq_len - 1, h)
                    for s in dec)
                if need <= self.alloc.num_free:
                    return h
                h -= 1                   # linear: blocks_needed is monotone
            self.preempt_youngest()

    def grow_for_horizon(self, h: int) -> List[tuple]:
        """Pre-allocate every KV block an ``h``-step horizon will touch
        (cannot raise: ``plan_horizon`` budgeted it). Returns the CoW
        (src, dst) block pairs the device must copy."""
        cow_pairs = []
        if self.ring_only:
            return cow_pairs                     # ring cache: fixed blocks
        for slot in sorted(self.decodable()):
            s = self.running[slot]
            pos = s.seq_len - 1                  # position the next write hits
            s.block_ids, cow = self.alloc.grow(s.block_ids, pos, h)
            if cow is not None:
                cow_pairs.append(cow)
        return cow_pairs

    # ------------------------------------------------------------ step plan
    def _pool_feasible(self, req: RequestState) -> bool:
        """Whether the (clamped) prompt could EVER fit this pool whole —
        the same bound whole-prompt admission enforces.  Infeasible
        prompts stay waiting without blocking anything else."""
        n = min(len(req.prompt), self.cap_tokens)
        return -(-n // self.alloc.block_size) + 1 \
            <= self.alloc.num_blocks - self.alloc.watermark

    def _chunk_fit(self, block_ids: List[int], start: int, want: int) -> int:
        """Largest chunk length <= ``want`` whose KV blocks fit the free
        pool right now (prefill chunks never CoW: a chunk's boundary block
        is either this sequence's private partial tail or a fresh block)."""
        bs = self.alloc.block_size
        slack = len(block_ids) * bs - start      # room in allocated blocks
        return min(want, max(0, slack) + self.alloc.num_free * bs)

    def _prefill_runnable(self, alloc_blocked: bool = False) -> bool:
        """Whether at least one prefill chunk could actually be scheduled
        THIS step — the only case worth pinning the decode horizon to 1
        for.  A mid-prefill sequence must have room for >= 1 token; a
        waiting prompt additionally needs a free slot, a pool it can
        ever fit, and watermarked headroom right now.  Anything else
        (full slots, zero headroom, forever-infeasible head, a blocked
        allocator) cannot progress regardless, so decodes keep the full
        fused horizon."""
        if alloc_blocked:
            return False
        for s in self.running.values():
            if s.prefilling and \
                    self._chunk_fit(s.block_ids, s.computed_len, 1) > 0:
                return True
        idx = self._admissible_index()
        return bool(idx is not None and self.free_slots
                    and self._pool_feasible(self.waiting[idx])
                    and self.alloc.num_free > self.alloc.watermark)

    def plan_step(self, max_num_batched_tokens: int,
                  max_horizon: int = 1,
                  alloc_blocked: bool = False) -> StepPlan:
        """Fill one token budget: running decodes first (decode-priority,
        so inter-token latency stays bounded), then prefill *chunks* of
        partially-admitted prompts, then fresh admissions into whatever
        budget remains.  Block allocation is incremental — each chunk
        grows only the blocks it will write — and decode blocks are
        reserved before any chunk's, so a prompt can never starve the
        decodes out of their next write.

        While prefill work is pending the decode horizon is pinned to 1
        (one decode token per sequence per iteration interleaved with
        chunks); with no prefill in flight the full fused horizon is
        planned, recovering the megastep steady state.

        ``alloc_blocked`` (fault injection: the allocator reports
        exhaustion) suppresses everything that would *take new blocks
        for new work* — chunk growth, fresh admission, and the
        deadlock-guard eviction — while already-running decodes keep
        their pre-budgeted growth and continue unharmed."""
        budget = max_num_batched_tokens
        h = self.plan_horizon(1 if self._prefill_runnable(alloc_blocked)
                              else min(max_horizon,
                                       max(1, budget
                                           // max(1, len(self.decodable())))))
        cow = self.grow_for_horizon(h) if h else []
        dec_slots = sorted(self.decodable()) if h else []
        if len(dec_slots) * h > budget:
            # degenerate budget <= decodable count (the engine forbids it,
            # but StepPlan's used <= budget contract holds standalone too):
            # the overflow slots simply sit this iteration out — their
            # pre-grown blocks stay owned and they decode next step
            dec_slots = dec_slots[:budget // h]
        rem = budget - len(dec_slots) * h
        if alloc_blocked:
            rem = 0                      # no chunk growth, no admission
        chunks: List[PrefillChunk] = []
        # continue partially-prefilled prompts first, oldest arrival first
        for s in sorted((s for s in self.running.values() if s.prefilling),
                        key=lambda s: (s.req.arrival, s.slot)):
            if rem <= 0:
                break
            want = min(rem, len(s.req.prompt) - s.computed_len)
            length = self._chunk_fit(s.block_ids, s.computed_len, want)
            if length <= 0:
                continue
            # content-addressed growth: full blocks this chunk will cover
            # may be shared with an identical live prefix (register-on-
            # write hashing makes continuation blocks discoverable)
            s.block_ids, _ = self.alloc.grow_prefill(
                s.block_ids, s.computed_len, length, s.req.prompt)
            chunks.append(PrefillChunk(seq=s, start=s.computed_len,
                                       length=length))
            rem -= length
        # fresh admissions: first chunk is watermark-gated like whole-
        # prompt admission; full blocks become content-addressed once the
        # chunk's device write is confirmed (``complete_chunk``), so
        # prefix reuse still applies to whatever the first chunk covers
        while rem > 0 and self.free_slots:
            idx = self._admissible_index()
            if idx is None:
                break
            req = self.waiting[idx]
            self._clamp_prompt(req)
            bs = self.alloc.block_size
            if not self._pool_feasible(req):
                # the whole prompt can never fit this pool: leave it
                # waiting (exactly like whole-prompt admission) instead
                # of parking a forever-stuck partial prefill on blocks
                break
            length = min(rem, len(req.prompt))
            headroom = (self.alloc.num_free - self.alloc.watermark) * bs
            length = min(length, max(0, headroom))
            if length <= 0:
                break
            self.waiting.pop(idx)
            block_ids, _ = self.alloc.allocate_prompt(req.prompt[:length])
            slot = self.free_slots.pop()
            seq = Sequence(req=req, slot=slot, block_ids=block_ids,
                           seq_len=0, last_token=req.prompt[-1],
                           computed_len=0,
                           hashed_blocks=length // self.alloc.block_size)
            self.running[slot] = seq
            chunks.append(PrefillChunk(seq=seq, start=0, length=length))
            rem -= length
        if not dec_slots and not chunks and not alloc_blocked \
                and len(self.running) > 1 \
                and any(s.prefilling for s in self.running.values()):
            # every runnable path is blocked on KV blocks held by newer
            # sequences: evict the youngest so the oldest makes progress
            # next iteration instead of deadlocking
            self.preempt_youngest()
        return StepPlan(decode_slots=dec_slots, horizon=h, cow_pairs=cow,
                        prefill=chunks, budget=budget)

    def complete_chunk(self, chunk: PrefillChunk) -> None:
        """Advance host bookkeeping after the device executed a chunk,
        and content-address the blocks the chunk just filled (register-
        on-write): every newly *full* block becomes discoverable for
        cross-request prefix reuse — ``allocate_prompt`` only hashes the
        first chunk's blocks, so without this a multi-chunk prompt's
        later blocks could never be shared."""
        s = chunk.seq
        s.computed_len = chunk.start + chunk.length
        s.seq_len = s.computed_len
        bs = self.alloc.block_size
        full = s.computed_len // bs
        # only blocks this chunk covered WHOLE are registered: a block
        # straddling the chunk start went through the int8 boundary
        # dequant-merge-requant, so its pool bytes differ from the fresh
        # full-block quantize a reusing sequence would rewrite it with —
        # sharing it would let that rewrite perturb this sequence's KV.
        # (bf16 merges are exact, but the rule stays uniform.)
        first = max(s.hashed_blocks, -(-chunk.start // bs))
        for i in range(first, full):
            self.alloc.register_full_block(s.block_ids[i],
                                           s.req.prompt[:(i + 1) * bs])
        s.hashed_blocks = max(s.hashed_blocks, full)
