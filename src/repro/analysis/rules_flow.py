"""R5 — traced-control-flow.

Python ``if``/``while`` on a traced value inside a jitted body raises
``TracerBoolConversionError`` at trace time — but only on the paths a
test actually traces, so CPU-interpret suites can pass while the TPU
path is broken.  This rule finds them statically:

1. seed traced-parameter sets from the jit registry (lambda sites trace
   their lambda params, named sites everything except
   ``static_argnames``),
2. propagate interprocedurally: a callee param becomes traced when a
   call from a traced function passes it a non-static expression; a
   function passed *by name* (a ``pallas_call`` kernel body,
   ``functools.partial(_kernel, ...)``) gets its ``*_ref`` params and
   vararg traced, so partial-bound literal kwargs stay static,
3. inside every function with traced params, flag ``if``/``while``
   whose test is not provably static.

"Static" is deliberately generous — ``.shape``/``.dtype``/``.ndim``,
``len()``/``isinstance()``, ``x is (not) None``, ``key in tree``, and
anything built only from non-traced names — because a false positive
here teaches people to sprinkle allows.
"""
from __future__ import annotations

import ast
from collections import deque
from typing import Dict, List, Optional, Set

from repro.analysis.callgraph import CallGraph
from repro.analysis.findings import Finding, finalize_occurrences
from repro.analysis.jit_registry import JitRegistry
from repro.analysis.project import FunctionInfo, Project, call_name

RULE = "R5"

_META_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize", "sharding",
               "aval", "weak_type"}
_STATIC_CALLS = {"len", "isinstance", "hasattr", "callable", "type",
                 "getattr", "range", "id", "repr", "str"}


def _static_properties(project: Project) -> Set[str]:
    """Names of ``@property`` methods on project classes whose return
    value is static even on a traced instance — e.g. ``KVCache.quantized``
    returning ``self.k_scale is not None``.  Branching on those is pytree
    structure, not a traced value."""
    props: Set[str] = set()
    for mod in project.modules:
        for fn in mod.functions.values():
            node = fn.node
            if not isinstance(node, ast.FunctionDef) \
                    or fn.class_name is None:
                continue
            if not any(isinstance(d, ast.Name) and d.id == "property"
                       for d in node.decorator_list):
                continue
            rets = [s.value for s in ast.walk(node)
                    if isinstance(s, ast.Return) and s.value is not None]
            if rets and all(_is_static(r, {"self"}) for r in rets):
                props.add(node.name)
    return props


def _is_static(node: ast.AST, traced: Set[str],
               static_attrs: Set[str] = frozenset()) -> bool:
    def rec(n):
        if n is None or isinstance(n, ast.Constant):
            return True
        if isinstance(n, ast.Name):
            return n.id not in traced
        if isinstance(n, ast.Attribute):
            if n.attr in _META_ATTRS or n.attr in static_attrs:
                return True
            return rec(n.value)
        if isinstance(n, ast.Subscript):
            return rec(n.value) and rec(n.slice)
        if isinstance(n, ast.Call):
            if call_name(n).split(".")[-1] in _STATIC_CALLS:
                return True
            return (rec(n.func) and all(rec(a) for a in n.args)
                    and all(rec(k.value) for k in n.keywords))
        if isinstance(n, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in n.ops):
                return True             # identity checks are python-level
            if all(isinstance(op, (ast.In, ast.NotIn)) for op in n.ops):
                return True             # pytree / dict key membership
            return all(rec(c) for c in [n.left] + n.comparators)
        if isinstance(n, ast.Lambda):
            return True
        if isinstance(n, (ast.BoolOp, ast.BinOp, ast.UnaryOp, ast.IfExp,
                          ast.Tuple, ast.List, ast.Set, ast.Dict,
                          ast.JoinedStr, ast.FormattedValue, ast.Starred,
                          ast.Slice)):
            return all(rec(c) for c in ast.iter_child_nodes(n)
                       if isinstance(c, (ast.expr, ast.Slice)))
        if isinstance(n, (ast.ListComp, ast.SetComp, ast.DictComp,
                          ast.GeneratorExp)):
            return all(rec(g.iter) for g in n.generators) \
                and all(rec(c) for c in ast.iter_child_nodes(n)
                        if isinstance(c, ast.expr))
        return True                     # unknown shapes: stay quiet

    return rec(node)


def _target_names(tgt: ast.expr) -> Set[str]:
    return {n.id for n in ast.walk(tgt) if isinstance(n, ast.Name)}


def _bind(params: List[str], call: ast.Call) -> Dict[str, ast.expr]:
    bound: Dict[str, ast.expr] = {}
    for i, arg in enumerate(call.args):
        if isinstance(arg, ast.Starred):
            break
        if i < len(params):
            bound[params[i]] = arg
    for kw in call.keywords:
        if kw.arg is not None:
            bound[kw.arg] = kw.value
    return bound


class FlowChecker:
    def __init__(self, project: Project):
        self.project = project
        self.registry = JitRegistry(project)
        self.graph = CallGraph(project)
        self.static_attrs = _static_properties(project)
        # FunctionInfo.ref -> set of traced parameter names
        self.traced_params: Dict[str, Set[str]] = {}
        self.queue = deque()
        self._seed()
        self._fixpoint()

    # ------------------------------------------------------------- seeds
    def _mark(self, fn: Optional[FunctionInfo], params: Set[str]) -> None:
        if fn is None or not params:
            return
        cur = self.traced_params.setdefault(fn.ref, set())
        if not params <= cur:
            cur |= params
            self.queue.append(fn.ref)

    def _seed(self) -> None:
        for site in self.registry.all_sites():
            statics = set(site.static_names)
            if site.fn_info is not None:
                fn = site.fn_info
                self._mark(fn, {p for p in fn.positional_params
                                if p not in statics})
            elif site.fn_lambda is not None:
                mod = self.project.by_rel.get(site.module_rel)
                if mod is None:
                    continue
                lam_params = {p.arg for p in site.fn_lambda.args.args
                              if p.arg not in statics}
                holder = FunctionInfo(qualname=f"<jit:{site.name}>",
                                      module=mod, node=site.fn_lambda)
                self._propagate_calls(holder, site.fn_lambda.body,
                                      lam_params, class_name=None)

    # ---------------------------------------------------------- fixpoint
    def _fixpoint(self) -> None:
        while self.queue:
            ref = self.queue.popleft()
            fn = self.project.function(ref)
            if fn is None:
                continue
            traced = self._local_traced(fn, self.traced_params[ref],
                                        findings=None)
            self._propagate_calls(fn, fn.node, traced, fn.class_name)

    def _propagate_calls(self, fn: FunctionInfo, root: ast.AST,
                         traced: Set[str],
                         class_name: Optional[str]) -> None:
        for call in (n for n in ast.walk(root)
                     if isinstance(n, ast.Call)):
            callee = self._resolve(fn, call, class_name)
            if callee is not None:
                hot = {p for p, arg in
                       _bind(callee.positional_params, call).items()
                       if not _is_static(arg, traced, self.static_attrs)}
                self._mark(callee, hot)
            # functions passed by name: kernel bodies, partial targets
            for arg in list(call.args) + [k.value for k in call.keywords]:
                if isinstance(arg, ast.Name):
                    cb = fn.module.functions.get(
                        f"{fn.qualname}.{arg.id}") \
                        or self.project.resolve_symbol(fn.module, arg.id)
                    if cb is not None and isinstance(
                            cb.node,
                            (ast.FunctionDef, ast.AsyncFunctionDef)):
                        refs = {p for p in cb.params
                                if p.endswith("_ref")
                                or p.startswith("*")}
                        self._mark(cb, refs)

    def _resolve(self, fn: FunctionInfo, call: ast.Call,
                 class_name: Optional[str]) -> Optional[FunctionInfo]:
        f = call.func
        if isinstance(f, ast.Name):
            return fn.module.functions.get(f"{fn.qualname}.{f.id}") \
                or self.project.resolve_symbol(fn.module, f.id)
        if isinstance(f, ast.Attribute):
            if isinstance(f.value, ast.Name) and f.value.id == "self" \
                    and class_name:
                return self.graph._method(class_name, f.attr)
            return self.project.resolve_attr_call(fn.module, f.value,
                                                  f.attr)
        return None

    # --------------------------------------------------- per-fn analysis
    def _local_traced(self, fn: FunctionInfo, seed: Set[str],
                      findings: Optional[List[Finding]]) -> Set[str]:
        """Forward pass over the body: returns the final traced-name set;
        when ``findings`` is given, flags traced if/while tests."""
        traced = set(seed)

        def visit(body):
            for stmt in body:
                if isinstance(stmt, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    continue
                if isinstance(stmt, (ast.Assign, ast.AnnAssign,
                                     ast.AugAssign)):
                    value = getattr(stmt, "value", None)
                    targets = stmt.targets \
                        if isinstance(stmt, ast.Assign) else [stmt.target]
                    names = set()
                    for t in targets:
                        names |= _target_names(t)
                    if value is not None \
                            and _is_static(value, traced,
                                           self.static_attrs) \
                            and not isinstance(stmt, ast.AugAssign):
                        traced.difference_update(names)
                    elif value is not None:
                        traced.update(names)
                elif isinstance(stmt, ast.For):
                    if not _is_static(stmt.iter, traced,
                                      self.static_attrs):
                        traced.update(_target_names(stmt.target))
                    else:
                        traced.difference_update(
                            _target_names(stmt.target))
                    visit(stmt.body)
                    visit(stmt.orelse)
                elif isinstance(stmt, (ast.If, ast.While)):
                    if findings is not None \
                            and not _is_static(stmt.test, traced,
                                               self.static_attrs):
                        kind = "flow.traced-branch" \
                            if isinstance(stmt, ast.If) \
                            else "flow.traced-loop"
                        word = "if" if isinstance(stmt, ast.If) \
                            else "while"
                        findings.append(Finding(
                            RULE, fn.module.rel, fn.qualname, kind,
                            f"python `{word} "
                            f"{ast.unparse(stmt.test)}:` branches on a "
                            "traced value inside a jitted body — use "
                            "jnp.where / lax.cond / lax.while_loop",
                            stmt.lineno))
                    visit(stmt.body)
                    visit(stmt.orelse)
                elif isinstance(stmt, ast.Try):
                    visit(stmt.body)
                    for h in stmt.handlers:
                        visit(h.body)
                    visit(stmt.orelse)
                    visit(stmt.finalbody)
                elif isinstance(stmt, ast.With):
                    visit(stmt.body)

        visit(fn.node.body if not isinstance(fn.node, ast.Lambda) else [])
        return traced

    # ------------------------------------------------------------ report
    def check(self) -> List[Finding]:
        findings: List[Finding] = []
        for ref in sorted(self.traced_params):
            fn = self.project.function(ref)
            if fn is None or not self.traced_params[ref]:
                continue
            self._local_traced(fn, self.traced_params[ref], findings)
        return findings


def check_traced_flow(project: Project) -> List[Finding]:
    return finalize_occurrences(FlowChecker(project).check())
