"""R1 — host-sync-in-hot-path.

Taint analysis over the serving hot loop: values produced by jit
executables (or jnp ops) are DEVICE; converting a DEVICE value to host
data blocks the host on the device stream.  Sinks flagged:

* ``np.asarray(x)`` / ``np.array(x)`` — implicit device->host copy
* ``jax.device_get(x)``
* ``x.item()`` / ``x.tolist()``
* ``int(x)`` / ``float(x)`` / ``bool(x)``
* iterating a device array (``for v in x``)
* branching on a device array (``if x: ... `` / ``while x:``)

Only *definitely-device* values fire — UNKNOWN stays silent, so the
scheduler's host-numpy bookkeeping produces no noise.  The planned
token readbacks (one per dispatch) are real findings carried in
``analysis/baseline.json`` with justifications; anything new is creep
the CI gate refuses.

Cross-function precision comes from summaries: every project function
gets a return-taint summary (fixpoint over 3 passes), including the
"returns the result of calling its callable parameter" shape so
``self._protected(rids, lambda: self.runner.megastep(...))`` carries
the lambda body's taint to the call site.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

from repro.analysis.callgraph import CallGraph
from repro.analysis.findings import Finding, finalize_occurrences
from repro.analysis.jit_registry import JitRegistry
from repro.analysis.project import FunctionInfo, Project, call_name

RULE = "R1"

DEVICE, HOST, UNKNOWN = "device", "host", "unknown"

# attribute reads that are host metadata even on a device array
_META_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize", "sharding"}
_DEVICE_ROOTS = ("jnp.", "jax.numpy.", "jax.lax.", "jax.random.", "jax.nn.",
                 "jax.scipy.")
_DEVICE_CALLS = {"jax.device_put", "jax.tree.map", "jax.vmap"}
_HOST_ROOTS = ("np.", "numpy.", "math.", "time.", "os.")
_CAST_SINKS = {"int", "float", "bool"}
_COPY_SINKS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}


class Tup:
    """Taint of a tuple value (elementwise)."""
    def __init__(self, elts):
        self.elts = list(elts)


class ListOf:
    """Taint of a homogeneous container (element taint)."""
    def __init__(self, item):
        self.item = item


def _join(a, b):
    if isinstance(a, Tup) and isinstance(b, Tup) \
            and len(a.elts) == len(b.elts):
        return Tup([_join(x, y) for x, y in zip(a.elts, b.elts)])
    if isinstance(a, ListOf) and isinstance(b, ListOf):
        return ListOf(_join(a.item, b.item))
    if a == b:
        return a
    if UNKNOWN in (a, b) or isinstance(a, (Tup, ListOf)) \
            or isinstance(b, (Tup, ListOf)):
        return UNKNOWN
    # host vs device disagree -> unknown (silent)
    return UNKNOWN


def _scalar(t):
    """Collapse compound taints for contexts that need a plain one."""
    if isinstance(t, Tup):
        if any(_scalar(e) == DEVICE for e in t.elts):
            return DEVICE
        return UNKNOWN if any(_scalar(e) == UNKNOWN for e in t.elts) else HOST
    if isinstance(t, ListOf):
        return _scalar(t.item)
    return t


class _Summary:
    """Per-function summary: return taint, or 'calls param i'."""
    def __init__(self):
        self.ret = UNKNOWN
        self.calls_param: Optional[int] = None  # positional index incl self


class SyncAnalyzer:
    def __init__(self, project: Project):
        self.project = project
        self.graph = CallGraph(project)
        self.registry = JitRegistry(project)
        self.summaries: Dict[str, _Summary] = {}
        self._detect_param_calls()
        for _ in range(3):                      # summary fixpoint
            for fn in project.all_functions():
                self._summarize(fn)

    # ------------------------------------------------------- summaries
    def _detect_param_calls(self) -> None:
        for fn in self.project.all_functions():
            s = self.summaries.setdefault(fn.ref, _Summary())
            params = fn.positional_params
            for node in ast.walk(fn.node):
                if (isinstance(node, ast.Return) and node.value is not None
                        and isinstance(node.value, ast.Call)
                        and isinstance(node.value.func, ast.Name)
                        and node.value.func.id in params):
                    s.calls_param = params.index(node.value.func.id)

    def _summarize(self, fn: FunctionInfo) -> None:
        env: Dict[str, object] = {}
        rets: List[object] = []
        self._walk_body(fn, list(fn.node.body), env, rets, findings=None)
        s = self.summaries.setdefault(fn.ref, _Summary())
        if rets:
            out = rets[0]
            for r in rets[1:]:
                out = _join(out, r)
            s.ret = out

    # ------------------------------------------------------ entry point
    def hot_findings(self) -> List[Finding]:
        hot = self.graph.reachable(self.project.roots)
        findings: List[Finding] = []
        for ref in sorted(hot):
            fn = self.project.function(ref)
            if fn is None:
                continue
            env: Dict[str, object] = {}
            self._walk_body(fn, list(fn.node.body), env, rets=[],
                            findings=(findings, fn))
        return findings

    # ------------------------------------------------------- statements
    def _walk_body(self, fn, body, env, rets, findings) -> None:
        for stmt in body:
            self._stmt(fn, stmt, env, rets, findings)

    def _stmt(self, fn, stmt, env, rets, findings) -> None:
        ev = lambda e: self._eval(fn, e, env, findings)
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = getattr(stmt, "value", None)
            t = ev(value) if value is not None else UNKNOWN
            targets = stmt.targets if isinstance(stmt, ast.Assign) \
                else [stmt.target]
            for tgt in targets:
                self._bind(tgt, t, env)
        elif isinstance(stmt, ast.Expr):
            val = stmt.value
            # container building: x.append((a, b)) refines x's taint
            if (isinstance(val, ast.Call)
                    and isinstance(val.func, ast.Attribute)
                    and val.func.attr == "append"
                    and isinstance(val.func.value, ast.Name)
                    and len(val.args) == 1):
                item = ev(val.args[0])
                name = val.func.value.id
                prev = env.get(name)
                if isinstance(prev, ListOf):
                    env[name] = ListOf(_join(prev.item, item)
                                       if prev.item != UNKNOWN else item)
                else:
                    env[name] = ListOf(item)
            else:
                ev(val)
        elif isinstance(stmt, ast.Return):
            if stmt.value is not None:
                rets.append(ev(stmt.value))
        elif isinstance(stmt, ast.For):
            it = ev(stmt.iter)
            if _scalar(it) == DEVICE and not isinstance(it, (Tup, ListOf)):
                self._report(findings, stmt.iter, "sync.iterate",
                             "iterating a device array syncs per element: "
                             f"`for ... in {ast.unparse(stmt.iter)}`")
            self._bind_iter(stmt.target, it, env)
            self._walk_body(fn, stmt.body, env, rets, findings)
            self._walk_body(fn, stmt.orelse, env, rets, findings)
        elif isinstance(stmt, (ast.If, ast.While)):
            t = ev(stmt.test)
            if _scalar(t) == DEVICE and not isinstance(t, (Tup, ListOf)):
                self._report(findings, stmt.test, "sync.implicit-bool",
                             "branching on a device array forces a sync: "
                             f"`{ast.unparse(stmt.test)}`")
            self._walk_body(fn, stmt.body, env, rets, findings)
            self._walk_body(fn, stmt.orelse, env, rets, findings)
        elif isinstance(stmt, ast.Try):
            self._walk_body(fn, stmt.body, env, rets, findings)
            for h in stmt.handlers:
                self._walk_body(fn, h.body, env, rets, findings)
            self._walk_body(fn, stmt.orelse, env, rets, findings)
            self._walk_body(fn, stmt.finalbody, env, rets, findings)
        elif isinstance(stmt, ast.With):
            self._walk_body(fn, stmt.body, env, rets, findings)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            pass                    # nested defs analyzed via their own ref
        else:
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    ev(child)

    def _bind(self, tgt, t, env) -> None:
        if isinstance(tgt, ast.Name):
            env[tgt.id] = t
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            elts = t.elts if isinstance(t, Tup) \
                and len(t.elts) == len(tgt.elts) \
                else [_scalar(t) if _scalar(t) == DEVICE else UNKNOWN] \
                * len(tgt.elts)
            for e_tgt, e_t in zip(tgt.elts, elts):
                self._bind(e_tgt, e_t, env)
        # attribute / subscript stores: no attr env (self.state etc.)

    def _bind_iter(self, tgt, it, env) -> None:
        """Bind a for-loop target from the iterable's taint."""
        if isinstance(it, ListOf):
            self._bind(tgt, it.item, env)
        elif _scalar(it) == DEVICE:
            self._bind(tgt, DEVICE, env)
        elif _scalar(it) == HOST:
            self._bind(tgt, HOST, env)
        else:
            self._bind(tgt, UNKNOWN, env)

    # ------------------------------------------------------ expressions
    def _eval(self, fn, node, env, findings):
        if node is None:
            return UNKNOWN
        if isinstance(node, ast.Constant):
            return HOST
        if isinstance(node, ast.Name):
            return env.get(node.id, UNKNOWN)
        if isinstance(node, ast.Tuple):
            return Tup([self._eval(fn, e, env, findings)
                        for e in node.elts])
        if isinstance(node, ast.List):
            item = UNKNOWN
            for e in node.elts:
                item = _join(item, self._eval(fn, e, env, findings)) \
                    if item != UNKNOWN else self._eval(fn, e, env, findings)
            return ListOf(item)
        if isinstance(node, (ast.Dict, ast.DictComp, ast.Set)):
            for child in ast.walk(node):
                if isinstance(child, ast.Call):
                    self._eval(fn, child, env, findings)
            return UNKNOWN
        if isinstance(node, ast.Attribute):
            if node.attr in _META_ATTRS:
                self._eval(fn, node.value, env, findings)
                return HOST
            base = self._eval(fn, node.value, env, findings)
            return DEVICE if _scalar(base) == DEVICE else UNKNOWN
        if isinstance(node, ast.Subscript):
            base = self._eval(fn, node.value, env, findings)
            self._eval(fn, node.slice, env, findings)
            if isinstance(base, ListOf):
                return base.item
            if isinstance(base, Tup):
                if isinstance(node.slice, ast.Constant) \
                        and isinstance(node.slice.value, int) \
                        and 0 <= node.slice.value < len(base.elts):
                    return base.elts[node.slice.value]
                return _scalar(base)
            return _scalar(base) if _scalar(base) in (DEVICE, HOST) \
                else UNKNOWN
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.Not):
            # `not x` on a python container is host truthiness; only a
            # bare device scalar would sync (reported via the If branch)
            t = self._eval(fn, node.operand, env, findings)
            return HOST if isinstance(t, (Tup, ListOf)) else _scalar(t)
        if isinstance(node, ast.Compare) and all(
                isinstance(op, (ast.In, ast.NotIn, ast.Is, ast.IsNot))
                for op in node.ops):
            # membership / identity: dict-key and None checks are
            # host-level even when the container holds device arrays
            for c in [node.left] + node.comparators:
                self._eval(fn, c, env, findings)
            return HOST
        if isinstance(node, (ast.BinOp, ast.UnaryOp, ast.BoolOp,
                             ast.Compare, ast.IfExp)):
            parts = [self._eval(fn, c, env, findings)
                     for c in ast.iter_child_nodes(node)
                     if isinstance(c, ast.expr)]
            scal = [_scalar(p) for p in parts]
            if DEVICE in scal:
                return DEVICE
            if scal and all(s == HOST for s in scal):
                return HOST
            return UNKNOWN
        if isinstance(node, (ast.ListComp, ast.GeneratorExp, ast.SetComp)):
            local = dict(env)
            for gen in node.generators:
                it = self._eval(fn, gen.iter, local, findings)
                self._bind_iter(gen.target, it, local)
            return ListOf(self._eval(fn, node.elt, local, findings))
        if isinstance(node, ast.Lambda):
            return UNKNOWN          # evaluated at its call site
        if isinstance(node, ast.Starred):
            return self._eval(fn, node.value, env, findings)
        if isinstance(node, ast.Call):
            return self._call(fn, node, env, findings)
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                self._eval(fn, child, env, findings)
        return UNKNOWN

    # ------------------------------------------------------------ calls
    def _call(self, fn, node, env, findings):
        name = call_name(node)
        args = [self._eval(fn, a, env, findings) for a in node.args]
        for k in node.keywords:
            self._eval(fn, k.value, env, findings)
        arg0 = args[0] if args else UNKNOWN

        # ---- sinks -----------------------------------------------------
        if name in _COPY_SINKS and _scalar(arg0) == DEVICE:
            self._report(findings, node, "sync.np.asarray",
                         f"`{ast.unparse(node)}` copies a device array to "
                         "host (blocks on the device stream)")
            return HOST
        if name in ("jax.device_get",):
            if _scalar(arg0) == DEVICE:
                self._report(findings, node, "sync.device_get",
                             f"`{ast.unparse(node)}` is an explicit "
                             "device->host transfer")
            return HOST
        if name in _CAST_SINKS and len(node.args) == 1:
            if _scalar(arg0) == DEVICE:
                self._report(
                    findings, node, "sync.cast",
                    f"`{ast.unparse(node)}` collapses a device array to a "
                    "python scalar (host sync)")
            return HOST
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in ("item", "tolist"):
            base = self._eval(fn, node.func.value, env, findings)
            if _scalar(base) == DEVICE:
                self._report(
                    findings, node, f"sync.{node.func.attr}",
                    f"`{ast.unparse(node)}` syncs a device array to host")
            return HOST

        # ---- sources ---------------------------------------------------
        if name.startswith(_DEVICE_ROOTS) or name in _DEVICE_CALLS:
            return DEVICE
        if name.startswith(_HOST_ROOTS) or name in ("len", "sorted", "sum",
                                                    "max", "min", "abs",
                                                    "str", "repr", "round"):
            return HOST
        if name == "enumerate" and args:
            return ListOf(Tup([HOST, args[0].item
                               if isinstance(args[0], ListOf)
                               else _scalar(args[0])]))
        if name == "range":
            return ListOf(HOST)
        if name in ("list", "tuple") and args:
            return args[0] if isinstance(args[0], (ListOf, Tup)) else UNKNOWN

        # jit executables: self._megastep(...) and friends
        if isinstance(node.func, ast.Attribute) \
                and isinstance(node.func.value, ast.Name) \
                and node.func.value.id == "self" and fn.class_name:
            site = self.registry.attr_site(fn.class_name, node.func.attr)
            if site is not None:
                return DEVICE
            target = self.graph._method(fn.class_name, node.func.attr)
            if target is not None:
                return self._apply_summary(fn, target, node, env, findings)

        # self.attr.method(...) via the attribute-type map
        if isinstance(node.func, ast.Attribute) \
                and isinstance(node.func.value, ast.Attribute) \
                and isinstance(node.func.value.value, ast.Name) \
                and node.func.value.value.id == "self" and fn.class_name:
            attr_cls = self.graph.attr_types.get(fn.class_name, {}).get(
                node.func.value.attr)
            if attr_cls:
                target = self.graph._method(attr_cls, node.func.attr)
                if target is not None:
                    return self._apply_summary(fn, target, node, env,
                                               findings)

        # bare / imported project functions (incl. @jit-decorated)
        target = None
        if isinstance(node.func, ast.Name):
            nm = node.func.id
            target = fn.module.functions.get(f"{fn.qualname}.{nm}") \
                or self.project.resolve_symbol(fn.module, nm)
        elif isinstance(node.func, ast.Attribute):
            target = self.project.resolve_attr_call(
                fn.module, node.func.value, node.func.attr)
        if target is not None:
            if self.registry.decorated_site(target.ref) is not None:
                return DEVICE
            return self._apply_summary(fn, target, node, env, findings)
        return UNKNOWN

    def _apply_summary(self, fn, target, node, env, findings):
        s = self.summaries.get(target.ref)
        if s is None:
            return UNKNOWN
        if s.calls_param is not None:
            # map the callable argument (account for the bound self)
            idx = s.calls_param
            if target.class_name is not None \
                    and target.positional_params[:1] == ["self"]:
                idx -= 1
            if 0 <= idx < len(node.args):
                cb = node.args[idx]
                if isinstance(cb, ast.Lambda):
                    return self._eval(fn, cb.body, env, findings)
                if isinstance(cb, ast.Name):
                    nested = fn.module.functions.get(
                        f"{fn.qualname}.{cb.id}")
                    if nested is not None:
                        return self.summaries.get(nested.ref,
                                                  _Summary()).ret
                    other = self.project.resolve_symbol(fn.module, cb.id)
                    if other is not None:
                        return self.summaries.get(other.ref,
                                                  _Summary()).ret
            return UNKNOWN
        return s.ret

    # ---------------------------------------------------------- helpers
    def _report(self, findings, node, kind, detail) -> None:
        if findings is None:
            return
        out, fn = findings
        out.append(Finding(RULE, fn.module.rel, fn.qualname, kind, detail,
                           getattr(node, "lineno", 0)))


def check_host_sync(project: Project) -> List[Finding]:
    if not project.roots:
        return []
    return finalize_occurrences(SyncAnalyzer(project).hot_findings())
