"""Source loading and indexing for the static checker.

Everything here is stdlib-``ast`` only: the analyzer never imports the
code under analysis, so it runs in CI without jax installed and cannot
be confused by import-time side effects.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

# inline suppression: ``# repro: allow[R1,R4] reason`` on the finding's
# line or the line directly above it.  The reason is mandatory — an
# allow without one is ignored (and R-docs tell you why).
_ALLOW_RE = re.compile(
    r"#\s*repro:\s*allow\[([A-Z0-9,\s]+)\]\s*(\S.*)$")

_DEFAULT_ROOTS = (
    "src/repro/serving/engine.py::ServingEngine.step",
    "src/repro/serving/engine.py::ServingEngine.stream",
    "src/repro/serving/engine.py::ServingEngine.run_until_done",
)


@dataclass
class FunctionInfo:
    qualname: str                  # "Class.method" or "fn"
    module: "SourceModule"
    node: ast.AST                  # FunctionDef / AsyncFunctionDef
    class_name: Optional[str] = None

    @property
    def params(self) -> List[str]:
        a = self.node.args
        names = [p.arg for p in a.posonlyargs + a.args]
        if a.vararg:
            names.append("*" + a.vararg.arg)
        names += [p.arg for p in a.kwonlyargs]
        return names

    @property
    def positional_params(self) -> List[str]:
        a = self.node.args
        return [p.arg for p in a.posonlyargs + a.args]

    @property
    def ref(self) -> str:
        return f"{self.module.rel}::{self.qualname}"


@dataclass
class SourceModule:
    rel: str                       # repo-relative posix path
    tree: ast.Module
    lines: List[str]
    # lineno -> set of rules allowed there (inline suppressions)
    allows: Dict[int, Set[str]] = field(default_factory=dict)
    functions: Dict[str, FunctionInfo] = field(default_factory=dict)
    classes: Dict[str, ast.ClassDef] = field(default_factory=dict)
    # import name -> ("module", dotted) | ("symbol", dotted_mod, symbol)
    imports: Dict[str, Tuple] = field(default_factory=dict)

    @classmethod
    def parse(cls, rel: str, source: str) -> "SourceModule":
        tree = ast.parse(source, filename=rel)
        lines = source.splitlines()
        mod = cls(rel=rel, tree=tree, lines=lines)
        mod._collect_allows()
        mod._index(tree.body, prefix="", class_name=None)
        mod._collect_imports()
        return mod

    # ---------------------------------------------------------- indexing
    def _collect_allows(self) -> None:
        for i, line in enumerate(self.lines, start=1):
            m = _ALLOW_RE.search(line)
            if not m:
                continue
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            # the allow covers its own line and the following one
            # (comment-above style)
            self.allows.setdefault(i, set()).update(rules)
            self.allows.setdefault(i + 1, set()).update(rules)

    def _index(self, body, prefix: str, class_name: Optional[str]) -> None:
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{node.name}"
                self.functions[qual] = FunctionInfo(
                    qualname=qual, module=self, node=node,
                    class_name=class_name)
                # nested defs are indexed too (helper index_maps etc.)
                self._index(node.body, prefix=qual + ".",
                            class_name=class_name)
            elif isinstance(node, ast.ClassDef):
                self.classes[node.name] = node
                self._index(node.body, prefix=node.name + ".",
                            class_name=node.name)

    def _collect_imports(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.imports[a.asname or a.name.split(".")[0]] = (
                        "module", a.name)
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.imports[a.asname or a.name] = (
                        "symbol", node.module, a.name)

    def source_of(self, node: ast.AST) -> str:
        try:
            return ast.unparse(node)
        except Exception:       # pragma: no cover - defensive
            return "<unparseable>"


class Project:
    """A set of parsed modules plus cross-module lookup tables."""

    def __init__(self, modules: List[SourceModule], roots=None):
        self.modules = modules
        self.by_rel = {m.rel: m for m in modules}
        self.roots = list(roots) if roots is not None else \
            [r for r in _DEFAULT_ROOTS if r.split("::")[0] in self.by_rel]
        # dotted module name ("repro.serving.engine") -> SourceModule
        self.by_dotted: Dict[str, SourceModule] = {}
        for m in modules:
            dotted = self._dotted(m.rel)
            if dotted:
                self.by_dotted[dotted] = m

    # ------------------------------------------------------ construction
    @classmethod
    def from_root(cls, root, subdir="src/repro", roots=None) -> "Project":
        root = Path(root)
        mods = []
        for p in sorted((root / subdir).rglob("*.py")):
            rel = p.relative_to(root).as_posix()
            mods.append(SourceModule.parse(rel, p.read_text()))
        return cls(mods, roots=roots)

    @classmethod
    def from_sources(cls, sources: Dict[str, str], roots=None) -> "Project":
        mods = [SourceModule.parse(rel, src)
                for rel, src in sorted(sources.items())]
        if roots is None:
            # fixture default: every top-level function/method is a root
            roots = [f.ref for m in mods for f in m.functions.values()]
        return cls(mods, roots=roots)

    # ---------------------------------------------------------- lookups
    @staticmethod
    def _dotted(rel: str) -> Optional[str]:
        parts = Path(rel).with_suffix("").parts
        if "repro" in parts:
            parts = parts[parts.index("repro"):]
        return ".".join(parts) if parts else None

    def resolve_module(self, dotted: str) -> Optional[SourceModule]:
        if dotted in self.by_dotted:
            return self.by_dotted[dotted]
        # "repro.models.transformer" vs entries keyed the same way; also
        # accept a bare module name for single-file fixtures
        for rel, m in self.by_rel.items():
            if Path(rel).stem == dotted:
                return m
        return None

    def resolve_symbol(self, module: SourceModule,
                       name: str) -> Optional[FunctionInfo]:
        """Resolve a bare name used in ``module`` to a project function:
        local first, then ``from x import name``."""
        if name in module.functions:
            return module.functions[name]
        imp = module.imports.get(name)
        if imp and imp[0] == "symbol":
            target = self.resolve_module(imp[1])
            if target is not None:
                return target.functions.get(imp[2])
        return None

    def resolve_attr_call(self, module: SourceModule,
                          value: ast.expr,
                          attr: str) -> Optional[FunctionInfo]:
        """Resolve ``alias.attr(...)`` where ``alias`` is an imported
        project module (``from repro.models import transformer as T``)."""
        if isinstance(value, ast.Name):
            imp = module.imports.get(value.id)
            if imp:
                dotted = imp[1] if imp[0] == "module" \
                    else f"{imp[1]}.{imp[2]}"
                target = self.resolve_module(dotted)
                if target is not None:
                    return target.functions.get(attr)
        return None

    def function(self, ref: str) -> Optional[FunctionInfo]:
        """Look up "rel/path.py::Qual.name"."""
        rel, _, qual = ref.partition("::")
        mod = self.by_rel.get(rel)
        return mod.functions.get(qual) if mod else None

    def all_functions(self):
        for m in self.modules:
            yield from m.functions.values()

    # ------------------------------------------------------ suppressions
    def is_allowed(self, finding) -> bool:
        mod = self.by_rel.get(finding.path)
        if mod is None:
            return False
        return finding.rule in mod.allows.get(finding.line, ())


# --------------------------------------------------------------------------
# Shared AST utilities
# --------------------------------------------------------------------------

def call_name(node: ast.Call) -> str:
    """Dotted text of a call target ("np.asarray", "self.runner.sample")."""
    return dotted_name(node.func)


def dotted_name(node: ast.expr) -> str:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def enclosing_functions(tree: ast.Module):
    """Yield (qualname, node) for every def, with parent links attached
    (node._repro_parent) for upward walks."""
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._repro_parent = node  # type: ignore[attr-defined]


def iter_calls(node: ast.AST):
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            yield n


def literal_or_none(node: ast.expr):
    try:
        return ast.literal_eval(node)
    except Exception:
        return None
