"""CLI for the repro static checker.

Usage (from the repo root)::

    python -m repro.analysis                         # full run, text report
    python -m repro.analysis --baseline analysis/baseline.json
    python -m repro.analysis --rules R1,R4 --format json
    python -m repro.analysis --baseline analysis/baseline.json \
        --update-baseline                            # regenerate baseline

Exit codes: 0 clean (every finding baselined + justified), 1 gate
failure (new findings, or baseline entries without a justification),
2 usage error.  Stale baseline entries (fixed findings) only warn —
prune them with ``--update-baseline``.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis import ALL_RULES, RULE_TITLES, analyze_project
from repro.analysis.findings import Baseline, load_baseline, write_baseline
from repro.analysis.project import Project


def _parse_args(argv):
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX/Pallas-aware static checker (rules R1-R5)")
    ap.add_argument("--root", default="src/repro",
                    help="source subdir to analyze (default: src/repro)")
    ap.add_argument("--repo", default=".",
                    help="repo root paths are reported relative to")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON to diff findings against")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from current findings "
                         "(carries existing justifications forward)")
    ap.add_argument("--rules", default=",".join(ALL_RULES),
                    help="comma-separated rule subset (default: all)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = _parse_args(argv if argv is not None else sys.argv[1:])
    rules = tuple(r.strip() for r in args.rules.split(",") if r.strip())
    bad = [r for r in rules if r not in ALL_RULES]
    if bad:
        print(f"error: unknown rule(s) {', '.join(bad)} "
              f"(known: {', '.join(ALL_RULES)})", file=sys.stderr)
        return 2
    repo = Path(args.repo)
    if not (repo / args.root).is_dir():
        print(f"error: source root {repo / args.root} not found",
              file=sys.stderr)
        return 2

    project = Project.from_root(repo, subdir=args.root)
    findings = analyze_project(project, rules=rules)

    baseline = Baseline()
    if args.baseline and Path(args.baseline).exists():
        try:
            baseline = load_baseline(args.baseline)
        except (ValueError, json.JSONDecodeError) as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
    elif args.baseline and not args.update_baseline:
        print(f"warning: baseline {args.baseline} not found; "
              "treating every finding as new", file=sys.stderr)

    if args.update_baseline:
        if not args.baseline:
            print("error: --update-baseline requires --baseline",
                  file=sys.stderr)
            return 2
        Path(args.baseline).parent.mkdir(parents=True, exist_ok=True)
        write_baseline(args.baseline, findings, previous=baseline)
        print(f"wrote {args.baseline} with {len(findings)} finding(s); "
              "fill in any empty justifications")
        return 0

    new, known, stale = baseline.diff(findings)
    unjustified = [k for k in baseline.validate()
                   if k in {f.key for f in known}]

    if args.format == "json":
        print(json.dumps({
            "rules": list(rules),
            "new": [vars(f) | {"key": f.key} for f in new],
            "known": [vars(f) | {"key": f.key} for f in known],
            "stale": stale,
            "unjustified": unjustified,
        }, indent=2))
    else:
        for f in known:
            print(f.render("baselined"))
        for f in new:
            print(f.render("NEW"))
        for k in stale:
            print(f"stale baseline entry (no longer produced): {k}")
        for k in unjustified:
            print(f"baseline entry lacks a justification: {k}")
        counts = {}
        for f in findings:
            counts[f.rule] = counts.get(f.rule, 0) + 1
        summary = ", ".join(
            f"{r} {RULE_TITLES[r]}: {counts.get(r, 0)}" for r in rules)
        print(f"-- {len(findings)} finding(s) [{summary}]; "
              f"{len(new)} new, {len(known)} baselined, "
              f"{len(stale)} stale, {len(unjustified)} unjustified")

    if new or unjustified:
        if new:
            print(f"FAIL: {len(new)} finding(s) not in the baseline — fix "
                  "them, or justify via --update-baseline + a "
                  "'justification' entry", file=sys.stderr)
        if unjustified:
            print(f"FAIL: {len(unjustified)} baseline entr(ies) have no "
                  "justification", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
