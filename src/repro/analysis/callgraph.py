"""Cheap flow-insensitive call graph over the project.

Good enough for hot-path reachability (R1/R5): resolves

* bare calls ``fn(...)`` to module-local or ``from m import fn`` defs,
* ``alias.fn(...)`` through module imports (``from repro.models import
  transformer as T`` → ``T.prefill``),
* ``self.method(...)`` within the enclosing class,
* ``self.attr.method(...)`` via an attribute-type map built from
  ``self.attr = ClassName(...)`` assignments in ``__init__`` (so
  ``ServingEngine.step`` reaches ``ModelRunner.sample``), and
* callables passed as arguments (``self._protected(rids, lambda: ...)``
  marks the lambda body reachable too).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.project import FunctionInfo, Project, dotted_name


def _class_attr_types(project: Project) -> Dict[str, Dict[str, str]]:
    """class name -> {self attr name -> class name of assigned value}."""
    known_classes = {name for m in project.modules for name in m.classes}
    out: Dict[str, Dict[str, str]] = {}
    for mod in project.modules:
        for cls_name, cls_node in mod.classes.items():
            attrs: Dict[str, str] = {}
            for node in ast.walk(cls_node):
                if not isinstance(node, ast.Assign):
                    continue
                for tgt in node.targets:
                    if (isinstance(tgt, ast.Attribute)
                            and isinstance(tgt.value, ast.Name)
                            and tgt.value.id == "self"
                            and isinstance(node.value, ast.Call)):
                        callee = dotted_name(node.value.func)
                        base = callee.split(".")[-1]
                        if base in known_classes:
                            attrs[tgt.attr] = base
            out[cls_name] = attrs
    return out


class CallGraph:
    def __init__(self, project: Project):
        self.project = project
        self.attr_types = _class_attr_types(project)
        # FunctionInfo.ref -> set of callee refs
        self.edges: Dict[str, Set[str]] = {}
        # class name -> defining module (first wins; names are unique here)
        self.class_home: Dict[str, str] = {}
        for m in project.modules:
            for name in m.classes:
                self.class_home.setdefault(name, m.rel)
        for fn in project.all_functions():
            self.edges[fn.ref] = self._callees(fn)

    # ------------------------------------------------------------------
    def _method(self, cls: str, name: str) -> Optional[FunctionInfo]:
        rel = self.class_home.get(cls)
        if rel is None:
            return None
        return self.project.by_rel[rel].functions.get(f"{cls}.{name}")

    def _callees(self, fn: FunctionInfo) -> Set[str]:
        callees: Set[str] = set()
        mod = fn.module

        def add(info: Optional[FunctionInfo]):
            if info is not None:
                callees.add(info.ref)

        for node in ast.walk(fn.node):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Name):
                add(self.project.resolve_symbol(mod, f.id))
            elif isinstance(f, ast.Attribute):
                base = f.value
                if isinstance(base, ast.Name) and base.id == "self":
                    if fn.class_name:
                        add(self._method(fn.class_name, f.attr))
                elif (isinstance(base, ast.Attribute)
                      and isinstance(base.value, ast.Name)
                      and base.value.id == "self" and fn.class_name):
                    # self.attr.method(...)
                    attr_cls = self.attr_types.get(
                        fn.class_name, {}).get(base.attr)
                    if attr_cls:
                        add(self._method(attr_cls, f.attr))
                else:
                    add(self.project.resolve_attr_call(mod, base, f.attr))
            # callables passed as args reach their bodies: resolve
            # Name args that denote project functions (lambdas are part
            # of the caller's own AST and are walked in place by rules)
            for arg in list(node.args) + [k.value for k in node.keywords]:
                if isinstance(arg, ast.Name):
                    cand = self.project.resolve_symbol(mod, arg.id)
                    if cand is not None and isinstance(
                            cand.node,
                            (ast.FunctionDef, ast.AsyncFunctionDef)):
                        add(cand)
        return callees

    # ------------------------------------------------------------------
    def reachable(self, roots: List[str]) -> Set[str]:
        """BFS closure of FunctionInfo refs from the given root refs.
        Method roots pull in sibling private helpers conservatively via
        the explicit edges only."""
        seen: Set[str] = set()
        frontier = [r for r in roots if r in self.edges]
        seen.update(frontier)
        while frontier:
            nxt = []
            for ref in frontier:
                for callee in self.edges.get(ref, ()):
                    if callee not in seen:
                        seen.add(callee)
                        nxt.append(callee)
            frontier = nxt
        return seen
