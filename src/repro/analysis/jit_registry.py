"""Discovery of every jit executable in the project.

Two shapes exist in this repo:

* attribute sites — ``self._megastep = jax.jit(lambda ...: ...,
  donate_argnums=(1,))`` inside a class body (the ModelRunner
  executables), and
* decorated functions — ``@functools.partial(jax.jit,
  static_argnames=(...), donate_argnums=(...))`` (the kernel wrappers,
  ``copy_blocks``).

The registry records, per site: the jitted callable's AST (lambda or
resolved function), donated positional indices, static argument names,
and where it lives — the shared ground truth for R1 (jit call => device
value), R2 (donation positions), R3 (static params) and R5 (trace
roots).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.analysis.project import (FunctionInfo, Project, call_name,
                                    literal_or_none)


@dataclass
class JitSite:
    name: str                       # display: "ModelRunner._megastep"
    module_rel: str
    lineno: int
    donate: Tuple[int, ...] = ()
    static_names: Tuple[str, ...] = ()
    # the callable under jit: a Lambda node, or the FunctionInfo of a
    # named function (decorated site / jax.jit(fn) by name)
    fn_lambda: Optional[ast.Lambda] = None
    fn_info: Optional[FunctionInfo] = None

    @property
    def positional_params(self) -> List[str]:
        if self.fn_lambda is not None:
            a = self.fn_lambda.args
            return [p.arg for p in a.posonlyargs + a.args]
        if self.fn_info is not None:
            return self.fn_info.positional_params
        return []


def _tuple_of_ints(node: Optional[ast.expr]) -> Tuple[int, ...]:
    val = literal_or_none(node) if node is not None else None
    if isinstance(val, int):
        return (val,)
    if isinstance(val, (tuple, list)) and all(
            isinstance(v, int) for v in val):
        return tuple(val)
    return ()


def _tuple_of_strs(node: Optional[ast.expr]) -> Tuple[str, ...]:
    val = literal_or_none(node) if node is not None else None
    if isinstance(val, str):
        return (val,)
    if isinstance(val, (tuple, list)) and all(
            isinstance(v, str) for v in val):
        return tuple(val)
    return ()


def _jit_call_parts(node: ast.Call):
    """If ``node`` is ``jax.jit(fn, ...)`` return (fn_expr, kwargs)."""
    if call_name(node) in ("jax.jit", "jit") and node.args:
        return node.args[0], {k.arg: k.value for k in node.keywords}
    return None


def _partial_jit_parts(node: ast.Call):
    """If ``node`` is ``functools.partial(jax.jit, ...)`` return kwargs."""
    if call_name(node) in ("functools.partial", "partial") and node.args:
        inner = node.args[0]
        if isinstance(inner, (ast.Name, ast.Attribute)):
            from repro.analysis.project import dotted_name
            if dotted_name(inner) in ("jax.jit", "jit"):
                return {k.arg: k.value for k in node.keywords}
    return None


class JitRegistry:
    def __init__(self, project: Project):
        self.project = project
        # (class_name, attr) -> JitSite   e.g. ("ModelRunner", "_megastep")
        self.attr_sites: Dict[Tuple[str, str], JitSite] = {}
        # FunctionInfo.ref -> JitSite for @jit-decorated functions
        self.decorated: Dict[str, JitSite] = {}
        # (enclosing FunctionInfo.ref, local name) -> JitSite for
        # ``fn = jax.jit(step, donate_argnums=...)`` inside a function
        # (the dryrun / train-loop shape)
        self.local_sites: Dict[Tuple[str, str], JitSite] = {}
        self._collect()

    # ------------------------------------------------------------------
    def _collect(self) -> None:
        for mod in self.project.modules:
            for cls_name, cls_node in mod.classes.items():
                for node in ast.walk(cls_node):
                    if not isinstance(node, ast.Assign):
                        continue
                    if not isinstance(node.value, ast.Call):
                        continue
                    parts = _jit_call_parts(node.value)
                    if parts is None:
                        continue
                    fn_expr, kwargs = parts
                    for tgt in node.targets:
                        if (isinstance(tgt, ast.Attribute)
                                and isinstance(tgt.value, ast.Name)
                                and tgt.value.id == "self"):
                            self.attr_sites[(cls_name, tgt.attr)] = \
                                self._site(f"{cls_name}.{tgt.attr}", mod,
                                           node.lineno, fn_expr, kwargs)
            for fn in mod.functions.values():
                node = fn.node
                if not isinstance(node,
                                  (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                for sub in ast.walk(node):
                    if not (isinstance(sub, ast.Assign)
                            and isinstance(sub.value, ast.Call)):
                        continue
                    parts = _jit_call_parts(sub.value)
                    if parts is None:
                        continue
                    fn_expr, kwargs = parts
                    for tgt in sub.targets:
                        if isinstance(tgt, ast.Name):
                            self.local_sites[(fn.ref, tgt.id)] = self._site(
                                f"{fn.qualname}.{tgt.id}", mod, sub.lineno,
                                fn_expr, kwargs)
                for dec in node.decorator_list:
                    kwargs = None
                    if isinstance(dec, ast.Call):
                        kwargs = _partial_jit_parts(dec)
                    elif isinstance(dec, (ast.Name, ast.Attribute)):
                        from repro.analysis.project import dotted_name
                        if dotted_name(dec) in ("jax.jit", "jit"):
                            kwargs = {}
                    if kwargs is None:
                        continue
                    site = JitSite(
                        name=fn.qualname, module_rel=mod.rel,
                        lineno=node.lineno,
                        donate=_tuple_of_ints(kwargs.get("donate_argnums")),
                        static_names=_tuple_of_strs(
                            kwargs.get("static_argnames")),
                        fn_info=fn)
                    self.decorated[fn.ref] = site

    def _site(self, name, mod, lineno, fn_expr, kwargs) -> JitSite:
        site = JitSite(
            name=name, module_rel=mod.rel, lineno=lineno,
            donate=_tuple_of_ints(kwargs.get("donate_argnums")),
            static_names=_tuple_of_strs(kwargs.get("static_argnames")))
        if isinstance(fn_expr, ast.Lambda):
            site.fn_lambda = fn_expr
        elif isinstance(fn_expr, ast.Name):
            site.fn_info = self.project.resolve_symbol(mod, fn_expr.id)
        return site

    # ------------------------------------------------------------------
    def attr_site(self, cls_name: Optional[str],
                  attr: str) -> Optional[JitSite]:
        if cls_name is None:
            return None
        return self.attr_sites.get((cls_name, attr))

    def decorated_site(self, fn_ref: str) -> Optional[JitSite]:
        return self.decorated.get(fn_ref)

    def local_site(self, fn_ref: str, name: str) -> Optional[JitSite]:
        return self.local_sites.get((fn_ref, name))

    def all_sites(self) -> List[JitSite]:
        return (list(self.attr_sites.values())
                + list(self.decorated.values())
                + list(self.local_sites.values()))
