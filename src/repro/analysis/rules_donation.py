"""R2 — donation-safety.

``donate_argnums`` hands a buffer to XLA: after the call, reading the
donated python reference is undefined (on TPU it is a deleted buffer
error; under CPU interpret it silently works, which is how these bugs
ship).  For every call site of a donating jit executable this rule
checks each donated argument:

* **safe** if the same statement rebinds it (``out, self.state =
  self._megastep(self.params, self.state, ...)`` — the canonical
  consume-and-replace shape), or if nothing in the enclosing function
  reads the same expression after the call before a rebind;
* **finding** (``donation.use-after``) when a later read exists;
* **finding** (``donation.alias``) when two donated positions receive
  the textually identical expression — both can't own the buffer.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

from repro.analysis.findings import Finding, finalize_occurrences
from repro.analysis.jit_registry import JitRegistry, JitSite
from repro.analysis.project import FunctionInfo, Project

RULE = "R2"


def _own_statements(fn_node):
    """Statements of a function body in source order, not descending
    into nested function definitions (they have their own FunctionInfo)."""
    out = []

    def rec(body):
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            out.append(stmt)
            for field in ("body", "orelse", "finalbody"):
                rec(getattr(stmt, field, []) or [])
            for h in getattr(stmt, "handlers", []) or []:
                rec(h.body)

    rec(fn_node.body)
    return out


def _header_calls(stmt: ast.stmt):
    """Calls belonging to ``stmt`` itself — for compound statements only
    the header expressions (test / iter / items), since the nested bodies
    appear as their own entries in ``_own_statements`` (a call must be
    checked exactly once, at its innermost statement)."""
    if isinstance(stmt, (ast.If, ast.While)):
        headers = [stmt.test]
    elif isinstance(stmt, ast.For):
        headers = [stmt.iter]
    elif isinstance(stmt, ast.With):
        headers = [i.context_expr for i in stmt.items]
    elif isinstance(stmt, ast.Try):
        headers = []
    else:
        headers = [stmt]
    for h in headers:
        for n in ast.walk(h):
            if isinstance(n, ast.Call):
                yield n


def _unparse(node: ast.expr) -> str:
    try:
        return ast.unparse(node)
    except Exception:       # pragma: no cover - defensive
        return ""


def _targets_cover(targets: List[ast.expr], text: str) -> bool:
    """Does any assignment target (or tuple element) rebind ``text``?"""
    for tgt in targets:
        elts = tgt.elts if isinstance(tgt, (ast.Tuple, ast.List)) else [tgt]
        for e in elts:
            if _unparse(e) == text:
                return True
            # ``self.state[k] = ...`` also rebinds ``self.state[k]`` when
            # the subscript text matches exactly (handled above) — and a
            # whole-object rebind covers any of its subscripts/attrs
            if text.startswith(_unparse(e) + "[") \
                    or text.startswith(_unparse(e) + "."):
                return True
    return False


def _reads_in(stmt: ast.stmt, text: str) -> bool:
    """Does ``stmt`` read an expression textually equal to ``text``
    (outside of being a plain store target)?"""
    store_ids = set()
    if isinstance(stmt, ast.Assign):
        for t in stmt.targets:
            store_ids.add(id(t))
            for e in getattr(t, "elts", []) or []:
                store_ids.add(id(e))
    for node in ast.walk(stmt):
        if id(node) in store_ids:
            continue
        if isinstance(node, (ast.Name, ast.Attribute, ast.Subscript)) \
                and isinstance(getattr(node, "ctx", None), ast.Load) \
                and _unparse(node) == text:
            return True
    return False


class DonationChecker:
    def __init__(self, project: Project):
        self.project = project
        self.registry = JitRegistry(project)

    # ------------------------------------------------------------------
    def _site_for_call(self, fn: FunctionInfo,
                       call: ast.Call) -> Optional[JitSite]:
        f = call.func
        if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name) \
                and f.value.id == "self":
            return self.registry.attr_site(fn.class_name, f.attr)
        target = None
        if isinstance(f, ast.Name):
            local = self.registry.local_site(fn.ref, f.id)
            if local is not None:
                return local
            target = self.project.resolve_symbol(fn.module, f.id)
        elif isinstance(f, ast.Attribute):
            target = self.project.resolve_attr_call(fn.module, f.value,
                                                    f.attr)
        if target is not None:
            return self.registry.decorated_site(target.ref)
        return None

    def _donated_args(self, site: JitSite,
                      call: ast.Call) -> Dict[int, ast.expr]:
        """donated position -> argument expression at this call."""
        params = site.positional_params
        out: Dict[int, ast.expr] = {}
        for pos in site.donate:
            if pos < len(call.args):
                out[pos] = call.args[pos]
            elif pos < len(params):
                for kw in call.keywords:
                    if kw.arg == params[pos]:
                        out[pos] = kw.value
        return out

    # ------------------------------------------------------------------
    def check(self) -> List[Finding]:
        findings: List[Finding] = []
        for fn in self.project.all_functions():
            stmts = _own_statements(fn.node)
            for si, stmt in enumerate(stmts):
                for call in _header_calls(stmt):
                    site = self._site_for_call(fn, call)
                    if site is None or not site.donate:
                        continue
                    self._check_call(fn, site, call, stmt, stmts[si + 1:],
                                     findings)
        return findings

    def _check_call(self, fn, site, call, stmt, later, findings) -> None:
        donated = self._donated_args(site, call)
        texts = [(_unparse(e), pos) for pos, e in sorted(donated.items())]
        seen: Dict[str, int] = {}
        for text, pos in texts:
            if not text:
                continue
            if text in seen:
                findings.append(Finding(
                    RULE, fn.module.rel, fn.qualname, "donation.alias",
                    f"`{site.name}` donates positions {seen[text]} and "
                    f"{pos} but both receive `{text}` — one buffer cannot "
                    "be donated twice", call.lineno))
                continue
            seen[text] = pos
            self._check_use_after(fn, site, call, stmt, later, text,
                                  findings)

    def _check_use_after(self, fn, site, call, stmt, later, text,
                         findings) -> None:
        # same-statement rebind (the canonical safe shape)
        if isinstance(stmt, ast.Assign) and stmt.value is not None \
                and any(n is call for n in ast.walk(stmt.value)) \
                and _targets_cover(stmt.targets, text):
            return
        # constants / fresh expressions can't be read later
        if not any(c.isalpha() for c in text):
            return
        for nxt in later:
            if isinstance(nxt, ast.Assign) \
                    and _targets_cover(nxt.targets, text) \
                    and not _reads_in_value(nxt, text):
                return                      # rebound before any read
            if _reads_in(nxt, text):
                findings.append(Finding(
                    RULE, fn.module.rel, fn.qualname, "donation.use-after",
                    f"`{text}` is donated to `{site.name}` (line "
                    f"{call.lineno}) but read again on line "
                    f"{nxt.lineno} — donated buffers are invalid after "
                    "the call", call.lineno))
                return


def _reads_in_value(assign: ast.Assign, text: str) -> bool:
    for node in ast.walk(assign.value):
        if isinstance(node, (ast.Name, ast.Attribute, ast.Subscript)) \
                and _unparse(node) == text:
            return True
    return False


def check_donation(project: Project) -> List[Finding]:
    return finalize_occurrences(DonationChecker(project).check())
