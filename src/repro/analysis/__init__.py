"""repro.analysis — JAX/Pallas-aware static checker for this repo.

The serving stack's performance claims rest on contracts no unit test
watches continuously: the hot loop must not sync with the device outside
the one planned token readback per step (R1), donated buffers must never
be read after the dispatch that consumed them (R2), the fixed-shape
executables must not grow retrace vectors (R3), every Pallas page walk
must stay inside the live prefix of the paged pool — the exact class of
the seed's unbounded-page-walk bug (R4), and Python control flow must
never branch on traced values inside a jitted body (R5).

``python -m repro.analysis`` runs all rules over ``src/repro`` and diffs
the findings against ``analysis/baseline.json``; any finding not in the
baseline exits nonzero, which is the CI merge gate.  Every baseline
entry carries a mandatory justification — see docs/ANALYSIS.md.
"""
from __future__ import annotations

from repro.analysis.findings import (Baseline, Finding,  # noqa: F401
                                     load_baseline)
from repro.analysis.project import Project, SourceModule  # noqa: F401

ALL_RULES = ("R1", "R2", "R3", "R4", "R5")

RULE_TITLES = {
    "R1": "host-sync-in-hot-path",
    "R2": "donation-safety",
    "R3": "retrace-hazard",
    "R4": "kernel-contract",
    "R5": "traced-control-flow",
}


def analyze_project(project: Project, rules=ALL_RULES):
    """Run the requested rules over a loaded ``Project``; returns the
    sorted finding list (inline ``# repro: allow[...]`` sites already
    dropped)."""
    from repro.analysis.rules_donation import check_donation
    from repro.analysis.rules_flow import check_traced_flow
    from repro.analysis.rules_kernel import check_kernel_contracts
    from repro.analysis.rules_retrace import check_retrace
    from repro.analysis.rules_sync import check_host_sync

    runners = {"R1": check_host_sync, "R2": check_donation,
               "R3": check_retrace, "R4": check_kernel_contracts,
               "R5": check_traced_flow}
    findings = []
    for rule in rules:
        findings.extend(runners[rule](project))
    findings = [f for f in findings if not project.is_allowed(f)]
    return sorted(findings, key=lambda f: (f.path, f.line, f.key))


def analyze_source(source: str, filename: str = "<fixture>.py",
                   rules=ALL_RULES, roots=None):
    """Analyze a single in-memory module (the test-fixture entry point)."""
    project = Project.from_sources({filename: source}, roots=roots)
    return analyze_project(project, rules=rules)
