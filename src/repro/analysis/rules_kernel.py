"""R4 — kernel-contract checks for ``pl.pallas_call`` sites.

Pallas contracts are easy to break silently: a BlockSpec ``index_map``
with the wrong arity, a kernel body whose ref count no longer matches
``in_specs + out_specs + scratch_shapes``, an operand list out of step
with the specs — and, the seed-bug class, a *page walk* whose table
column is not clamped to the sequence's live pages, so the DMA reads a
stale physical block id and attends to garbage KV.

Everything here is abstract evaluation over the wrapper's AST with a
small constant environment (representative shapes for anything that
cannot be computed statically):

* ``kernel.index-map-arity`` — every ``index_map`` must take
  ``len(grid) + num_scalar_prefetch`` arguments;
* ``kernel.body-arity`` — the kernel body's unbound positional params
  must equal prefetch + inputs + outputs + scratch (skipped for
  ``*refs`` bodies and non-literal spec lists);
* ``kernel.operand-count`` — the immediate call must pass
  ``num_scalar_prefetch + len(in_specs)`` operands;
* ``kernel.page-walk-unbounded`` — every index map that subscripts a
  prefetched block table is evaluated over the full grid x a set of
  live lengths; each table column must stay within
  ``[0, max(ceil(live/block_size) - 1, 0)]`` and ``[0, table_width)``.
  Helper clamps (``_clamp_live``, ``_chunk_clamp``) are inlined;
* ``kernel.out-dtype`` — stores to the output ref must ``.astype`` the
  ref's dtype (f32 accumulators silently upcast the output otherwise).
"""
from __future__ import annotations

import ast
import itertools
import math
import re
from typing import Dict, List, Optional, Tuple

from repro.analysis.findings import Finding, finalize_occurrences
from repro.analysis.project import (FunctionInfo, Project, call_name,
                                    literal_or_none)

RULE = "R4"

# representative shape seeds: small enough to enumerate, chosen so every
# derived quantity (tiles, padded lengths) stays integral
_SEED_ENV = {"B": 2, "W": 8, "H": 2, "D": 4, "KV": 1, "G": 2, "MB": 5,
             "NB": 7, "BS": 4, "S": 8, "Sq": 8, "Sk": 8, "M": 8, "K": 32,
             "N": 8, "n_groups": 4}
# live-prefix lengths the page walk is exercised over (clipped to the
# pool capacity MB * BS below)
_LIVE_SET = (0, 1, 3, 4, 5, 9, 17, 20)
_GRID_CAP = 4096                        # skip walk on absurdly large grids
_OUT_REF_RE = re.compile(r"^(o|out)_ref$")


class _EvalError(Exception):
    pass


class _Table:
    """Abstract scalar-prefetch operand.

    * 2-index reads (``bt[b, col]``) are block-table lookups: the column
      is recorded for the bounds check and returned (the table value is
      unknown, only the column matters).
    * 1-index reads are scalar rows: ``sl[b]`` / ``info[0]`` give the
      live length; the literal index 1 (``info[1]`` = total_len) gives
      live + chunk width.
    """

    def __init__(self, live: int, total: int):
        self.live = live
        self.total = total
        self.cols: List[int] = []

    def read(self, idx_nodes: List[ast.expr], idx_vals: List[int]) -> int:
        if len(idx_vals) >= 2:
            col = int(idx_vals[1])
            self.cols.append(col)
            return col
        if len(idx_nodes) == 1 and isinstance(idx_nodes[0], ast.Constant) \
                and idx_nodes[0].value == 1:
            return self.total
        return self.live


class _Evaluator:
    """Tiny int evaluator over map/helper bodies."""

    def __init__(self, project: Project, module, env: Dict[str, object]):
        self.project = project
        self.module = module
        self.env = env

    def eval(self, node: ast.AST):
        if isinstance(node, ast.Constant):
            if isinstance(node.value, (int, float)):
                return node.value
            raise _EvalError(f"non-numeric constant {node.value!r}")
        if isinstance(node, ast.Name):
            if node.id in self.env:
                return self.env[node.id]
            raise _EvalError(f"unknown name {node.id}")
        if isinstance(node, ast.Tuple):
            return tuple(self.eval(e) for e in node.elts)
        if isinstance(node, ast.UnaryOp):
            v = self.eval(node.operand)
            if isinstance(node.op, ast.USub):
                return -v
            if isinstance(node.op, ast.UAdd):
                return +v
            raise _EvalError("unary op")
        if isinstance(node, ast.BinOp):
            le, r = self.eval(node.left), self.eval(node.right)
            op = node.op
            if isinstance(op, ast.Add):
                return le + r
            if isinstance(op, ast.Sub):
                return le - r
            if isinstance(op, ast.Mult):
                return le * r
            if isinstance(op, ast.FloorDiv):
                return le // r
            if isinstance(op, ast.Mod):
                return le % r
            if isinstance(op, ast.Pow):
                return le ** r
            if isinstance(op, ast.Div):
                return le / r
            raise _EvalError("binop")
        if isinstance(node, ast.Subscript):
            base = self.eval(node.value)
            sl = node.slice
            idx_nodes = list(sl.elts) if isinstance(sl, ast.Tuple) else [sl]
            if isinstance(base, _Table):
                idx_vals = [self.eval(n) for n in idx_nodes]
                return base.read(idx_nodes, idx_vals)
            idx = self.eval(sl)
            if isinstance(base, tuple) and isinstance(idx, int):
                return base[idx]
            raise _EvalError("subscript")
        if isinstance(node, ast.Call):
            return self._call(node)
        if isinstance(node, ast.IfExp):
            return self.eval(node.body) if self.eval(node.test) \
                else self.eval(node.orelse)
        if isinstance(node, ast.Compare) and len(node.ops) == 1:
            le, r = self.eval(node.left), self.eval(node.comparators[0])
            op = node.ops[0]
            if isinstance(op, ast.Lt):
                return le < r
            if isinstance(op, ast.LtE):
                return le <= r
            if isinstance(op, ast.Gt):
                return le > r
            if isinstance(op, ast.GtE):
                return le >= r
            if isinstance(op, ast.Eq):
                return le == r
            if isinstance(op, ast.NotEq):
                return le != r
        raise _EvalError(f"unsupported node {type(node).__name__}")

    def _call(self, node: ast.Call):
        name = call_name(node)
        leaf = name.split(".")[-1]
        args = [self.eval(a) for a in node.args]
        if leaf in ("minimum", "min"):
            return min(args)
        if leaf in ("maximum", "max"):
            return max(args)
        if leaf == "clip" and len(args) == 3:
            return min(max(args[0], args[1]), args[2])
        if leaf == "abs":
            return abs(args[0])
        if leaf == "cdiv" and len(args) == 2:
            return -(-args[0] // args[1])
        if leaf == "int32":
            return args[0]
        if leaf == "ceil":
            return math.ceil(args[0])
        # project helper (clamp functions): inline-evaluate its body
        fn = None
        if isinstance(node.func, ast.Name):
            fn = self.project.resolve_symbol(self.module, node.func.id)
        if fn is not None and isinstance(fn.node, ast.FunctionDef):
            return self._inline(fn, args)
        raise _EvalError(f"uneval call {name}")

    def _inline(self, fn: FunctionInfo, args: List[object]):
        local = dict(self.env)
        for p, v in zip(fn.positional_params, args):
            local[p] = v
        sub = _Evaluator(self.project, fn.module, local)
        for stmt in fn.node.body:
            if isinstance(stmt, ast.Assign) \
                    and isinstance(stmt.targets[0], ast.Name):
                local[stmt.targets[0].id] = sub.eval(stmt.value)
            elif isinstance(stmt, ast.Return) and stmt.value is not None:
                return sub.eval(stmt.value)
        raise _EvalError(f"helper {fn.qualname} has no return")


def _const_env(project: Project, fn: FunctionInfo) -> Dict[str, object]:
    """Seed shapes + module constants + param defaults + a forward pass
    over the wrapper's simple assignments (failures keep the seeds)."""
    env: Dict[str, object] = dict(_SEED_ENV)
    for stmt in fn.module.tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            val = literal_or_none(stmt.value)
            if isinstance(val, (int, float)):
                env[stmt.targets[0].id] = val
    a = fn.node.args
    pos = a.posonlyargs + a.args
    for p, d in zip(pos[len(pos) - len(a.defaults):], a.defaults):
        val = literal_or_none(d)
        if isinstance(val, (int, float)):
            env[p.arg] = val
    for p, d in zip(a.kwonlyargs, a.kw_defaults):
        if d is not None:
            val = literal_or_none(d)
            if isinstance(val, (int, float)):
                env[p.arg] = val
    ev = _Evaluator(project, fn.module, env)
    for stmt in ast.walk(fn.node):
        if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1:
            continue
        tgt = stmt.targets[0]
        try:
            if isinstance(tgt, ast.Name):
                if tgt.id != "_":
                    env[tgt.id] = ev.eval(stmt.value)
            elif isinstance(tgt, ast.Tuple) \
                    and all(isinstance(e, ast.Name) for e in tgt.elts):
                vals = ev.eval(stmt.value)
                if isinstance(vals, tuple) \
                        and len(vals) == len(tgt.elts):
                    for e, v in zip(tgt.elts, vals):
                        if e.id != "_":
                            env[e.id] = v
        except _EvalError:
            pass                         # shapes etc.: seeds stand in
    return env


# --------------------------------------------------------------------------
# pallas_call site parsing
# --------------------------------------------------------------------------

class _Site:
    def __init__(self, call: ast.Call):
        self.call = call
        self.n_prefetch = 0
        self.grid_expr: Optional[ast.expr] = None
        self.in_specs_expr: Optional[ast.expr] = None
        self.out_specs_expr: Optional[ast.expr] = None
        self.scratch_expr: Optional[ast.expr] = None

    @property
    def kernel_expr(self) -> Optional[ast.expr]:
        return self.call.args[0] if self.call.args else None


def _parse_site(call: ast.Call) -> _Site:
    site = _Site(call)
    kw = {k.arg: k.value for k in call.keywords}
    spec = kw.get("grid_spec")
    if isinstance(spec, ast.Call) \
            and call_name(spec).split(".")[-1] in (
                "PrefetchScalarGridSpec", "GridSpec"):
        skw = {k.arg: k.value for k in spec.keywords}
        n = literal_or_none(skw.get("num_scalar_prefetch")) \
            if skw.get("num_scalar_prefetch") is not None else 0
        site.n_prefetch = n if isinstance(n, int) else 0
        site.grid_expr = skw.get("grid")
        site.in_specs_expr = skw.get("in_specs")
        site.out_specs_expr = skw.get("out_specs")
        site.scratch_expr = skw.get("scratch_shapes")
    else:
        site.grid_expr = kw.get("grid")
        site.in_specs_expr = kw.get("in_specs")
        site.out_specs_expr = kw.get("out_specs")
        site.scratch_expr = kw.get("scratch_shapes")
    return site


def _spec_count(expr: Optional[ast.expr]) -> Optional[int]:
    if expr is None:
        return 0
    if isinstance(expr, (ast.List, ast.Tuple)):
        if any(isinstance(e, ast.Starred) for e in expr.elts):
            return None
        return len(expr.elts)
    if isinstance(expr, ast.Call):
        return 1                         # a single BlockSpec / shape
    return None                          # built dynamically


def _index_maps(fn: FunctionInfo):
    """Every ``pl.BlockSpec(shape, index_map)`` in the wrapper: yields
    (display name, lineno, params, body-or-None, FunctionInfo-or-None)."""
    seen = set()
    for node in ast.walk(fn.node):
        if not (isinstance(node, ast.Call)
                and call_name(node).split(".")[-1] == "BlockSpec"
                and len(node.args) >= 2):
            continue
        m = node.args[1]
        if isinstance(m, ast.Lambda):
            params = [p.arg for p in m.args.posonlyargs + m.args.args]
            yield ("<lambda>", m.lineno, params, m.body, None)
        elif isinstance(m, ast.Name):
            target = fn.module.functions.get(f"{fn.qualname}.{m.id}") \
                or fn.module.functions.get(m.id)
            if target is None or target.ref in seen:
                continue
            seen.add(target.ref)
            body = None
            for stmt in target.node.body:
                if isinstance(stmt, ast.Return):
                    body = stmt.value
            yield (m.id, target.node.lineno, target.positional_params,
                   body, target)


def _resolve_kernel(fn: FunctionInfo, expr: Optional[ast.expr],
                    project: Project):
    """(kernel FunctionInfo, partial-bound kw names) for the body arg."""
    if expr is None:
        return None, set()
    if isinstance(expr, ast.Name):
        # local ``kernel = functools.partial(...)`` assignment
        for stmt in ast.walk(fn.node):
            if isinstance(stmt, ast.Assign) \
                    and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name) \
                    and stmt.targets[0].id == expr.id:
                return _resolve_kernel(fn, stmt.value, project)
        target = project.resolve_symbol(fn.module, expr.id)
        return target, set()
    if isinstance(expr, ast.Call) \
            and call_name(expr).split(".")[-1] == "partial" and expr.args:
        inner, bound = _resolve_kernel(fn, expr.args[0], project)
        return inner, bound | {k.arg for k in expr.keywords
                               if k.arg is not None}
    return None, set()


# --------------------------------------------------------------------------
# checks
# --------------------------------------------------------------------------

class KernelChecker:
    def __init__(self, project: Project):
        self.project = project

    def check(self) -> List[Finding]:
        findings: List[Finding] = []
        for fn in self.project.all_functions():
            calls = [n for n in ast.walk(fn.node)
                     if isinstance(n, ast.Call)
                     and call_name(n).split(".")[-1] == "pallas_call"]
            for call in calls:
                self._check_site(fn, _parse_site(call), findings)
        return findings

    # ------------------------------------------------------------------
    def _check_site(self, fn: FunctionInfo, site: _Site,
                    findings: List[Finding]) -> None:
        env = _const_env(self.project, fn)
        ev = _Evaluator(self.project, fn.module, env)
        grid: Optional[Tuple[int, ...]] = None
        if site.grid_expr is not None:
            try:
                g = ev.eval(site.grid_expr)
                if isinstance(g, tuple) \
                        and all(isinstance(x, int) for x in g):
                    grid = g
                elif isinstance(g, int):
                    grid = (g,)
            except _EvalError:
                pass

        n_in = _spec_count(site.in_specs_expr)
        n_out = _spec_count(site.out_specs_expr)
        if site.out_specs_expr is None:
            # no out_specs: outputs are implied by out_shape
            kw = {k.arg: k.value for k in site.call.keywords}
            n_out = _spec_count(kw.get("out_shape")) \
                if "out_shape" in kw else None
        n_scr = _spec_count(site.scratch_expr)

        # (a) index-map arity + (d) page-walk boundedness
        if grid is not None:
            want = len(grid) + site.n_prefetch
            for name, lineno, params, body, _tgt in _index_maps(fn):
                if len(params) != want:
                    findings.append(Finding(
                        RULE, fn.module.rel, fn.qualname,
                        f"kernel.index-map-arity.{name}",
                        f"index_map `{name}` takes {len(params)} args but "
                        f"the grid has {len(grid)} dims + "
                        f"{site.n_prefetch} scalar-prefetch refs "
                        f"(= {want})", lineno))
                    continue
                if body is not None:
                    self._walk_check(fn, env, grid, site.n_prefetch, name,
                                     lineno, params, body, findings)

        # (b) kernel body arity
        kernel, bound = _resolve_kernel(fn, site.kernel_expr, self.project)
        if kernel is not None and None not in (n_in, n_out, n_scr) \
                and isinstance(kernel.node, ast.FunctionDef) \
                and kernel.node.args.vararg is None:
            free = [p for p in kernel.positional_params if p not in bound]
            want = site.n_prefetch + n_in + n_out + n_scr
            if len(free) != want:
                findings.append(Finding(
                    RULE, fn.module.rel, fn.qualname,
                    f"kernel.body-arity.{kernel.qualname}",
                    f"kernel body `{kernel.qualname}` has {len(free)} "
                    f"unbound positional refs but the specs imply "
                    f"{site.n_prefetch} prefetch + {n_in} in + {n_out} "
                    f"out + {n_scr} scratch = {want}",
                    site.call.lineno))

        # (c) operand count at the immediate call
        outer = self._outer_call(fn, site.call)
        if outer is not None and n_in is not None \
                and not any(isinstance(a, ast.Starred) for a in outer.args):
            want = site.n_prefetch + n_in
            if len(outer.args) != want:
                findings.append(Finding(
                    RULE, fn.module.rel, fn.qualname,
                    "kernel.operand-count",
                    f"pallas_call is invoked with {len(outer.args)} "
                    f"operands but the specs imply {site.n_prefetch} "
                    f"prefetch + {n_in} inputs = {want}", outer.lineno))

        # (e) output-store dtype agreement
        if kernel is not None and isinstance(kernel.node, ast.FunctionDef):
            self._dtype_check(kernel, findings)

    # ------------------------------------------------------------------
    def _outer_call(self, fn: FunctionInfo,
                    inner: ast.Call) -> Optional[ast.Call]:
        for node in ast.walk(fn.node):
            if isinstance(node, ast.Call) and node.func is inner:
                return node
        return None

    def _walk_check(self, fn, env, grid, n_prefetch, name, lineno, params,
                    body, findings) -> None:
        if not grid or math.prod(grid) > _GRID_CAP:
            return
        mb = env.get("MB", _SEED_ENV["MB"])
        bs = env.get("BS", _SEED_ENV["BS"])
        cap = mb * bs
        chunk = env.get("W", _SEED_ENV["W"])
        for live in _LIVE_SET:
            if live > cap:
                continue
            tables = [_Table(live, live + chunk) for _ in range(n_prefetch)]
            for point in itertools.product(*(range(d) for d in grid)):
                local = dict(env)
                for p, v in zip(params, list(point) + tables):
                    local[p] = v
                try:
                    _Evaluator(self.project, fn.module, local).eval(body)
                except _EvalError:
                    return               # can't evaluate: stay quiet
            cols = [c for t in tables for c in t.cols]
            if not cols:
                return                   # no table access in this map
            last_live = max(-(-live // bs) - 1, 0)
            bad = [c for c in cols if c < 0 or c >= mb or c > last_live]
            if bad:
                findings.append(Finding(
                    RULE, fn.module.rel, fn.qualname,
                    f"kernel.page-walk-unbounded.{name}",
                    f"index_map `{name}` reads block-table column "
                    f"{max(bad)} with only {live} live tokens "
                    f"(last live page {last_live}, table width {mb}) — "
                    "clamp the walk to the live prefix "
                    "(see _clamp_live / _chunk_clamp)", lineno))
                return

    def _dtype_check(self, kernel: FunctionInfo,
                     findings: List[Finding]) -> None:
        for node in ast.walk(kernel.node):
            if not (isinstance(node, ast.Assign)
                    and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Subscript)
                    and isinstance(node.targets[0].value, ast.Name)):
                continue
            oname = node.targets[0].value.id
            if not _OUT_REF_RE.match(oname):
                continue
            src = ast.unparse(node.value)
            if f".astype({oname}.dtype)" in src:
                continue
            # a pure ref-to-ref copy keeps the dtype by construction
            if isinstance(node.value, ast.Subscript) \
                    and isinstance(node.value.value, ast.Name) \
                    and node.value.value.id.endswith("_ref"):
                continue
            findings.append(Finding(
                RULE, kernel.module.rel, kernel.qualname,
                "kernel.out-dtype",
                f"store to `{oname}` does not `.astype({oname}.dtype)` — "
                "an f32 accumulator write silently changes the kernel's "
                "output dtype under interpret and fails on TPU",
                node.lineno))


def check_kernel_contracts(project: Project) -> List[Finding]:
    return finalize_occurrences(KernelChecker(project).check())
