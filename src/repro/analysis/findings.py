"""Finding records and the checked-in baseline.

A finding's *key* deliberately excludes line numbers: it is
``rule:path:qualname:kind:occurrence`` so that unrelated edits above a
justified site do not churn ``analysis/baseline.json``.  The occurrence
index disambiguates repeated identical sites inside one function (two
``np.asarray`` readbacks in the same body are two keys).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List

BASELINE_VERSION = 1


@dataclass(frozen=True)
class Finding:
    rule: str          # "R1".."R5"
    path: str          # repo-relative posix path
    qualname: str      # enclosing function ("<module>" at top level)
    kind: str          # stable slug, e.g. "sync.np.asarray(out)"
    detail: str        # human-readable message
    line: int          # 1-based source line (informational only)
    occurrence: int = 0

    @property
    def key(self) -> str:
        return (f"{self.rule}:{self.path}:{self.qualname}:{self.kind}"
                f":{self.occurrence}")

    def render(self, status: str = "") -> str:
        tag = f" [{status}]" if status else ""
        return (f"{self.rule}{tag} {self.path}:{self.line} "
                f"({self.qualname}): {self.detail}")


def finalize_occurrences(findings: List[Finding]) -> List[Finding]:
    """Assign occurrence indices: identical (rule, path, qualname, kind)
    tuples are numbered in source order so keys stay unique + stable."""
    seen: Dict[tuple, int] = {}
    out = []
    for f in sorted(findings, key=lambda f: (f.path, f.line)):
        ident = (f.rule, f.path, f.qualname, f.kind)
        n = seen.get(ident, 0)
        seen[ident] = n + 1
        out.append(Finding(f.rule, f.path, f.qualname, f.kind, f.detail,
                           f.line, occurrence=n))
    return out


@dataclass
class Baseline:
    """The justified-findings allowlist (``analysis/baseline.json``)."""
    entries: Dict[str, dict] = field(default_factory=dict)

    def justification(self, key: str) -> str:
        return self.entries.get(key, {}).get("justification", "")

    def diff(self, findings: List[Finding]):
        """Split current findings against the baseline.

        Returns (new, known, stale_keys): *new* findings are absent from
        the baseline (the merge-gate failures), *known* are baselined,
        *stale_keys* are baseline entries the current tree no longer
        produces (fixed or renamed — prune with ``--update-baseline``).
        """
        current = {f.key for f in findings}
        new = [f for f in findings if f.key not in self.entries]
        known = [f for f in findings if f.key in self.entries]
        stale = sorted(k for k in self.entries if k not in current)
        return new, known, stale

    def validate(self) -> List[str]:
        """Every baseline entry must carry a non-empty justification —
        an unjustified suppression is itself a gate failure."""
        return sorted(k for k, v in self.entries.items()
                      if not str(v.get("justification", "")).strip())


def load_baseline(path) -> Baseline:
    data = json.loads(Path(path).read_text())
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(f"baseline {path}: unsupported version "
                         f"{data.get('version')!r} (expected "
                         f"{BASELINE_VERSION})")
    entries = data.get("findings", {})
    if not isinstance(entries, dict):
        raise ValueError(f"baseline {path}: 'findings' must be an object "
                         "keyed by finding key")
    return Baseline(entries=entries)


def write_baseline(path, findings: List[Finding],
                   previous: Baseline = None) -> None:
    """Regenerate the baseline from the current findings, carrying over
    existing justifications; fresh entries get an empty justification the
    validator will force the author to fill in."""
    prev = previous.entries if previous is not None else {}
    entries = {}
    for f in findings:
        entry = dict(prev.get(f.key, {}))
        entry.setdefault("justification", "")
        entry["rule"] = f.rule
        entry["detail"] = f.detail
        entries[f.key] = entry
    payload = {"version": BASELINE_VERSION,
               "findings": dict(sorted(entries.items()))}
    Path(path).write_text(json.dumps(payload, indent=2) + "\n")
