"""R3 — retrace-hazard.

Two ways a call site silently multiplies jit cache entries:

* **unstable-static** — a value derived from runtime quantities (a
  ``len(...)``, a loop counter, arithmetic on either) is passed into a
  ``static_argnames`` position: every distinct value is a fresh trace.
  Static positions are tracked through one forwarding hop, so
  ``ops.flash_attention(..., q_offset=off)`` is caught even though the
  ``static_argnames`` declaration lives on the kernel it forwards to.
* **varying-shape** — an array whose *shape* embeds a runtime quantity
  (``np.zeros((len(seqs), maxlen))``) reaches a jit executable: every
  distinct shape is a fresh trace.  Propagated through ``jnp.asarray``,
  dict literals, and dict-subscript stores so the batched prefill dicts
  are tracked end to end.

Both are per-function, flow-forward, and fire only when an *unstable*
name is syntactically present — config attributes, ``bool(...)`` flags
and backend probes never contain one, so the fixed-shape serving paths
stay silent.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from repro.analysis.findings import Finding, finalize_occurrences
from repro.analysis.jit_registry import JitRegistry
from repro.analysis.project import FunctionInfo, Project, call_name

RULE = "R3"

_SHAPE_CTORS = {"zeros", "ones", "empty", "full", "arange"}
_ARRAY_WRAPS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
                "jnp.asarray", "jnp.array", "jax.numpy.asarray",
                "jax.numpy.array"}


def _names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _own_statements(fn_node):
    out = []

    def rec(body):
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            out.append(stmt)
            for field in ("body", "orelse", "finalbody"):
                rec(getattr(stmt, field, []) or [])
            for h in getattr(stmt, "handlers", []) or []:
                rec(h.body)

    rec(fn_node.body)
    return out


def _unstable_names(fn: FunctionInfo) -> Set[str]:
    """Names holding runtime-varying host scalars: len() results, loop
    targets, and arithmetic derived from either."""
    unstable: Set[str] = set()
    for stmt in _own_statements(fn.node):
        if isinstance(stmt, ast.For):
            for n in ast.walk(stmt.target):
                if isinstance(n, ast.Name):
                    unstable.add(n.id)
        if isinstance(stmt, (ast.Assign, ast.AugAssign)) \
                and getattr(stmt, "value", None) is not None:
            derived = False
            v = stmt.value
            if isinstance(v, ast.Call) and call_name(v) == "len":
                derived = True
            elif isinstance(v, (ast.BinOp, ast.UnaryOp)):
                names = _names_in(v)
                if names & unstable or any(
                        isinstance(c, ast.Call) and call_name(c) == "len"
                        for c in ast.walk(v)):
                    derived = True
            elif isinstance(v, ast.Name) and v.id in unstable:
                derived = True
            if derived:
                targets = stmt.targets if isinstance(stmt, ast.Assign) \
                    else [stmt.target]
                for tgt in targets:
                    for n in ast.walk(tgt):
                        if isinstance(n, ast.Name):
                            unstable.add(n.id)
    return unstable


def _varying_names(fn: FunctionInfo, unstable: Set[str]) -> Set[str]:
    """Names holding arrays (or containers of arrays) whose shape embeds
    an unstable quantity."""
    varying: Set[str] = set()
    for stmt in _own_statements(fn.node):
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            tgt, v = stmt.targets[0], stmt.value
            marked = False
            if isinstance(v, ast.Call):
                name = call_name(v)
                if name.split(".")[-1] in _SHAPE_CTORS and v.args:
                    if _names_in(v.args[0]) & unstable:
                        marked = True
                elif name in _ARRAY_WRAPS and v.args:
                    if _names_in(v.args[0]) & varying:
                        marked = True
            elif isinstance(v, ast.Dict):
                if any(_names_in(val) & varying
                       for val in v.values if val is not None):
                    marked = True
            elif isinstance(v, (ast.DictComp, ast.ListComp)):
                if any(_names_in(g.iter) & varying for g in v.generators):
                    marked = True
            elif isinstance(v, ast.IfExp):
                if _names_in(v) & varying:
                    marked = True
            elif isinstance(v, ast.Name) and v.id in varying:
                marked = True
            if marked:
                if isinstance(tgt, ast.Name):
                    varying.add(tgt.id)
                elif isinstance(tgt, ast.Subscript) \
                        and isinstance(tgt.value, ast.Name):
                    varying.add(tgt.value.id)
            # dict-subscript store of a varying value marks the dict
            if isinstance(tgt, ast.Subscript) \
                    and isinstance(tgt.value, ast.Name) \
                    and _names_in(v) & varying:
                varying.add(tgt.value.id)
    return varying


class RetraceChecker:
    def __init__(self, project: Project):
        self.project = project
        self.registry = JitRegistry(project)
        # FunctionInfo.ref -> {param name} forwarded into a static position
        self.forwarding: Dict[str, Set[str]] = {}
        self._build_forwarding()

    # ------------------------------------------------------------------
    def _static_params_for_call(self, fn: FunctionInfo, call: ast.Call):
        """(display name, positional params, static names, site-or-None)
        when ``call`` targets a jit site or static-forwarding function."""
        f = call.func
        if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name) \
                and f.value.id == "self":
            site = self.registry.attr_site(fn.class_name, f.attr)
            if site is not None:
                return (site.name, site.positional_params,
                        set(site.static_names), site)
            return None
        target = None
        if isinstance(f, ast.Name):
            target = self.project.resolve_symbol(fn.module, f.id)
        elif isinstance(f, ast.Attribute):
            target = self.project.resolve_attr_call(fn.module, f.value,
                                                    f.attr)
        if target is None:
            return None
        site = self.registry.decorated_site(target.ref)
        statics: Set[str] = set(site.static_names) if site else set()
        statics |= self.forwarding.get(target.ref, set())
        if not statics and site is None:
            return None
        return target.qualname, target.positional_params, statics, site

    def _build_forwarding(self) -> None:
        """One hop: a param passed (as a bare name) into a static position
        of a jit callable marks that param static-forwarding."""
        for fn in self.project.all_functions():
            params = set(fn.params)
            fwd: Set[str] = set()
            for call in (n for n in ast.walk(fn.node)
                         if isinstance(n, ast.Call)):
                hit = self._direct_static(fn, call)
                if hit is None:
                    continue
                _, statics, bound = hit
                for pname, arg in bound.items():
                    if pname in statics and isinstance(arg, ast.Name) \
                            and arg.id in params:
                        fwd.add(arg.id)
            if fwd:
                self.forwarding[fn.ref] = fwd

    def _direct_static(self, fn, call):
        """Like ``_static_params_for_call`` but registry-only (no
        forwarding — prevents recursion while building the map)."""
        f = call.func
        site = None
        if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name) \
                and f.value.id == "self":
            site = self.registry.attr_site(fn.class_name, f.attr)
        else:
            target = None
            if isinstance(f, ast.Name):
                target = self.project.resolve_symbol(fn.module, f.id)
            elif isinstance(f, ast.Attribute):
                target = self.project.resolve_attr_call(fn.module, f.value,
                                                        f.attr)
            if target is not None:
                site = self.registry.decorated_site(target.ref)
        if site is None or not site.static_names:
            return None
        return site.name, set(site.static_names), _bind(site.positional_params,
                                                        call)

    # ------------------------------------------------------------------
    def check(self) -> List[Finding]:
        findings: List[Finding] = []
        for fn in self.project.all_functions():
            unstable = _unstable_names(fn)
            varying = _varying_names(fn, unstable)
            for call in (n for n in ast.walk(fn.node)
                         if isinstance(n, ast.Call)):
                self._check_call(fn, call, unstable, varying, findings)
        return findings

    def _check_call(self, fn, call, unstable, varying, findings) -> None:
        hit = self._static_params_for_call(fn, call)
        if hit is None:
            return
        name, params, statics, site = hit
        bound = _bind(params, call)
        for pname, arg in bound.items():
            if pname not in statics:
                continue
            bad = sorted(_names_in(arg) & unstable)
            if bad:
                findings.append(Finding(
                    RULE, fn.module.rel, fn.qualname,
                    f"retrace.unstable-static.{pname}",
                    f"static argument `{pname}` of `{name}` receives "
                    f"`{ast.unparse(arg)}` — `{'`, `'.join(bad)}` varies "
                    "at runtime, so every value compiles a new trace",
                    call.lineno))
        # varying-shape operands reaching a jit executable
        if site is not None and not isinstance(call.func, ast.Lambda):
            flagged: Set[str] = set()
            for arg in list(call.args) + [k.value for k in call.keywords]:
                bad = sorted((_names_in(arg) & varying) - flagged)
                if bad:
                    flagged.update(bad)
                    findings.append(Finding(
                        RULE, fn.module.rel, fn.qualname,
                        f"retrace.varying-shape.{bad[0]}",
                        f"`{name}` is called with `{ast.unparse(arg)}` "
                        f"whose shape depends on runtime size "
                        f"(`{'`, `'.join(bad)}`) — each distinct shape "
                        "compiles a new trace", call.lineno))


def _bind(params: List[str], call: ast.Call) -> Dict[str, ast.expr]:
    """Map positional params to the argument expressions at a call."""
    bound: Dict[str, ast.expr] = {}
    for i, arg in enumerate(call.args):
        if isinstance(arg, ast.Starred):
            break
        if i < len(params):
            bound[params[i]] = arg
    for kw in call.keywords:
        if kw.arg is not None:
            bound[kw.arg] = kw.value
    return bound


def check_retrace(project: Project) -> List[Finding]:
    return finalize_occurrences(RetraceChecker(project).check())
