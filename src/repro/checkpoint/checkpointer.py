"""Atomic, resumable, topology-independent checkpoints.

* Leaves are saved as .npy under ``step_<N>.tmp/`` then renamed —
  a crash mid-write never corrupts the latest checkpoint.
* Shardings are NOT stored: on restore, arrays are ``device_put`` with
  shardings derived from the *current* mesh's logical rules, so a job can
  restart on a different device count (elastic re-mesh, DESIGN.md §4).
* ``AsyncCheckpointer`` overlaps serialization with the next train steps.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    if isinstance(tree, dict):
        out = {}
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}.{k}" if prefix else str(k)))
        return out
    if isinstance(tree, (tuple, list)) or hasattr(tree, "_fields"):
        items = tree._asdict().items() if hasattr(tree, "_asdict") else \
            enumerate(tree)
        out = {}
        for k, v in items:
            out.update(_flatten(v, f"{prefix}.{k}" if prefix else str(k)))
        return out
    return {prefix: tree}


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, trees: Dict[str, Any],
             extra: Optional[Dict[str, Any]] = None) -> str:
        tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
        final = os.path.join(self.dir, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "trees": {}, "extra": extra or {}}
        for tname, tree in trees.items():
            flat = _flatten(tree)
            manifest["trees"][tname] = sorted(flat)
            for path, leaf in flat.items():
                arr = np.asarray(jax.device_get(leaf))
                fn = _SAFE.sub("_", f"{tname}.{path}") + ".npy"
                np.save(os.path.join(tmp, fn), arr)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for n in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", n)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        st = self.all_steps()
        return st[-1] if st else None

    def restore(self, step: int, templates: Dict[str, Any],
                shardings: Optional[Dict[str, Any]] = None
                ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """templates: pytrees with the target structure (leaves may be
        ShapeDtypeStructs). shardings: same-structure NamedSharding trees."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        out = {}
        for tname, tree in templates.items():
            flat_t = _flatten(tree)
            flat_s = _flatten(shardings[tname]) if shardings and \
                tname in shardings else {}
            loaded = {}
            for path in flat_t:
                fn = _SAFE.sub("_", f"{tname}.{path}") + ".npy"
                arr = np.load(os.path.join(d, fn))
                sh = flat_s.get(path)
                loaded[path] = (jax.device_put(arr, sh) if sh is not None
                                else jnp.asarray(arr))
            out[tname] = _unflatten_like(tree, loaded)
        return out, manifest["extra"]


def _unflatten_like(tree: Any, flat: Dict[str, Any], prefix: str = ""):
    if isinstance(tree, dict):
        return {k: _unflatten_like(v, flat, f"{prefix}.{k}" if prefix else str(k))
                for k, v in tree.items()}
    if hasattr(tree, "_fields"):                           # NamedTuple
        vals = {k: _unflatten_like(v, flat, f"{prefix}.{k}" if prefix else str(k))
                for k, v in tree._asdict().items()}
        return type(tree)(**vals)
    if isinstance(tree, (tuple, list)):
        vals = [_unflatten_like(v, flat, f"{prefix}.{i}" if prefix else str(i))
                for i, v in enumerate(tree)]
        return type(tree)(vals)
    return flat[prefix]


class AsyncCheckpointer(Checkpointer):
    """Overlaps device_get+serialize with subsequent steps (one in flight)."""

    def __init__(self, directory: str, keep: int = 3):
        super().__init__(directory, keep)
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save_async(self, step: int, trees: Dict[str, Any],
                   extra: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        # snapshot to host NOW (cheap, ordered) — serialization runs async
        host_trees = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  trees)

        def work():
            try:
                self.save(step, host_trees, extra)
            except BaseException as e:      # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
