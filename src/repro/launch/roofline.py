"""Roofline-term extraction from compiled dry-run artifacts.

compute term    = HLO_FLOPs / peak_FLOP/s            (per-chip: post-SPMD
memory term     = HLO_bytes / HBM_bw                  modules are per-device)
collective term = collective_bytes / link_bw

collective_bytes are parsed from the (per-device) optimized HLO: for every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
we count the *result* shard bytes, scaled by the ring-traffic factor of the
op (all-reduce moves ~2x its payload over the slowest link; the others ~1x).

Scan-over-layers caveat: XLA's cost_analysis counts a while-loop body ONCE
(verified empirically), so costs for L-layer models are derived from two
small *unrolled* lowers (L_a, L_b = L_a + period) and extrapolated
linearly: C(L) = C(L_a) + (L - L_a)/P * (C(L_b) - C(L_a)). The full-config
compile is still performed — it is the sharding/memory proof.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

import numpy as _np

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(
    r"(pred|[sub]\d+|bf16|f8e4m3fn|f8e5m2|f8e4m3b11fnuz|f\d+|c\d+)"
    r"\[([\d,]*)\]")

_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
           "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-op-kind result bytes (per device), ring-factor scaled."""
    out: Dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes = m.group(1) or m.group(2)
        kind = m.group(3)
        b = _shape_bytes(shapes) * _FACTOR[kind]
        out[kind] = out.get(kind, 0.0) + b
    return out


# Ops that necessarily touch HBM on TPU (elementwise chains fuse into their
# neighbours and are excluded — the CPU backend fuses far less than the TPU
# backend, so raw cost_analysis() "bytes accessed" overestimates traffic by
# ~5-10x; see EXPERIMENTS.md §Roofline methodology).
_HEAVY_OPS = {
    "dot", "convolution", "fusion", "scatter", "gather",
    "dynamic-update-slice", "dynamic-slice", "reduce", "sort", "copy",
    "transpose", "concatenate", "pad", "reduce-window",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
}

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%?[\w.-]+)\s*=\s*((?:\([^)]*\)|\S+))\s+([\w-]+)"
    r"(?:-start|-done)?\((.*?)\)", re.M)
_OPERAND_RE = re.compile(r"%[\w.-]+")
_COMP_RE = re.compile(r"^(%?[\w.-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$")


def hbm_bytes_fusion_aware(hlo_text: str) -> float:
    """Estimate per-device HBM traffic from optimized HLO.

    Unique-buffer accounting: every buffer produced or consumed by a
    _HEAVY_OPS instruction (outside fusion bodies) crosses HBM twice —
    once written, once read — regardless of how many consumers it has.
    This (a) drops elementwise chains that a TPU backend would fuse, and
    (b) avoids multi-consumer double counting from the CPU backend's
    slice-happy SPMD lowering. It approximates the traffic of a
    well-fused TPU lowering of the same program.
    """
    defs: Dict[str, int] = {}
    touched: Dict[str, int] = {}
    sliced = 0.0
    in_fused = False
    # donated inputs (params in train, KV pools in decode) alias their
    # outputs: in-place update fusions on them move only the update, not
    # the buffer. Track the alias chain across the program.
    aliased: set = set()
    in_entry = False
    for line in hlo_text.splitlines():
        # computation headers start at column 0 (signatures may wrap over
        # several lines; the header line carries the name).
        if line and not line[0].isspace() and ("(" in line or
                                               line.startswith("ENTRY")):
            head = line.split("(")[0]
            in_entry = line.startswith("ENTRY")
            in_fused = (not in_entry) and ("fused" in head or
                                           "region" in head or
                                           "wide." in head)
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shapes, op, operands = m.groups()
        name = name.lstrip("%")
        b_out = _shape_bytes(shapes)
        defs[name] = b_out
        if op == "parameter" and in_entry and b_out >= 1e6:
            # Seed alias roots from all large entry params (donated pools /
            # weights): in-place same-element update chains on them don't
            # move the buffer; genuine full reads still count via the
            # consuming dot/reduce operands. XLA sometimes drops the
            # input_output_alias annotation (e.g. f8 pools), so we don't
            # rely on it.
            aliased.add(name)
        if in_fused:
            continue
        ops_list = [o.lstrip("%") for o in _OPERAND_RE.findall(operands)]
        # sliced-access ops touch only the moved slice, not the whole
        # buffer (paged-pool writes/gathers would otherwise count the
        # full pool per layer): gather/dynamic-slice move ~their output;
        # dynamic-update-slice/scatter move ~their update operand.
        if op in ("gather", "dynamic-slice"):
            sliced += 2.0 * b_out
            continue
        if op == "dynamic-update-slice":
            upd = defs.get(ops_list[1], 0) if len(ops_list) > 1 else 0
            sliced += 2.0 * upd
            if ops_list and ops_list[0] in aliased:
                aliased.add(name)
            continue
        if op == "scatter":
            upd = defs.get(ops_list[2], 0) if len(ops_list) > 2 else b_out
            sliced += 2.0 * upd
            if ops_list and ops_list[0] in aliased:
                aliased.add(name)
            continue
        # in-place update chain on donated buffers: a fusion/copy/convert
        # whose output is the same logical buffer (same element count;
        # bf16<->f32 legalization on CPU changes bytes 2x) moves only the
        # small non-aliased operands. TPU scatters bf16 in place.
        al = [o for o in ops_list if o in aliased]
        if al and any(b_out in (defs[o], 2 * defs[o], defs[o] // 2,
                                4 * defs[o], defs[o] // 4)
                      for o in al):
            aliased.add(name)
            if op in _HEAVY_OPS:
                for o in ops_list:
                    if o not in aliased and o in defs:
                        touched[o] = defs[o]
            continue
        if op not in _HEAVY_OPS:
            continue
        touched[name] = b_out
        for o in ops_list:
            if o in defs:
                touched[o] = defs[o]
    return 2.0 * sum(touched.values()) + sliced


@dataclass
class RooflineTerms:
    flops: float = 0.0                 # per device
    hbm_bytes: float = 0.0             # fusion-aware estimate
    hbm_bytes_upper: float = 0.0       # raw cost_analysis bound
    coll_bytes: float = 0.0            # factor-scaled
    coll_breakdown: Dict[str, float] = field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> Dict:
        return {**asdict(self), "t_compute": self.t_compute,
                "t_memory": self.t_memory, "t_collective": self.t_collective,
                "bottleneck": self.bottleneck}


def terms_from_compiled(compiled) -> RooflineTerms:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # jax 0.4.3x: one dict per computation
        ca = ca[0] if ca else {}
    text = compiled.as_text()
    cb = collective_bytes(text)
    return RooflineTerms(
        flops=float(ca.get("flops", 0.0)),
        hbm_bytes=hbm_bytes_fusion_aware(text),
        hbm_bytes_upper=float(ca.get("bytes accessed", 0.0)),
        coll_bytes=sum(cb.values()),
        coll_breakdown=cb,
    )


def extrapolate(t_a: RooflineTerms, t_b: RooflineTerms, l_a: int, l_b: int,
                L: int) -> RooflineTerms:
    """Linear layer-count extrapolation (see module docstring)."""
    k = (L - l_a) / max(l_b - l_a, 1)

    def ex(a, b):
        return a + k * (b - a)

    keys = set(t_a.coll_breakdown) | set(t_b.coll_breakdown)
    return RooflineTerms(
        flops=ex(t_a.flops, t_b.flops),
        hbm_bytes=ex(t_a.hbm_bytes, t_b.hbm_bytes),
        hbm_bytes_upper=ex(t_a.hbm_bytes_upper, t_b.hbm_bytes_upper),
        coll_bytes=ex(t_a.coll_bytes, t_b.coll_bytes),
        coll_breakdown={k2: ex(t_a.coll_breakdown.get(k2, 0.0),
                               t_b.coll_breakdown.get(k2, 0.0))
                        for k2 in keys},
    )


def mixer_terms(cfg, shape, chips: int, block_q: int = 512,
                bpe: int = 2, dp_size: Optional[int] = None) -> RooflineTerms:
    """Analytic kernel-accurate terms for the mixer cores that the
    ``skip_mixer_core`` lower removed (Pallas flash/paged attention, SSM /
    RG-LRU time scans). Traffic is the kernels' HBM traffic: score tiles /
    recurrent states stay in VMEM by construction (BlockSpec), so only
    q/k/v/o streaming, KV-cache reads, and chunk-boundary state spills
    count.
    """
    B, S = shape.global_batch, shape.seq_len
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    train = shape.kind == "train"
    decode = shape.kind == "decode"
    # decode caches shard over dp only (shard_map island, DESIGN §4) and
    # replicate over the model axis: per-chip traffic = global / dp.
    dp = dp_size or max(chips // 16, 1)
    kv_div = dp if decode else chips
    try:
        bpe_kv = _np.dtype(cfg.paging.cache_dtype).itemsize
    except TypeError:                      # float8 etc: 1 byte
        bpe_kv = 1 if "8" in cfg.paging.cache_dtype else 2
    passes = 3.5 if train else 1.0          # 1 fwd + ~2.5 flash bwd
    io_passes = 3.0 if train else 1.0
    flops = 0.0
    bts = 0.0
    for i in range(cfg.num_layers):
        kind = cfg.layer_kind(i)
        if kind in ("full", "sliding"):
            W = cfg.sliding_window if kind == "sliding" else 0
            if decode:
                kvlen = min(S, W) if W else S
                flops += 4.0 * B * H * kvlen * Dh
                if not W:                    # paged: pool read not in lower
                    bts += 2.0 * B * KV * kvlen * Dh * bpe_kv * (chips / kv_div)
                continue
            if cfg.is_encoder:
                pairs = float(S) * S
            elif W and W < S:
                pairs = float(S) * W - W * W / 2.0
            else:
                pairs = float(S) * S / 2.0
            flops += passes * 4.0 * B * H * pairs * Dh
            nqb = max(1, S // block_q)
            kv_reread = pairs / max(float(S) * S, 1.0) * 2.0   # causal frac
            bts += io_passes * B * Dh * bpe * (
                2.0 * S * H                  # q read + o write
                + 2.0 * S * KV * nqb * kv_reread)
        elif kind == "ssm":
            din = cfg.ssm_expand * cfg.d_model
            N = cfg.ssm_state
            steps = 1 if decode else S
            flops += passes * 9.0 * B * steps * din * N
            if decode:
                bts += B * din * N * 4 * 2.0          # state read+write
            else:
                bts += io_passes * B * steps * (3 * din + 2 * N) * 4
                bts += io_passes * (steps / 128.0) * B * din * N * 4 * 2
        elif kind == "recurrent":
            w = cfg.lru_width or cfg.d_model
            steps = 1 if decode else S
            flops += passes * 8.0 * B * steps * w
            bts += (B * w * 4 * 2.0 if decode
                    else io_passes * 3.0 * B * steps * w * 4)
        if cfg.num_experts and kind != "ssm":
            # routed experts (ragged grouped matmuls; skip-lowered because
            # XLA cost-counts ragged_dot as dense): 3 matmuls over
            # capacity-bounded rows, capacity factor 2.0 (models/moe.py).
            from repro.models.moe import CAPACITY_FACTOR, padded_experts
            d, f, k = cfg.d_model, cfg.moe_d_ff, cfg.moe_top_k
            tokens = B if decode else B * S
            rows = tokens * k * CAPACITY_FACTOR
            flops += passes * 6.0 * rows * d * f
            # expert weights stream once per step per chip (EP over the
            # 16-way model axis when divisible); bts is global here and is
            # divided by chips on return.
            e_pad = padded_experts(cfg, 16)
            ep = 16 if e_pad % 16 == 0 else 1
            w_pass = io_passes if not decode else 1.0
            bts += (e_pad / ep) * 3.0 * d * f * bpe * chips * w_pass
            bts += io_passes * rows * (2 * d + f) * bpe   # row activations
    return RooflineTerms(flops=flops / chips, hbm_bytes=bts / chips)


def combine(base: RooflineTerms, mixer: RooflineTerms) -> RooflineTerms:
    return RooflineTerms(
        flops=base.flops + mixer.flops,
        hbm_bytes=base.hbm_bytes + mixer.hbm_bytes,
        hbm_bytes_upper=base.hbm_bytes_upper + mixer.hbm_bytes,
        coll_bytes=base.coll_bytes,
        coll_breakdown=dict(base.coll_breakdown),
    )


def model_flops_per_step(cfg, shape, chips: int) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), per chip.

    N = active params, D = tokens processed this step."""
    n = cfg.num_active_params()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        f = 6.0 * n * d
    elif shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        f = 2.0 * n * d
    else:  # decode: one token per sequence
        d = shape.global_batch
        f = 2.0 * n * d
    return f / chips
