"""End-to-end training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt [--resume]

On this CPU box use --reduced (a ~100M-and-below same-family config); on a
pod, drop --reduced and the production mesh + shardings apply unchanged.
"""
from __future__ import annotations

import argparse
import logging
import time

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ShapeConfig
from repro.configs.registry import get_config, get_reduced
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.runtime.fault import PreemptionError, Supervisor
from repro.runtime.sharding import make_ctx, param_shardings
from repro.runtime.train_loop import jit_train_step

logging.basicConfig(level=logging.INFO)
log = logging.getLogger("repro.train")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", choices=["local", "single", "multi"],
                    default="local")
    ap.add_argument("--d-model", type=int, default=0,
                    help="override reduced width (e.g. ~100M model)")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="inject a failure once (tests checkpoint-restart)")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.d_model:
        cfg = cfg.replace(d_model=args.d_model, head_dim=args.d_model // cfg.num_heads)
    if args.layers:
        cfg = cfg.replace(num_layers=args.layers)

    mesh = {"local": make_local_mesh,
            "single": make_production_mesh,
            "multi": lambda: make_production_mesh(multi_pod=True)}[args.mesh]()
    ctx = make_ctx(mesh) if mesh.size > 1 else None

    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    data = SyntheticLM(cfg, shape)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps)

    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    if ctx is not None:
        params = jax.device_put(params, param_shardings(ctx, params, cfg))
    opt_state = init_opt_state(params, opt_cfg)
    step_fn = jit_train_step(cfg, opt_cfg, ctx, params,
                             rt={"scan_layers": True},
                             num_microbatches=args.microbatches)

    ckpt = Checkpointer(args.ckpt_dir)
    sup = Supervisor(checkpointer=ckpt, save_every=args.save_every)

    start = 0
    if args.resume and ckpt.latest_step() is not None:
        trees, extra = ckpt.restore(ckpt.latest_step(),
                                    {"params": params, "opt": opt_state})
        params, opt_state = trees["params"], trees["opt"]
        data.restore(extra["data"])
        start = int(ckpt.latest_step())
        log.info("resumed from step %d", start)

    state = {"step": start,
             "trees": {"params": params, "opt": opt_state},
             "extra": {"data": data.state()}}
    injected = {"done": False}

    def fail_hook(step):
        if args.fail_at_step >= 0 and step == args.fail_at_step \
                and not injected["done"]:
            injected["done"] = True
            raise PreemptionError(f"injected failure at step {step}")

    losses = []

    def do_step(step, st):
        batch = data.next_batch(mesh if ctx is not None else None)
        p, o = st["trees"]["params"], st["trees"]["opt"]
        t0 = time.perf_counter()
        p, o, m = step_fn(p, o, batch)
        loss = float(m["loss"])
        losses.append(loss)
        if step % 10 == 0:
            log.info("step %5d loss %.4f gnorm %.3f lr %.2e (%.3fs)",
                     step, loss, float(m["grad_norm"]), float(m["lr"]),
                     time.perf_counter() - t0)
        st["trees"] = {"params": p, "opt": o}
        st["extra"] = {"data": data.state()}
        return st

    def restore_fn(last_step):
        trees, extra = ckpt.restore(
            last_step, {"params": state["trees"]["params"],
                        "opt": state["trees"]["opt"]})
        data.restore(extra["data"])
        return {"step": last_step, "trees": trees,
                "extra": {"data": data.state()}}

    sup.run(total_steps=args.steps, state=state, step_fn=do_step,
            restore_fn=restore_fn, fail_hook=fail_hook)
    log.info("done. first loss %.4f -> last loss %.4f (restarts: %d)",
             losses[0], losses[-1], sup.restarts)


if __name__ == "__main__":
    main()
