"""Production mesh definition (TPU v5e: 16x16 = 256 chips per pod)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist, as a (data, model) mesh (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


# TPU v5e hardware constants for the roofline model (per chip).
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link
