"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces
  * the compile proof (sharding coherence) + memory_analysis of the FULL
    config (scan-over-layers),
  * per-chip roofline terms from two small unrolled lowers, extrapolated
    linearly in layer count (launch/roofline.py),
and writes one JSON per cell under experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--jobs-filter train]
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ALL_SHAPES, ModelConfig, ShapeConfig
from repro.configs.registry import ARCHS, get_config, shapes_for
from repro.launch.mesh import make_production_mesh
from repro.launch import roofline as RF
from repro.models import registry as MR
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.runtime.sharding import (batch_shardings, make_ctx,
                                    param_shardings, state_shardings)
from repro.runtime.train_loop import make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

BIG_PARAMS = 50e9      # >= this: bf16 params + bf16 adam moments


def _cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, dtype)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def _pattern_period(cfg: ModelConfig) -> int:
    return len(cfg.attn_pattern) if cfg.family == "hybrid" else 1


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               scan_layers: bool, num_layers: Optional[int] = None,
               quant: bool = False, skip_mixer_core: bool = False,
               num_microbatches: int = 1, rt_extra: Optional[dict] = None,
               policy: str = "2d", chunk_tokens: Optional[int] = None):
    """Returns (jitted_fn, arg_specs tuple) for one cell.

    ``chunk_tokens`` (serving ``max_num_batched_tokens``) switches a
    prefill cell to the fixed-shape chunk executable — the [1, W] +
    scalar-offset form the token-budget engine compiles exactly once."""
    if num_layers is not None:
        cfg = cfg.replace(num_layers=num_layers)
    ctx = make_ctx(mesh, policy)
    rt = {"use_pallas": False, "scan_layers": scan_layers,
          "skip_mixer_core": skip_mixer_core, "ctx": ctx,
          "remat_policy": jax.checkpoint_policies.nothing_saveable}
    rt.update(rt_extra or {})
    big = cfg.num_params() >= BIG_PARAMS
    pdtype = jnp.bfloat16 if big else jnp.float32

    params = _cast_tree(MR.param_specs(cfg, ep=ctx.tp_size), pdtype)
    if quant:
        from repro.models.quantize import quantize_params_rtn
        params = jax.eval_shape(
            lambda p: quantize_params_rtn(p, cfg), params)
    p_sh = param_shardings(ctx, params, cfg)

    if shape.kind == "train":
        opt_cfg = AdamWConfig(
            moment_dtype="bfloat16" if big else "float32")
        opt = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), params)
        o_sh = type(opt)(step=jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec()),
            mu=param_shardings(ctx, opt.mu, cfg),
            nu=param_shardings(ctx, opt.nu, cfg))
        batch = MR.input_specs(cfg, shape)
        b_sh = batch_shardings(ctx, batch)
        step = make_train_step(cfg, opt_cfg, ctx, rt,
                               num_microbatches=num_microbatches)
        fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))
        return fn, (params, opt, batch)

    if shape.kind == "prefill":
        if chunk_tokens and T.supports_chunked_prefill(cfg):
            from repro.core.kv_quant import cache_from_state
            state = MR.decode_state_specs(cfg, shape)
            s_sh = state_shardings(ctx, state, cfg)
            cache = cache_from_state(state)
            c_sh = cache_from_state(s_sh)     # pool shardings ride along
            batch = MR.chunk_prefill_input_specs(cfg, shape, chunk_tokens)
            step = MR.make_chunk_prefill_step(cfg, ctx, rt)
            fn = jax.jit(step, in_shardings=(p_sh, c_sh, None),
                         out_shardings=(None, c_sh), donate_argnums=(1,))
            return fn, (params, cache, batch)
        batch = MR.input_specs(cfg, shape)
        b_sh = batch_shardings(ctx, batch)
        if cfg.is_encoder:
            step = MR.make_forward_step(cfg, ctx, rt)
            fn = jax.jit(step, in_shardings=(p_sh, b_sh))
            return fn, (params, batch)
        state = MR.decode_state_specs(cfg, shape)
        s_sh = state_shardings(ctx, state, cfg)
        step = MR.make_prefill_step(cfg, ctx, rt)
        fn = jax.jit(step, in_shardings=(p_sh, s_sh, b_sh),
                     out_shardings=(None, s_sh), donate_argnums=(1,))
        return fn, (params, state, batch)

    # decode
    if chunk_tokens and T.supports_chunked_prefill(cfg):
        # unified single-dispatch serving step: the decode cell carries
        # the step's prefill chunk and the fused sampling too — the
        # sharding/memory proof of the one-dispatch mixed iteration
        batch = MR.unified_step_input_specs(cfg, shape, chunk_tokens)
        state = batch.pop("state")
        s_sh = state_shardings(ctx, state, cfg)
        step = MR.make_unified_step(cfg, ctx, rt)
        fn = jax.jit(step, in_shardings=(p_sh, s_sh, None),
                     out_shardings=(None, s_sh), donate_argnums=(1,))
        return fn, (params, state, batch)
    spec = MR.input_specs(cfg, shape)
    state, tokens = spec["state"], spec["tokens"]
    s_sh = state_shardings(ctx, state, cfg)
    t_sh = batch_shardings(ctx, tokens)
    step = MR.make_decode_step(cfg, ctx, rt)
    fn = jax.jit(step, in_shardings=(p_sh, s_sh, t_sh),
                 out_shardings=(None, s_sh), donate_argnums=(1,))
    return fn, (params, state, tokens)


HBM_PER_DEVICE = 16 * 2**30                  # TPU v5e


def _auto_microbatches(cfg: ModelConfig, shape: ShapeConfig, dp: int) -> int:
    """Initial microbatch guess for the train memory proof: ~8k tokens
    per device per microbatch (refined by the fit loop in run_cell)."""
    tokens_local = shape.global_batch * shape.seq_len // dp
    nm = max(1, tokens_local // 8192)
    while shape.global_batch % nm or (shape.global_batch // nm) % dp:
        nm //= 2
    return max(1, nm)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             quant: bool = False, skip_cost: bool = False,
             rt_extra: Optional[dict] = None,
             num_microbatches: Optional[int] = None,
             policy: str = "2d", cache_dtype: Optional[str] = None,
             chunk_tokens: Optional[int] = None
             ) -> Dict[str, Any]:
    cfg = get_config(arch)
    if cache_dtype:
        cfg = cfg.replace(paging=cfg.paging.__class__(
            **{**cfg.paging.__dict__, "cache_dtype": cache_dtype}))
    shape = next(s for s in ALL_SHAPES if s.name == shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = make_ctx(mesh, policy)
    chips = mesh.size
    res: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "quant": quant, "policy": policy,
    }
    t0 = time.time()
    # 1. full-config compile: sharding + memory proof (auto-microbatched
    #    until the step fits HBM, up to 3 doublings)
    nm = num_microbatches if num_microbatches is not None else (
        _auto_microbatches(cfg, shape, ctx.dp_size)
        if shape.kind == "train" else 1)
    for _attempt in range(4):
        # decode steps are unrolled even for the full compile: the graphs
        # are small, and scan-carried pools trip an XLA-CPU-SPMD carry
        # resharding (spurious pool all-gathers) that the unrolled form
        # (and the TPU runtime schedule) does not have.
        fn, specs = build_cell(cfg, shape, mesh,
                               scan_layers=(shape.kind != "decode"),
                               quant=quant, num_microbatches=nm,
                               rt_extra=rt_extra, policy=policy,
                               chunk_tokens=chunk_tokens)
        compiled = fn.lower(*specs).compile()
        try:
            ma = compiled.memory_analysis()
            peak = int(ma.argument_size_in_bytes + ma.temp_size_in_bytes)
        except Exception:
            ma, peak = None, 0
        if (shape.kind != "train" or peak <= HBM_PER_DEVICE
                or nm * 2 * ctx.dp_size > shape.global_batch
                or num_microbatches is not None):
            break
        nm *= 2
    res["num_microbatches"] = nm
    res["compile_s"] = round(time.time() - t0, 1)
    if ma is not None:
        res["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "peak_bytes": peak,
            "bytes_per_device_gib": round(peak / 2**30, 3),
            "fits_hbm": bool(peak <= HBM_PER_DEVICE),
        }
    else:
        res["memory"] = {"error": "memory_analysis unavailable"}

    # full-compile collective schedule (scan body counted once — recorded
    # for the schedule shape, not the totals)
    res["coll_schedule_scanbody"] = RF.collective_bytes(compiled.as_text())

    if not skip_cost:
        # 2. per-layer cost: two small unrolled lowers, with and without the
        #    mixer core (launch/roofline.py docstring), nm=1 for true totals
        P = _pattern_period(cfg)
        l_a = P + cfg.num_layers % P
        l_b = l_a + P
        terms = {}
        for skip in (False, True):
            tt = {}
            for tag, L in (("a", l_a), ("b", l_b)):
                f2, sp2 = build_cell(cfg, shape, mesh, scan_layers=False,
                                     num_layers=L, quant=quant,
                                     skip_mixer_core=skip, rt_extra=rt_extra,
                                     policy=policy, chunk_tokens=chunk_tokens)
                tt[tag] = RF.terms_from_compiled(f2.lower(*sp2).compile())
            terms[skip] = RF.extrapolate(tt["a"], tt["b"], l_a, l_b,
                                         cfg.num_layers)
        mixer = RF.mixer_terms(cfg, shape, chips, dp_size=ctx.dp_size)
        adj = RF.combine(terms[True], mixer)
        res["roofline_xla_ref"] = terms[False].as_dict()
        res["roofline"] = adj.as_dict()
        res["roofline"]["mixer_flops"] = mixer.flops
        res["roofline"]["mixer_hbm_bytes"] = mixer.hbm_bytes
        mf = RF.model_flops_per_step(cfg, shape, chips)
        for key in ("roofline", "roofline_xla_ref"):
            t = adj if key == "roofline" else terms[False]
            res[key]["model_flops_per_chip"] = mf
            res[key]["useful_flop_frac"] = mf / t.flops if t.flops else None
            res[key]["roofline_frac"] = (
                (mf / RF.PEAK_FLOPS_BF16) / t.t_bound if t.t_bound else None)
    res["total_s"] = round(time.time() - t0, 1)
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--quant", action="store_true",
                    help="int4 GPTQ weights (the Opt-GPTQ configuration)")
    ap.add_argument("--policy", default="2d", choices=["2d", "dp_only"])
    ap.add_argument("--cache-dtype", default=None,
                    help="e.g. float8_e4m3fn for the fp8 KV-cache variant")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill (vLLM-style) token budget")
    ap.add_argument("--max-num-batched-tokens", type=int, default=0,
                    help="lower prefill cells as the serving engine's "
                         "fixed-shape [1, W] chunk executable (W = this "
                         "budget) instead of the whole-prompt form")
    ap.add_argument("--suffix", default="")
    ap.add_argument("--skip-cost", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    cells = []
    if args.all:
        for arch, cfg in ARCHS.items():
            for s, status in shapes_for(cfg):
                cells.append((arch, s.name, status))
    elif args.arch and not args.shape:      # all shapes of one arch
        for s, status in shapes_for(get_config(args.arch)):
            cells.append((args.arch, s.name, status))
    else:
        cells.append((args.arch, args.shape, "run"))

    n_ok = n_skip = n_fail = 0
    for arch, shape_name, status in cells:
        for mp in meshes:
            tag = f"{arch}__{shape_name}__{'multi' if mp else 'single'}" \
                + ("__q4" if args.quant else "") \
                + (f"__{args.policy}" if args.policy != "2d" else "") \
                + (f"__kv8" if args.cache_dtype else "") \
                + ("__chunk" if args.max_num_batched_tokens else "") \
                + args.suffix
            out_path = os.path.join(args.out, tag + ".json")
            if status != "run":
                json.dump({"arch": arch, "shape": shape_name,
                           "status": status}, open(out_path, "w"), indent=1)
                print(f"[skip] {tag}: {status}")
                n_skip += 1
                continue
            try:
                rt_extra = ({"prefill_chunk": args.prefill_chunk}
                            if args.prefill_chunk else None)
                res = run_cell(arch, shape_name, mp, quant=args.quant,
                               skip_cost=args.skip_cost, policy=args.policy,
                               cache_dtype=args.cache_dtype,
                               rt_extra=rt_extra,
                               chunk_tokens=args.max_num_batched_tokens
                               or None)
                res["status"] = "ok"
                json.dump(res, open(out_path, "w"), indent=1)
                rf = res.get("roofline", {})
                print(f"[ok]   {tag}: compile={res['compile_s']}s "
                      f"mem/dev={res['memory'].get('bytes_per_device_gib')}GiB "
                      f"bottleneck={rf.get('bottleneck')} "
                      f"roofline_frac={rf.get('roofline_frac')}")
                n_ok += 1
            except Exception as e:
                n_fail += 1
                json.dump({"arch": arch, "shape": shape_name,
                           "status": "fail", "error": repr(e),
                           "trace": traceback.format_exc()},
                          open(out_path, "w"), indent=1)
                print(f"[FAIL] {tag}: {e!r}")
    print(f"done: {n_ok} ok, {n_skip} skip, {n_fail} fail")


if __name__ == "__main__":
    main()
