"""Serving driver: continuous batching over the paged engine via ``LLM``.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
        --requests 16 [--quant gptq-int4] [--stream] [--top-k 40] \
        [--top-p 0.95] [--temperature 0.8] [--stop 13 198] [--mha-baseline]

``--mha-baseline`` serves the same arch with kv_heads == num_heads and
prefix reuse off — the paper's comparison point (Fig. 2). ``--stream``
prints each ``RequestOutput`` delta as horizons complete instead of
waiting for the batch to drain.

Robustness knobs (see docs/API.md "Fault tolerance"): ``--max-waiting N``
bounds the intake queue with ``--shed-policy {reject,shed-oldest}``
deciding what happens when it is full (``reject`` raises
``EngineOverloadedError`` at submit — with this driver's submit-all-
upfront pattern that aborts the run, which is the point of the policy;
``shed-oldest`` finishes the oldest waiting request with
``finish_reason='shed'``), and ``--deadline-ms`` attaches an end-to-end
deadline to every request (``finish_reason='deadline'`` on expiry).

Observability knobs (see docs/OBSERVABILITY.md): ``--metrics-port N``
serves ``/metrics`` (Prometheus), ``/health`` (JSON) and ``/trace``
(Chrome trace JSON) on localhost while the run executes;
``--trace-out f.json`` writes the span timeline at exit (open in
Perfetto); ``--metrics-out f.json`` dumps the registry snapshot;
``--profile-dir d/`` wraps the run in a ``jax.profiler`` capture with
per-dispatch TraceAnnotation labels; ``--no-enable-telemetry`` turns
the span tracer off (the metrics registry is always on).
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs.base import PagingConfig
from repro.serving import LLM, SamplingParams


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="serve the tiny same-family CPU config "
                         "(--no-reduced loads the full-size one)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-tokens", "--max-new", dest="max_tokens",
                    type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--blocks", type=int, default=256)
    ap.add_argument("--quant", default=None,
                    choices=["rtn-int4", "gptq-int4"],
                    help="serve int4 weights (Opt-GPTQ configuration): "
                         "RTN or Hessian-based GPTQ")
    ap.add_argument("--kv-cache-dtype", default="bf16",
                    choices=["bf16", "int8"],
                    help="paged KV pool format: int8 quantizes K/V on "
                         "write (per-block-per-head scales, ~2x lower KV "
                         "bytes/token vs bf16)")
    ap.add_argument("--checkpoint", default=None,
                    help="Checkpointer directory to restore params from")
    ap.add_argument("--max-num-batched-tokens", type=int, default=256,
                    help="per-step token budget: running decodes are "
                         "packed first, prefill chunks fill the rest "
                         "(bounds inter-token latency at O(chunk))")
    ap.add_argument("--enable-chunked-prefill",
                    action=argparse.BooleanOptionalAction, default=True,
                    help="--no-enable-chunked-prefill restores the "
                         "stop-the-world whole-prompt prefill (the "
                         "parity oracle; also the path non-full-"
                         "attention archs always use)")
    ap.add_argument("--enable-unified-step",
                    action=argparse.BooleanOptionalAction, default=True,
                    help="--no-enable-unified-step restores the two-call "
                         "mixed step (separate decode / prefill-chunk / "
                         "sample dispatches) — the unified single-"
                         "dispatch step's parity oracle")
    ap.add_argument("--enable-async-step",
                    action=argparse.BooleanOptionalAction, default=True,
                    help="--no-enable-async-step restores the read-back-"
                         "every-step loop — the async pipelined step "
                         "(plan/enqueue N+1 while N executes, tokens "
                         "read back one step late) is on by default in "
                         "unified mode")
    ap.add_argument("--max-waiting", type=int, default=None,
                    help="bound the waiting queue; arrivals past the "
                         "bound are handled per --shed-policy")
    ap.add_argument("--shed-policy", default="reject",
                    choices=["reject", "shed-oldest"],
                    help="full-queue policy: 'reject' refuses the new "
                         "request (EngineOverloadedError), 'shed-oldest' "
                         "finishes the oldest waiting request with "
                         "finish_reason='shed' to make room")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request end-to-end deadline from arrival; "
                         "expired requests finish with "
                         "finish_reason='deadline'")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--stop", type=int, nargs="*", default=[],
                    help="stop token ids (finish_reason='stop')")
    ap.add_argument("--stream", action="store_true",
                    help="print RequestOutput deltas as they arrive")
    ap.add_argument("--mha-baseline", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--enable-telemetry",
                    action=argparse.BooleanOptionalAction, default=True,
                    help="--no-enable-telemetry disables the span tracer "
                         "(zero-work no-op); counters/histograms stay on")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve /metrics (Prometheus), /health (JSON) and "
                         "/trace (Chrome JSON) on 127.0.0.1:PORT for the "
                         "duration of the run")
    ap.add_argument("--trace-out", default=None,
                    help="write the span timeline as Chrome-trace JSON "
                         "at exit (load in Perfetto / about:tracing)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the metrics-registry JSON snapshot at exit")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace of the run into "
                         "this directory (adds TraceAnnotation labels to "
                         "every device dispatch)")
    args = ap.parse_args()

    overrides = {}
    if args.mha_baseline:
        from repro.configs.registry import get_config, get_reduced
        base = get_reduced(args.arch) if args.reduced else \
            get_config(args.arch)
        overrides = dict(num_kv_heads=base.num_heads,
                         paging=PagingConfig(enable_prefix_reuse=False))
    llm = LLM.load(args.arch, quant=args.quant,
                   kv_cache_dtype=args.kv_cache_dtype,
                   checkpoint=args.checkpoint,
                   reduced=args.reduced, overrides=overrides,
                   seed=args.seed, max_slots=args.slots,
                   num_blocks=args.blocks, max_blocks_per_seq=16,
                   max_num_batched_tokens=args.max_num_batched_tokens,
                   enable_chunked_prefill=args.enable_chunked_prefill,
                   enable_unified_step=args.enable_unified_step,
                   enable_async_step=args.enable_async_step,
                   max_waiting=args.max_waiting,
                   shed_policy=args.shed_policy,
                   prefill_bucket=32,
                   enable_telemetry=args.enable_telemetry,
                   profile_labels=bool(args.profile_dir))

    server = None
    if args.metrics_port is not None:
        from repro.obs.http import start_obs_server
        server = start_obs_server(args.metrics_port,
                                  registry=llm.engine.obs,
                                  health_fn=llm.engine.health,
                                  tracer=llm.engine.tracer)
        print(f"# obs endpoint on http://127.0.0.1:"
              f"{server.server_address[1]} (/metrics /health /trace)")
    if args.profile_dir:
        import jax
        jax.profiler.start_trace(args.profile_dir)

    rng = np.random.default_rng(args.seed)
    prefix = list(rng.integers(1, 200, 24))
    prompts = [prefix + list(rng.integers(1, 200, int(rng.integers(4, 32))))
               for _ in range(args.requests)]
    sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                        top_p=args.top_p, stop=list(args.stop),
                        max_tokens=args.max_tokens,
                        deadline_ms=args.deadline_ms)

    try:
        if args.stream:
            for out in llm.stream(prompts, sp):
                print(json.dumps({
                    "rid": out.request_id, "new": out.new_token_ids,
                    "n_total": len(out.token_ids),
                    "finish_reason": out.finish_reason}))
        else:
            outs = llm.generate(prompts, sp)
            for out in outs:
                print(json.dumps({"rid": out.request_id,
                                  "tokens": out.token_ids,
                                  "finish_reason": out.finish_reason}))
        if args.profile_dir:
            import jax
            jax.profiler.stop_trace()
        if args.trace_out:
            llm.engine.tracer.save(args.trace_out)
        if args.metrics_out:
            with open(args.metrics_out, "w") as f:
                json.dump(llm.engine.obs.snapshot(), f, indent=1)
        attr = llm.engine.attribution()
        if attr["steps"]:
            print(json.dumps({"attribution": {k: round(float(v), 4)
                                              for k, v in attr.items()}}))
    finally:
        # flush the async pipeline + detok worker, stop the obs server
        # thread — even when the run aborts (EngineOverloadedError under
        # --shed-policy reject, Ctrl-C, a poisoned run), nothing leaks
        llm.close()
        if server is not None:
            server.shutdown()
    rep = llm.engine.report()
    mode = ("mha" if args.mha_baseline else "opt-gqa") + \
        (f"+{args.quant}" if args.quant else "") + \
        (f"+kv-{args.kv_cache_dtype}" if args.kv_cache_dtype != "bf16"
         else "")
    print(json.dumps({"mode": mode, **{k: round(float(v), 4)
                                       for k, v in rep.items()}}, indent=1))


if __name__ == "__main__":
    main()
