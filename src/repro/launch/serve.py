"""Serving driver: continuous batching over the paged engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
        --requests 16 [--quant] [--mha-baseline]

``--mha-baseline`` serves the same arch with kv_heads == num_heads and
prefix reuse off — the paper's comparison point (Fig. 2).
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs.base import PagingConfig, QuantConfig
from repro.configs.registry import get_config, get_reduced
from repro.models import transformer as T
from repro.serving.engine import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--blocks", type=int, default=256)
    ap.add_argument("--quant", action="store_true",
                    help="serve int4 GPTQ weights (Opt-GPTQ configuration)")
    ap.add_argument("--mha-baseline", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.mha_baseline:
        cfg = cfg.replace(num_kv_heads=cfg.num_heads,
                          paging=PagingConfig(enable_prefix_reuse=False))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    if args.quant:
        from repro.models.quantize import quantize_params_rtn
        params = quantize_params_rtn(params, cfg, group_size=32)

    eng = ServingEngine(cfg, params, max_slots=args.slots,
                        num_blocks=args.blocks, max_blocks_per_seq=16,
                        prefill_bucket=32, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    prefix = list(rng.integers(1, 200, 24))
    for i in range(args.requests):
        eng.add_request(Request(
            rid=i,
            prompt=prefix + list(rng.integers(1, 200,
                                              int(rng.integers(4, 32)))),
            max_new_tokens=args.max_new))
    rep = eng.run_until_done()
    mode = ("mha" if args.mha_baseline else "opt-gqa") + \
        ("+int4" if args.quant else "")
    print(json.dumps({"mode": mode, **{k: round(float(v), 4)
                                       for k, v in rep.items()}}, indent=1))


if __name__ == "__main__":
    main()
