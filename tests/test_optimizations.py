"""Beyond-paper optimization levers: chunked prefill, fp8 KV cache,
dp_only policy, int8-EF gradient compression math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_reduced
from repro.models import transformer as T
from repro.models.registry import decode_geometry

KEY = jax.random.PRNGKey(0)


def _setup(cfg, B=2, S=24):
    params = T.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    g = decode_geometry(cfg, ShapeConfig("t", 64, B, "decode"))
    st = T.make_decode_state(cfg, B, g["num_blocks"],
                             g["max_blocks_per_seq"], dtype=jnp.float32)
    if "block_table" in st:
        st["block_table"] = jnp.arange(
            B * g["max_blocks_per_seq"], dtype=jnp.int32).reshape(B, -1)
    return params, toks, st


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "qwen2-moe-a2.7b"])
def test_chunked_prefill_matches_regular(arch):
    cfg = get_reduced(arch)
    params, toks, st = _setup(cfg)
    cl = jnp.array([24, 17], jnp.int32)
    b = {"tokens": toks, "ctx_lens": cl}
    l1, s1 = T.prefill(cfg, params, dict(st), b, rt={"scan_layers": True})
    l2, s2 = T.prefill(cfg, params, dict(st), b,
                       rt={"scan_layers": True, "prefill_chunk": 8})
    np.testing.assert_allclose(l1, l2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(s1["k_pool"]),
                               np.asarray(s2["k_pool"]), atol=2e-2)


def test_fp8_kv_cache_decode_close():
    cfg = get_reduced("qwen2-1.5b")
    cfg8 = cfg.replace(paging=cfg.paging.__class__(
        **{**cfg.paging.__dict__, "cache_dtype": "float8_e4m3fn"}))
    params = T.init_params(cfg, KEY)
    B, S = 2, 20
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full = T.forward(cfg, params, {"tokens": toks})
    g = decode_geometry(cfg8, ShapeConfig("t", 40, B, "decode"))
    st = T.make_decode_state(cfg8, B, g["num_blocks"], g["max_blocks_per_seq"])
    assert st["k_pool"].dtype == jnp.float8_e4m3fn
    st["block_table"] = jnp.arange(B * g["max_blocks_per_seq"],
                                   dtype=jnp.int32).reshape(B, -1)
    cl = jnp.array([15, 15], jnp.int32)
    lg, st = T.prefill(cfg8, params, st, {"tokens": toks[:, :15],
                                          "ctx_lens": cl})
    st["seq_lens"] = cl + 1
    lg2, _ = T.decode_step(cfg8, params, st, toks[:, 15])
    scale = float(jnp.abs(full).max())
    assert float(jnp.abs(lg2 - full[:, 15]).max()) < 0.15 * max(scale, 1.0)


def test_dp_only_policy_matches_2d():
    """Same math under both parallelism policies (8 virtual... 1 device)."""
    from repro.runtime.sharding import make_ctx
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_reduced("qwen2-1.5b", num_layers=2)
    params = T.init_params(cfg, KEY)
    b = {"tokens": jax.random.randint(KEY, (2, 17), 0, cfg.vocab_size)}
    l2d = T.loss_fn(cfg, params, b, make_ctx(mesh, "2d"))
    ldp = T.loss_fn(cfg, params, b, make_ctx(mesh, "dp_only"))
    np.testing.assert_allclose(float(l2d), float(ldp), rtol=1e-5)


def test_int8_ef_quantize_dequantize_cycle():
    """One-device check of the compression arithmetic: q/dq error is
    bounded by scale, and error feedback removes bias over steps."""
    rng = np.random.default_rng(0)
    g = rng.normal(size=512).astype(np.float32) * 0.01
    err = np.zeros_like(g)
    acc = np.zeros_like(g)
    acc_exact = np.zeros_like(g)
    for step in range(50):
        gs = g * (1 + 0.1 * rng.normal(size=g.shape).astype(np.float32))
        x = gs + err
        scale = np.abs(x).max() / 127.0 + 1e-20
        q = np.clip(np.round(x / scale), -127, 127)
        deq = q * scale
        err = x - deq
        acc += deq
        acc_exact += gs
    # with EF, accumulated compressed grads track accumulated exact grads
    rel = np.linalg.norm(acc - acc_exact) / np.linalg.norm(acc_exact)
    assert rel < 0.01
