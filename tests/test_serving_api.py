"""vLLM-style serving API: per-request SamplingParams through both decode
paths (bitwise), stop-token semantics, streaming, the LLM facade and the
deprecation shim."""
import jax
import numpy as np
import pytest

from repro.configs.registry import get_reduced
from repro.models import transformer as T
from repro.serving import (LLM, Request, RequestOutput, SamplingParams,
                           ServingEngine)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def small():
    cfg = get_reduced("qwen1.5-0.5b", num_layers=2)
    params = T.init_params(cfg, KEY)
    return cfg, params


def _prompts(n, seed=0, lo=4, hi=20):
    rng = np.random.default_rng(seed)
    return [list(rng.integers(1, 200, int(rng.integers(lo, hi))))
            for _ in range(n)]


def _engine(cfg, params, **kw):
    kw.setdefault("max_slots", 3)
    kw.setdefault("num_blocks", 64)
    kw.setdefault("max_blocks_per_seq", 8)
    kw.setdefault("prefill_bucket", 16)
    return ServingEngine(cfg, params, **kw)


def _drain(eng, prompts, sps):
    for p, sp in zip(prompts, sps):
        eng.add(p, sp)
    eng.run_until_done()
    return {r.rid: list(r.output) for r in eng.finished}, \
        {r.rid: r.finish_reason for r in eng.finished}


# ---------------------------------------------------- heterogeneous parity

def test_heterogeneous_params_fused_matches_legacy(small):
    """Acceptance: one batch mixing greedy / temperature / top-k / top-p /
    seeded requests decodes bitwise-identically through the fused megastep
    and the legacy per-token loop."""
    cfg, params = small
    prompts = _prompts(6, seed=5)
    sps = [SamplingParams(max_tokens=10),
           SamplingParams(temperature=0.9, max_tokens=10),
           SamplingParams(temperature=0.8, top_k=5, max_tokens=10),
           SamplingParams(temperature=1.1, top_p=0.8, max_tokens=10),
           SamplingParams(temperature=0.7, top_k=12, top_p=0.95, seed=42,
                          max_tokens=10),
           SamplingParams(max_tokens=10)]
    o_leg, _ = _drain(_engine(cfg, params, use_fused=False), prompts, sps)
    o_fus, fr = _drain(_engine(cfg, params, use_fused=True), prompts, sps)
    assert len(o_leg) == len(o_fus) == 6
    assert o_leg == o_fus
    assert all(r == "length" for r in fr.values())


def test_seeded_request_reproduces_across_batch_compositions(small):
    """A request's sampling stream is keyed per slot by (seed, position),
    so its tokens do not depend on who shares the batch."""
    cfg, params = small
    prompts = _prompts(3, seed=9)
    sp = SamplingParams(temperature=0.8, top_k=20, seed=123, max_tokens=8)
    fillers = [SamplingParams(temperature=1.3, max_tokens=8)] * 2
    batched, _ = _drain(_engine(cfg, params), prompts, [sp] + fillers)
    solo, _ = _drain(_engine(cfg, params, max_slots=1), prompts[:1], [sp])
    assert solo[0] == batched[0]


# ---------------------------------------------------- stop-token semantics

def test_stop_token_finishes_and_releases_blocks_immediately(small):
    """A stop token ends the request with finish_reason='stop' (tokens past
    it are discarded) and its KV blocks return to the pool in the same
    engine step, while other sequences keep running."""
    cfg, params = small
    probe, _ = _drain(_engine(cfg, params), _prompts(1, seed=3),
                      [SamplingParams(max_tokens=12)])
    greedy = probe[0]
    stop_tok = greedy[4]
    idx = greedy.index(stop_tok)            # first occurrence wins

    eng = _engine(cfg, params, max_slots=2, max_horizon=4)
    eng.add(_prompts(1, seed=3)[0], SamplingParams(max_tokens=12,
                                                   stop=[stop_tok]))
    other_prompt = list(np.random.default_rng(8).integers(1, 200, 10))
    eng.add(other_prompt, SamplingParams(max_tokens=40))
    total = eng.alloc.num_blocks
    for _ in range(100):
        eng.step()
        if eng.finished and eng.finished[0].finish_reason == "stop":
            break
    assert eng.finished[0].finish_reason == "stop"
    assert list(eng.finished[0].output) == greedy[:idx + 1]
    # the stopped request's blocks are free again; only the still-running
    # sequence holds pool blocks
    assert len(eng.running) == 1
    (live,) = eng.running.values()
    assert eng.alloc.num_free == total - len(live.block_ids)
    eng.run_until_done()
    assert eng.finished[-1].finish_reason in ("length", "capacity")


def test_stop_midhorizon_parity_fused_vs_legacy(small):
    cfg, params = small
    probe, _ = _drain(_engine(cfg, params), _prompts(2, seed=4),
                      [SamplingParams(max_tokens=12)] * 2)
    stop = [probe[0][3], probe[1][5]]
    sps = [SamplingParams(max_tokens=12, stop=[stop[0]]),
           SamplingParams(max_tokens=12, stop=[stop[1]])]
    o_leg, f_leg = _drain(_engine(cfg, params, use_fused=False),
                          _prompts(2, seed=4), sps)
    o_fus, f_fus = _drain(_engine(cfg, params, use_fused=True, max_horizon=8),
                          _prompts(2, seed=4), sps)
    assert o_leg == o_fus and f_leg == f_fus
    assert set(f_fus.values()) == {"stop"}


# ---------------------------------------------------- streaming intake

def test_stream_yields_first_output_before_batch_finishes(small):
    cfg, params = small
    eng = _engine(cfg, params, max_slots=2, max_horizon=4)
    for p in _prompts(4, seed=6):
        eng.add(p, SamplingParams(max_tokens=16))
    first_event_had_work_left = None
    events = []
    for out in eng.stream():
        if first_event_had_work_left is None:
            first_event_had_work_left = eng.scheduler.has_work()
        events.append(out)
    assert first_event_had_work_left is True
    assert all(isinstance(e, RequestOutput) for e in events)
    fin = [e for e in events if e.finished]
    assert len(fin) == 4
    # deltas reassemble exactly into the cumulative outputs
    for rid in range(4):
        deltas = sum((e.new_token_ids for e in events
                      if e.request_id == rid), [])
        assert deltas == next(e.token_ids for e in reversed(events)
                              if e.request_id == rid)


def test_add_request_while_streaming(small):
    cfg, params = small
    eng = _engine(cfg, params, max_slots=2)
    prompts = _prompts(5, seed=7)
    eng.add(prompts[0], SamplingParams(max_tokens=8))
    pending = prompts[1:]
    for _out in eng.stream():
        if pending:                          # continuous intake mid-stream
            eng.add(pending.pop(0), SamplingParams(max_tokens=8))
    assert len(eng.finished) == 5
    assert all(len(r.output) == 8 for r in eng.finished)


def test_filter_keeps_all_tokens_when_top_p_disabled():
    """A top_p=1.0 (disabled) row must keep its whole top-k set even when
    the filter runs because another slot requested filtering — f32 cumsum
    rounds tail prior-mass to exactly 1.0 on peaked rows, and truncating
    there would make the row's sample depend on batch composition."""
    import jax.numpy as jnp
    from repro.core.sampling import _filter_top_k_top_p
    peaked = np.zeros((1, 64), np.float32)
    peaked[0, 7] = 50.0                     # softmax mass ~1.0 at token 7
    out = _filter_top_k_top_p(jnp.asarray(peaked / 0.05),
                              jnp.asarray([0], jnp.int32),
                              jnp.asarray([1.0], jnp.float32))
    assert bool(jnp.isfinite(out).all())    # nothing masked
    # a genuinely filtering row still truncates
    out2 = _filter_top_k_top_p(jnp.asarray(peaked / 0.05),
                               jnp.asarray([0], jnp.int32),
                               jnp.asarray([0.9], jnp.float32))
    assert not bool(jnp.isfinite(out2).all())


def test_detokenizer_fills_text_incrementally(small):
    cfg, params = small
    det = lambda toks: "".join(chr(65 + t % 26) for t in toks)  # noqa: E731
    eng = _engine(cfg, params, detokenizer=det, max_slots=2, max_horizon=4)
    eng.add(_prompts(1, seed=15)[0], SamplingParams(max_tokens=10))
    events = list(eng.stream())
    final = [e for e in events if e.finished][0]
    assert final.text == det(final.token_ids)   # delta-accumulated == full
    assert "".join(e.new_text for e in events) == final.text


# ---------------------------------------------------- finish reasons

def test_capacity_finish_reason(small):
    cfg, params = small
    eng = _engine(cfg, params, max_slots=2, num_blocks=8,
                  max_blocks_per_seq=2, prefill_bucket=32)
    eng.add(list(range(1, 18)), SamplingParams(max_tokens=48))
    eng.run_until_done()
    assert eng.finished[0].finish_reason == "capacity"
    assert 0 < len(eng.finished[0].output) < 48


# ---------------------------------------------------- deprecation shim

def test_legacy_request_shim_drains_and_matches_new_api(small):
    cfg, params = small
    prompts = _prompts(4, seed=11)
    eng_new = _engine(cfg, params)
    new_out, _ = _drain(eng_new, prompts,
                        [SamplingParams(max_tokens=6)] * 4)
    eng_old = _engine(cfg, params)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    with pytest.warns(DeprecationWarning):
        for r in reqs:
            eng_old.add_request(r)
    eng_old.run_until_done()
    assert len(eng_old.finished) == 4
    # the shim shares the output list with the caller's Request objects
    assert {r.rid: r.output for r in reqs} == new_out
    # ... and mirrors the timestamps the old engine used to set
    for r in reqs:
        assert r.first_token_t is not None and r.done_t is not None
        assert r.done_t - r.arrival >= 0


# ---------------------------------------------------- LLM facade

def test_llm_load_generate_rtn_and_stop(small):
    llm = LLM.load("qwen1.5-0.5b", quant="rtn-int4", reduced=True,
                   overrides=dict(num_layers=2), max_slots=3,
                   num_blocks=64, max_blocks_per_seq=8, prefill_bucket=16)
    prompts = _prompts(3, seed=2)
    [probe] = llm.generate([prompts[0]], SamplingParams(max_tokens=10))
    assert probe.finished and probe.finish_reason == "length"
    stop_tok = probe.token_ids[2]
    outs = llm.generate(prompts,
                        [SamplingParams(max_tokens=10, stop=[stop_tok]),
                         SamplingParams(max_tokens=10, top_k=40,
                                        temperature=0.9),
                         SamplingParams(max_tokens=10)])
    assert [o.request_id for o in outs] == sorted(o.request_id for o in outs)
    assert outs[0].finish_reason == "stop"
    assert outs[0].token_ids == probe.token_ids[
        :probe.token_ids.index(stop_tok) + 1]
    assert all(o.finished for o in outs)


def test_llm_load_gptq_int4_end_to_end():
    llm = LLM.load("qwen2-1.5b", quant="gptq-int4", reduced=True,
                   overrides=dict(num_layers=2), max_slots=2,
                   num_blocks=64, max_blocks_per_seq=8, prefill_bucket=16)
    outs = llm.generate(_prompts(2, seed=1),
                        SamplingParams(top_k=40, max_tokens=6))
    assert all(o.finished and len(o.token_ids) == 6 for o in outs)


def test_llm_load_checkpoint_restores_params(small, tmp_path):
    from repro.checkpoint.checkpointer import Checkpointer
    cfg, params = small
    Checkpointer(str(tmp_path)).save(3, {"params": params})
    llm = LLM.load("qwen1.5-0.5b", checkpoint=str(tmp_path), reduced=True,
                   overrides=dict(num_layers=2), max_slots=2,
                   num_blocks=64, max_blocks_per_seq=8, prefill_bucket=16)
    prompts = _prompts(2, seed=13)
    outs = llm.generate(prompts, SamplingParams(max_tokens=6))
    ref, _ = _drain(_engine(cfg, params, max_slots=2), prompts,
                    [SamplingParams(max_tokens=6)] * 2)
    assert {o.request_id: o.token_ids for o in outs} == ref


def test_llm_load_rejects_unknown_quant():
    with pytest.raises(ValueError, match="quant"):
        LLM.load("qwen1.5-0.5b", quant="int3", reduced=True)


def test_llm_load_gptq_rejects_non_dense():
    with pytest.raises(ValueError, match="rtn-int4"):
        LLM.load("falcon-mamba-7b", quant="gptq-int4", reduced=True)
