"""Host-side Scheduler unit tests: admission, watermark, clamping,
horizon planning, preemption and capacity — no model, no device arrays."""
import numpy as np
import pytest

from repro.core.paged_cache import BlockAllocator
from repro.serving.params import SamplingParams
from repro.serving.scheduler import RequestState, Scheduler, Sequence

BS = 4


def _sched(num_blocks=32, max_slots=3, mb=4, **kw):
    alloc = BlockAllocator(num_blocks, BS, watermark_frac=0.0)
    return Scheduler(alloc, max_slots=max_slots, max_blocks_per_seq=mb, **kw)


def _req(rid, n_prompt, max_tokens=8, arrival=None):
    r = RequestState(rid=rid, prompt=list(range(1, n_prompt + 1)),
                     sampling=SamplingParams(max_tokens=max_tokens))
    r.arrival = float(rid + 1 if arrival is None else arrival)
    r.prompt_len0 = n_prompt
    return r


def test_admission_fifo_and_slot_bound():
    s = _sched(max_slots=2)
    for i in range(4):
        s.add(_req(i, 6))
    admitted = s.try_admit()
    assert [q.req.rid for q in admitted] == [0, 1]   # FIFO, 2 slots
    assert len(s.waiting) == 2 and len(s.running) == 2
    assert all(q.seq_len == 6 and q.last_token == 6 for q in admitted)


def test_admission_watermark_blocks():
    # 4 blocks total; each 6-token prompt wants ceil(6/4)+1 = 3 blocks
    alloc = BlockAllocator(4, BS, watermark_frac=0.5)   # watermark = 2
    s = Scheduler(alloc, max_slots=4, max_blocks_per_seq=4)
    s.add(_req(0, 6))
    s.add(_req(1, 6))
    admitted = s.try_admit()
    assert admitted == []                    # 3 needed > 4 free - 2 watermark
    assert len(s.waiting) == 2


def test_overlong_prompt_clamped_at_admission():
    s = _sched(mb=2)                         # cap = 2 * 4 = 8 tokens
    s.add(_req(0, 20))
    [q] = s.try_admit()
    assert q.seq_len == 8 and len(q.req.prompt) == 8
    assert s.metrics["truncated_prompts"] == 1
    # prompt_token_ids reflects the prompt actually served, even after a
    # preemption folds generated tokens into the recompute prompt
    q.req.output.extend([50, 51])
    q.seq_len += 2
    s.preempt_youngest()
    assert q.req.prompt_token_ids == list(range(1, 9))


def test_plan_horizon_bounded_by_remaining_and_capacity():
    s = _sched(mb=4)                         # cap = 16
    s.add(_req(0, 6, max_tokens=20))
    s.add(_req(1, 6, max_tokens=3))
    for q in s.try_admit():
        q.seq_len += 1                       # first sampled token absorbed
    s.running[0].req.output.append(7)
    s.running[1].req.output.append(7)
    # finish boundary: rid 1 has 2 tokens left -> horizon 2
    assert s.plan_horizon(8) == 2
    s.running[1].req.output.extend([7, 7])   # now 0 left... but capacity
    # writes_left: rid 0 seq_len 7 -> 16 - 6 = 10; horizon capped by caller
    assert s.plan_horizon(4) == 1            # max(1, min(0, ...)) floor


def test_plan_horizon_preempts_youngest_when_blocks_exhausted():
    s = _sched(num_blocks=6, max_slots=2, mb=4)
    s.add(_req(0, 8, arrival=1.0))           # 2 full blocks
    r1 = _req(1, 8, arrival=2.0)
    r1.prompt = list(range(101, 109))        # distinct: no prefix sharing
    s.add(r1)                                # 2 more blocks
    for q in s.try_admit():
        q.seq_len += 1
    # exhaust the pool so even one growth block cannot be found
    held = [s.alloc._alloc_raw() for _ in range(s.alloc.num_free)]
    h = s.plan_horizon(8)
    assert s.metrics["preemptions"] >= 1
    assert 1 not in s.running or 0 in s.running   # youngest (rid 1) evicted
    # requeued at the head with prompt+output folded for recompute
    assert s.waiting and s.waiting[0].rid == 1
    for b in held:
        s.alloc.free(b)
    assert h >= 1 or not s.running


def test_grow_for_horizon_returns_cow_pairs_for_shared_tail():
    s = _sched(num_blocks=16, max_slots=2, mb=4)
    ids, _ = s.alloc.allocate_prompt(list(range(6)))   # 1 full + 1 partial
    fork = s.alloc.fork_sequence(ids)
    r0, r1 = _req(0, 6), _req(1, 6)
    s.running[0] = Sequence(req=r0, slot=0, block_ids=ids, seq_len=7,
                            last_token=9)
    s.running[1] = Sequence(req=r1, slot=1, block_ids=fork, seq_len=7,
                            last_token=9)
    cows = s.grow_for_horizon(1)             # both write at pos 6 (shared)
    assert len(cows) == 1                    # first grow CoWs, second owns
    src, dst = cows[0]
    assert src == ids[-1]
    assert s.running[0].block_ids[-1] != s.running[1].block_ids[-1]


def test_finish_at_capacity_sets_reason_and_frees():
    s = _sched(mb=2)                         # cap = 8
    s.add(_req(0, 8, max_tokens=50))
    [q] = s.try_admit()
    q.seq_len += 1                           # next write pos = 8 == cap
    free_before = s.alloc.num_free
    done = s.finish_at_capacity()
    assert [r.finish_reason for r in done] == ["capacity"]
    assert not s.running and s.free_slots and s.alloc.num_free > free_before


def test_preemption_requeues_with_generated_prefix():
    s = _sched(max_slots=2)
    s.add(_req(0, 5, arrival=1.0))
    s.add(_req(1, 5, arrival=2.0))
    for q in s.try_admit():
        q.req.output.extend([100, 101])
        q.seq_len += 2
    s.preempt_youngest()
    assert s.waiting[0].rid == 1
    assert s.waiting[0].prompt == list(range(1, 6)) + [100, 101]
    assert s.waiting[0].prompt_len0 == 5     # reporting keeps the original
    assert s.metrics["preemptions"] == 1


def test_double_preemption_does_not_duplicate_folded_tokens():
    """A second preemption must *replace* the previously folded generated
    suffix, not append the whole output again."""
    s = _sched(max_slots=1)
    s.add(_req(0, 4, max_tokens=20))
    [q] = s.try_admit()
    q.req.output.extend([10, 11])
    q.seq_len += 2
    s.preempt_youngest()
    assert s.waiting[0].prompt == [1, 2, 3, 4, 10, 11]
    [q] = s.try_admit()                      # re-admitted with folded prefix
    q.req.output.append(12)
    q.seq_len += 1
    s.preempt_youngest()
    assert s.waiting[0].prompt == [1, 2, 3, 4, 10, 11, 12]
    assert s.waiting[0].output == [10, 11, 12]
    assert s.waiting[0].prompt_len0 == 4
