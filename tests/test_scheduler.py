"""Host-side Scheduler unit tests: admission, watermark, clamping,
horizon planning, preemption, capacity and the token-budget step planner
(``plan_step``) — no model, no device arrays."""
import pytest

from repro.core.paged_cache import BlockAllocator
from repro.serving.params import SamplingParams
from repro.serving.scheduler import (RequestState, Scheduler,
                                     Sequence, StepPlan)

BS = 4


def _sched(num_blocks=32, max_slots=3, mb=4, **kw):
    alloc = BlockAllocator(num_blocks, BS, watermark_frac=0.0)
    return Scheduler(alloc, max_slots=max_slots, max_blocks_per_seq=mb, **kw)


def _req(rid, n_prompt, max_tokens=8, arrival=None):
    r = RequestState(rid=rid, prompt=list(range(1, n_prompt + 1)),
                     sampling=SamplingParams(max_tokens=max_tokens))
    r.arrival = float(rid + 1 if arrival is None else arrival)
    r.prompt_len0 = n_prompt
    return r


def test_admission_fifo_and_slot_bound():
    s = _sched(max_slots=2)
    for i in range(4):
        s.add(_req(i, 6))
    admitted = s.try_admit()
    assert [q.req.rid for q in admitted] == [0, 1]   # FIFO, 2 slots
    assert len(s.waiting) == 2 and len(s.running) == 2
    assert all(q.seq_len == 6 and q.last_token == 6 for q in admitted)


def test_admission_watermark_blocks():
    # 4 blocks total; each 6-token prompt wants ceil(6/4)+1 = 3 blocks
    alloc = BlockAllocator(4, BS, watermark_frac=0.5)   # watermark = 2
    s = Scheduler(alloc, max_slots=4, max_blocks_per_seq=4)
    s.add(_req(0, 6))
    s.add(_req(1, 6))
    admitted = s.try_admit()
    assert admitted == []                    # 3 needed > 4 free - 2 watermark
    assert len(s.waiting) == 2


def test_overlong_prompt_clamped_at_admission():
    s = _sched(mb=2)                         # cap = 2 * 4 = 8 tokens
    s.add(_req(0, 20))
    [q] = s.try_admit()
    assert q.seq_len == 8 and len(q.req.prompt) == 8
    assert s.metrics["truncated_prompts"] == 1
    # prompt_token_ids reflects the prompt actually served, even after a
    # preemption folds generated tokens into the recompute prompt
    q.req.output.extend([50, 51])
    q.seq_len += 2
    s.preempt_youngest()
    assert q.req.prompt_token_ids == list(range(1, 9))


def test_plan_horizon_bounded_by_remaining_and_capacity():
    s = _sched(mb=4)                         # cap = 16
    s.add(_req(0, 6, max_tokens=20))
    s.add(_req(1, 6, max_tokens=3))
    for q in s.try_admit():
        q.seq_len += 1                       # first sampled token absorbed
    s.running[0].req.output.append(7)
    s.running[1].req.output.append(7)
    # finish boundary: rid 1 has 2 tokens left -> horizon 2
    assert s.plan_horizon(8) == 2
    s.running[1].req.output.extend([7, 7])   # now 0 left... but capacity
    # writes_left: rid 0 seq_len 7 -> 16 - 6 = 10; horizon capped by caller
    assert s.plan_horizon(4) == 1            # max(1, min(0, ...)) floor


def test_plan_horizon_preempts_youngest_when_blocks_exhausted():
    s = _sched(num_blocks=6, max_slots=2, mb=4)
    s.add(_req(0, 8, arrival=1.0))           # 2 full blocks
    r1 = _req(1, 8, arrival=2.0)
    r1.prompt = list(range(101, 109))        # distinct: no prefix sharing
    s.add(r1)                                # 2 more blocks
    for q in s.try_admit():
        q.seq_len += 1
    # exhaust the pool so even one growth block cannot be found
    held = [s.alloc._alloc_raw() for _ in range(s.alloc.num_free)]
    h = s.plan_horizon(8)
    assert s.metrics["preemptions"] >= 1
    assert 1 not in s.running or 0 in s.running   # youngest (rid 1) evicted
    # requeued at the head with prompt+output folded for recompute
    assert s.waiting and s.waiting[0].rid == 1
    for b in held:
        s.alloc.free(b)
    assert h >= 1 or not s.running


def test_grow_for_horizon_returns_cow_pairs_for_shared_tail():
    s = _sched(num_blocks=16, max_slots=2, mb=4)
    ids, _ = s.alloc.allocate_prompt(list(range(6)))   # 1 full + 1 partial
    fork = s.alloc.fork_sequence(ids)
    r0, r1 = _req(0, 6), _req(1, 6)
    s.running[0] = Sequence(req=r0, slot=0, block_ids=ids, seq_len=7,
                            last_token=9, computed_len=6)
    s.running[1] = Sequence(req=r1, slot=1, block_ids=fork, seq_len=7,
                            last_token=9, computed_len=6)
    cows = s.grow_for_horizon(1)             # both write at pos 6 (shared)
    assert len(cows) == 1                    # first grow CoWs, second owns
    src, dst = cows[0]
    assert src == ids[-1]
    assert s.running[0].block_ids[-1] != s.running[1].block_ids[-1]


def test_finish_at_capacity_sets_reason_and_frees():
    s = _sched(mb=2)                         # cap = 8
    s.add(_req(0, 8, max_tokens=50))
    [q] = s.try_admit()
    q.seq_len += 1                           # next write pos = 8 == cap
    free_before = s.alloc.num_free
    done = s.finish_at_capacity()
    assert [r.finish_reason for r in done] == ["capacity"]
    assert not s.running and s.free_slots and s.alloc.num_free > free_before


def test_preemption_requeues_with_generated_prefix():
    s = _sched(max_slots=2)
    s.add(_req(0, 5, arrival=1.0))
    s.add(_req(1, 5, arrival=2.0))
    for q in s.try_admit():
        q.req.output.extend([100, 101])
        q.seq_len += 2
    s.preempt_youngest()
    assert s.waiting[0].rid == 1
    assert s.waiting[0].prompt == list(range(1, 6)) + [100, 101]
    assert s.waiting[0].prompt_len0 == 5     # reporting keeps the original
    assert s.metrics["preemptions"] == 1


def test_double_preemption_does_not_duplicate_folded_tokens():
    """A second preemption must *replace* the previously folded generated
    suffix, not append the whole output again."""
    s = _sched(max_slots=1)
    s.add(_req(0, 4, max_tokens=20))
    [q] = s.try_admit()
    q.req.output.extend([10, 11])
    q.seq_len += 2
    s.preempt_youngest()
    assert s.waiting[0].prompt == [1, 2, 3, 4, 10, 11]
    [q] = s.try_admit()                      # re-admitted with folded prefix
    q.req.output.append(12)
    q.seq_len += 1
    s.preempt_youngest()
    assert s.waiting[0].prompt == [1, 2, 3, 4, 10, 11, 12]
    assert s.waiting[0].output == [10, 11, 12]
    assert s.waiting[0].prompt_len0 == 4


# ------------------------------------------------------- token-budget planner

def _execute_plan(s: Scheduler, plan: StepPlan, tok: int = 500) -> None:
    """Deviceless stand-in for the engine's plan execution: absorb
    ``horizon`` decode tokens per decode slot, mark chunks computed, and
    sample a first token when a prompt's final chunk lands."""
    for slot in plan.decode_slots:
        q = s.running.get(slot)
        if q is None:
            continue
        for _ in range(plan.horizon):
            q.req.output.append(tok)
            q.last_token = tok
            q.seq_len += 1
            if q.req.tokens_remaining() <= 0:
                s.finish(q, "length")
                break
    for c in plan.prefill:
        s.complete_chunk(c)
        if c.last and c.seq.slot in s.running:
            c.seq.req.output.append(tok)       # first sampled token
            c.seq.last_token = tok
            c.seq.seq_len += 1
            if c.seq.req.tokens_remaining() <= 0:
                s.finish(c.seq, "length")


def _drive(s: Scheduler, budget: int, max_horizon: int = 4,
           max_steps: int = 500):
    """Run plan/execute to drain; yields every plan for invariant checks."""
    plans = []
    for _ in range(max_steps):
        if not (s.waiting or s.running):
            break
        for _q in s.finish_at_capacity():
            pass
        plan = s.plan_step(budget, max_horizon=max_horizon)
        plans.append(plan)
        _execute_plan(s, plan)
    return plans


def test_plan_step_budget_never_exceeded():
    s = _sched(num_blocks=64, max_slots=3, mb=8)     # cap 32
    for i, n in enumerate([3, 25, 9, 31, 14, 6, 22]):
        s.add(_req(i, n, max_tokens=5))
    budget = 11
    plans = _drive(s, budget)
    assert len(s.finished) == 7
    assert all(p.used <= budget for p in plans)
    assert any(p.prefill for p in plans) and any(p.decode_slots for p in plans)
    # a 25/31-token prompt cannot fit one 11-token budget: chunking happened
    assert max(len(p.prefill) and max(c.length for c in p.prefill)
               for p in plans) <= budget


def test_plan_step_decode_priority_and_interleave():
    """Running decodes claim budget first; prefill chunks only pack the
    remainder, and the decode horizon is pinned to 1 while prefill work
    is pending (bounded inter-token latency)."""
    s = _sched(num_blocks=64, max_slots=3, mb=8)
    s.add(_req(0, 4, max_tokens=50))
    _execute_plan(s, s.plan_step(32, max_horizon=4))  # admit + full prefill
    assert not s.running[0].prefilling
    s.add(_req(1, 20, max_tokens=50))                 # long prompt arrives
    plan = s.plan_step(8, max_horizon=4)
    assert plan.decode_slots == [0]
    assert plan.horizon == 1                          # interleaved, not fused
    assert len(plan.prefill) == 1
    assert plan.prefill[0].length == 7                # budget 8 - 1 decode
    assert plan.used == 8


def test_plan_step_full_horizon_without_prefill_work():
    s = _sched(num_blocks=64, max_slots=2, mb=8)
    s.add(_req(0, 4, max_tokens=40))
    _execute_plan(s, s.plan_step(32, max_horizon=4))
    plan = s.plan_step(32, max_horizon=4)
    assert plan.decode_slots == [0] and plan.horizon == 4


def test_plan_step_no_starvation_under_steady_decode_load():
    """A waiting prompt makes monotonic chunk progress every step even
    while every slot's decode keeps claiming budget first."""
    s = _sched(num_blocks=64, max_slots=2, mb=8)
    s.add(_req(0, 4, max_tokens=10 ** 6))             # decodes forever
    _execute_plan(s, s.plan_step(32, max_horizon=4))
    s.add(_req(1, 21, max_tokens=5))
    budget = 6                                        # 1 decode + 5 prefill
    seen = []
    for _ in range(10):
        plan = s.plan_step(budget, max_horizon=4)
        assert plan.used <= budget
        _execute_plan(s, plan)
        q = next((x for x in s.running.values() if x.req.rid == 1), None)
        if q is None:                                 # finished prefill+gen
            break
        seen.append(q.computed_len)
    assert seen == sorted(seen)                       # monotone progress
    assert any(x.req.rid == 1 and not x.prefilling
               for x in s.running.values()) or \
        any(r.rid == 1 for r in s.finished)
    # progress took ceil(21/5) = 5 chunk steps, not a stall-out
    assert len(seen) <= 6


def test_plan_step_incremental_blocks_never_exceed_whole_prompt():
    """Chunked admission allocates per chunk; at no point may a
    mid-prefill sequence hold more blocks than whole-prompt admission
    would have allocated up front (ceil(len/bs) + 1)."""
    s = _sched(num_blocks=64, max_slots=1, mb=8)
    n = 30
    s.add(_req(0, n, max_tokens=2))
    whole = -(-n // BS) + 1
    peak = 0
    for _ in range(20):
        plan = s.plan_step(7, max_horizon=2)
        for q in s.running.values():
            peak = max(peak, len(q.block_ids))
        _execute_plan(s, plan)
        if s.finished:
            break
    assert s.finished and peak <= whole
    # and strictly fewer while the first chunks were in flight
    assert peak == -(-n // BS)                        # never the +1 upfront


def test_plan_step_admission_is_watermark_gated():
    alloc = BlockAllocator(8, BS, watermark_frac=0.25)  # watermark = 2
    s = Scheduler(alloc, max_slots=2, max_blocks_per_seq=8)
    held = [alloc._alloc_raw() for _ in range(3)]       # another tenant
    s.add(_req(0, 20, max_tokens=4))                    # feasible: 6 <= 8-2
    plan = s.plan_step(32, max_horizon=2)
    # the first chunk is clipped to the watermarked headroom:
    # (5 free - 2 watermark) * BS = 12 tokens, not the whole 20
    assert sum(c.length for c in plan.prefill) == 12
    for b in held:
        alloc.free(b)


def test_plan_step_never_admits_pool_infeasible_prompt():
    """A prompt that could never complete on this pool stays in waiting
    (exactly like whole-prompt admission) instead of being parked
    mid-prefill on blocks it can never finish with."""
    alloc = BlockAllocator(4, BS, watermark_frac=0.5)   # watermark = 2
    s = Scheduler(alloc, max_slots=2, max_blocks_per_seq=4)
    s.add(_req(0, 12, max_tokens=4))                    # needs 4 > 4 - 2
    plan = s.plan_step(16, max_horizon=2)
    assert not plan.prefill and len(s.waiting) == 1
    assert alloc.num_free == 4                          # nothing held
    # ... and the stuck head must not pin running decodes to horizon 1
    ids, _ = alloc.allocate_prompt([900])
    s.running[0] = Sequence(req=_req(9, 1, max_tokens=50), slot=0,
                            block_ids=ids, seq_len=2, last_token=900,
                            computed_len=1)
    s.free_slots.remove(0)
    plan = s.plan_step(16, max_horizon=2)
    assert plan.decode_slots == [0] and plan.horizon == 2


def test_plan_step_preempts_mid_prefill_and_recomputes_from_zero():
    """Out-of-blocks preemption may evict a mid-prefill sequence: its
    blocks free immediately, the untouched prompt requeues, and
    re-admission restarts the chunk walk at computed_len = 0."""
    s = _sched(num_blocks=6, max_slots=2, mb=6)
    s.add(_req(0, 8, max_tokens=50, arrival=1.0))     # 2 blocks + grow
    _execute_plan(s, s.plan_step(32, max_horizon=1))
    r1 = _req(1, 16, max_tokens=5, arrival=2.0)
    r1.prompt = list(range(101, 117))                  # no prefix sharing
    s.add(r1)
    plan = s.plan_step(5, max_horizon=1)               # 1 decode + 4 prefill
    _execute_plan(s, plan)
    young = next(x for x in s.running.values() if x.req.rid == 1)
    assert young.prefilling and young.computed_len == 4
    # decode growth now exhausts the pool -> youngest (mid-prefill) evicted
    for _ in range(30):
        plan = s.plan_step(5, max_horizon=1)
        _execute_plan(s, plan)
        if s.metrics["preemptions_mid_prefill"]:
            break
    assert s.metrics["preemptions_mid_prefill"] >= 1
    # the evicted request lost nothing: untouched prompt, no folded output,
    # and (whether still queued or already re-admitted) the chunk walk
    # restarted from zero
    assert r1.prompt == list(range(101, 117)) and r1.folded == 0
    readmitted = next((x for x in s.running.values() if x.req.rid == 1),
                      None)
    if readmitted is not None:
        assert readmitted.computed_len <= 4            # restarted, not 4+
    else:
        assert s.waiting and s.waiting[0].rid == 1
    # rid 0 drains, rid 1 re-admits at computed_len 0 and completes
    while s.waiting or s.running:
        for _q in s.finish_at_capacity():
            pass
        _execute_plan(s, s.plan_step(5, max_horizon=1))
    assert {r.rid for r in s.finished} == {0, 1}


def test_plan_step_deadlock_guard_evicts_youngest():
    """All-prefilling, zero-free-blocks: the planner must evict rather
    than return empty plans forever."""
    s = _sched(num_blocks=4, max_slots=2, mb=4)        # cap 16
    r0 = _req(0, 16, max_tokens=2, arrival=1.0)
    r1 = _req(1, 16, max_tokens=2, arrival=2.0)
    r1.prompt = list(range(201, 217))                  # distinct blocks
    ids0, _ = s.alloc.allocate_prompt(r0.prompt[:8])   # 2 blocks each:
    ids1, _ = s.alloc.allocate_prompt(r1.prompt[:8])   # pool exhausted
    s.running[0] = Sequence(req=r0, slot=0, block_ids=ids0, seq_len=8,
                            last_token=8, computed_len=8)
    s.running[1] = Sequence(req=r1, slot=1, block_ids=ids1, seq_len=8,
                            last_token=8, computed_len=8)
    s.free_slots.clear()
    assert s.alloc.num_free == 0
    plan = s.plan_step(16, max_horizon=1)              # nothing schedulable
    assert not plan.decode_slots and not plan.prefill
    assert s.metrics["preemptions"] == 1               # guard fired
    assert s.metrics["preemptions_mid_prefill"] == 1   # ... on rid 1
    # and the survivor's next chunk continues from where it stopped
    plan = s.plan_step(16, max_horizon=1)
    assert plan.prefill and plan.prefill[0].seq.req.rid == 0
    assert plan.prefill[0].start == 8


def test_plan_step_property_random_arrivals():
    """Hypothesis sweep: for any arrival/budget/length mix the planner
    never exceeds the budget, never regresses computed_len, and never
    holds more blocks than whole-prompt admission would."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.data())
    def run(data):
        budget = data.draw(st.integers(3, 40), label="budget")
        horizon = data.draw(st.integers(1, 8), label="horizon")
        lens = data.draw(st.lists(st.integers(1, 40), min_size=1,
                                  max_size=8), label="lens")
        s = _sched(num_blocks=32, max_slots=2, mb=8)   # cap 32, tight-ish
        pending = [_req(i, min(n, 40), max_tokens=3)
                   for i, n in enumerate(lens)]
        for i, r in enumerate(pending):
            r.prompt = [1000 * (i + 1) + t for t in range(len(r.prompt))]
        steps = 0
        while (pending or s.waiting or s.running) and steps < 300:
            steps += 1
            if pending and steps % 2:                  # staggered arrivals
                s.add(pending.pop(0))
            for _q in s.finish_at_capacity():
                pass
            plan = s.plan_step(budget, max_horizon=horizon)
            assert plan.used <= budget
            for c in plan.prefill:
                assert c.start == c.seq.computed_len
                assert c.length >= 1
            before = {id(x): x.computed_len for x in s.running.values()}
            _execute_plan(s, plan)
            for x in s.running.values():
                if id(x) in before:
                    assert x.computed_len >= before[id(x)]
                assert x.computed_len <= len(x.req.prompt)
                assert len(x.block_ids) <= -(-len(x.req.prompt) // BS) + 1
        assert not pending and not s.waiting and not s.running

    run()


def test_plan_step_budget_bound_holds_standalone():
    """StepPlan's used <= budget contract holds even for a degenerate
    budget <= decodable count (no engine validation in front): overflow
    slots sit the iteration out instead of over-batching."""
    s = _sched(num_blocks=64, max_slots=4, mb=8)
    for i in range(4):
        r = _req(i, 4, max_tokens=50)
        r.prompt = [100 * (i + 1) + t for t in range(4)]
        s.add(r)
    while s.waiting:
        _execute_plan(s, s.plan_step(64, max_horizon=1))
    assert len(s.decodable()) == 4
    plan = s.plan_step(3, max_horizon=4)
    assert plan.used <= 3
    assert len(plan.decode_slots) == 3 and plan.horizon == 1


def test_plan_step_zero_headroom_keeps_full_horizon():
    """A feasible waiting prompt that cannot admit a single token this
    step (watermarked headroom exhausted) must not pin decodes to
    horizon 1 — no chunk could run anyway."""
    alloc = BlockAllocator(8, BS, watermark_frac=0.25)  # watermark = 2
    s = Scheduler(alloc, max_slots=2, max_blocks_per_seq=6)
    s.add(_req(0, 4, max_tokens=200))
    _execute_plan(s, s.plan_step(32, max_horizon=1))
    held = []
    while alloc.num_free > alloc.watermark:             # headroom -> 0
        held.append(alloc._alloc_raw())
    s.add(_req(1, 8, max_tokens=4))                     # feasible, stuck
    plan = s.plan_step(32, max_horizon=4)
    assert plan.horizon == 4 and not plan.prefill       # full fused speed
    for b in held:
        alloc.free(b)
    plan = s.plan_step(32, max_horizon=4)               # headroom is back
    assert plan.horizon == 1 and plan.prefill


# ------------------------------------------------- unified-dispatch layout

def test_unified_dispatch_layout():
    """The plan's unified-dispatch layout: the first dispatch carries
    every decode slot plus the first chunk, admission-burst chunks each
    dispatch alone, and only final chunks mark their sample row."""
    s = _sched(num_blocks=64, max_slots=3, mb=8)
    s.add(_req(0, 4, max_tokens=100))
    _execute_plan(s, s.plan_step(32, max_horizon=4))   # rid 0 decoding
    s.add(_req(1, 21, max_tokens=5))                   # needs 2+ chunks
    s.add(_req(2, 6, max_tokens=5))                    # fits one chunk
    plan = s.plan_step(32, max_horizon=4)
    ds = plan.unified_dispatches()
    assert [d.chunk for d in ds] == plan.prefill       # one each, in order
    assert ds[0].decode_slots == plan.decode_slots
    assert all(d.decode_slots == [] for d in ds[1:])
    assert [d.sample_chunk for d in ds] == [c.last for c in plan.prefill]
    # pure-decode plans have no unified dispatch (megastep territory)
    _execute_plan(s, plan)
    while any(q.prefilling for q in s.running.values()):
        _execute_plan(s, s.plan_step(32, max_horizon=4))
    plan = s.plan_step(32, max_horizon=4)
    assert not plan.prefill and plan.unified_dispatches() == []


# ---------------------------------------------- register-on-write hashing

def test_register_on_write_full_blocks_reused_across_requests():
    """A repeated 2-chunk prompt reuses ALL its full blocks: the first
    chunk's via ``allocate_prompt`` hashing, the continuation chunk's via
    register-on-write + content-addressed ``grow_prefill``."""
    s = _sched(num_blocks=64, max_slots=2, mb=8)
    prompt = list(range(1, 23))                        # 22 tokens, BS=4
    r0 = _req(0, 1, max_tokens=4)
    r0.prompt = list(prompt)
    r0.prompt_len0 = len(prompt)
    s.add(r0)
    # two chunks: 12 + 10 (budget 12) — 5 full blocks + private tail
    for _ in range(4):
        _execute_plan(s, s.plan_step(12, max_horizon=1))
        if not any(q.prefilling for q in s.running.values()):
            break
    q0 = next(q for q in s.running.values() if q.req.rid == 0)
    assert not q0.prefilling
    assert q0.hashed_blocks == len(prompt) // BS       # all 5 registered
    r1 = _req(1, 1, max_tokens=4)
    r1.prompt = list(prompt)
    r1.prompt_len0 = len(prompt)
    s.add(r1)
    before = s.alloc.stats["reused"]
    # budget 13 = 1 decode (rid 0) + 12 prefill: rid 1's chunk walk lands
    # on the same block-aligned 12 + 10 split rid 0 took
    while any(q.prefilling for q in s.running.values()) or \
            any(r.rid == 1 for r in s.waiting):
        _execute_plan(s, s.plan_step(13, max_horizon=1))
    q1 = next(q for q in s.running.values() if q.req.rid == 1)
    # every full block is shared with rid 0's live sequence
    n_full = len(prompt) // BS
    assert s.alloc.stats["reused"] - before == n_full
    assert q1.block_ids[:n_full] == q0.block_ids[:n_full]
    assert q1.block_ids[n_full] != q0.block_ids[n_full]   # tails private


def test_register_on_write_skips_chunk_straddling_blocks():
    """A block filled across two chunks (the int8 boundary-merge case)
    is never registered — only whole-chunk-covered blocks are shareable."""
    s = _sched(num_blocks=64, max_slots=1, mb=8)
    r = _req(0, 1, max_tokens=4)
    r.prompt = list(range(1, 25))                      # 24 tokens
    r.prompt_len0 = 24
    s.add(r)
    # chunks of 6: blocks 1 (tokens 4..8) and 4 (16..20) straddle
    while any(q.prefilling for q in s.running.values()) or s.waiting:
        _execute_plan(s, s.plan_step(7, max_horizon=1))   # 1 dec + 6 pre
    q = next(iter(s.running.values()))
    hashed = [s.alloc._blocks[b].token_hash is not None
              for b in q.block_ids[:6]]
    # block 0 hashed by allocate_prompt (first chunk covers it whole);
    # straddled blocks stay private, fully-covered later ones register
    assert hashed[0] and not all(hashed[1:])
    assert any(hashed[1:])                             # some registered
