"""Serving-layer fault tolerance: deterministic injection, lifecycle
control (abort / deadlines), poisoned-dispatch recovery, load shedding,
and the seeded chaos suite.

The central contract, asserted throughout: under any injected fault
schedule the engine drains, quarantined requests finish with
``finish_reason="error"``, every OTHER greedy request is token-exact
against a fault-free run, and the block allocator audits clean (no
leaked blocks, no dangling prefix-hash entries).
"""
import time

import jax
import numpy as np
import pytest

from repro.configs.registry import get_reduced
from repro.models import transformer as T
from repro.serving import (EngineOverloadedError, FaultInjector, FaultSpec,
                           SamplingParams, ServingEngine, TransientDeviceError,
                           random_schedule)

KEY = jax.random.PRNGKey(0)

# (engine kwargs, id) — the unified single-dispatch path and the two-call
# oracle path must give fault handling identical semantics
MODES = [
    pytest.param({}, id="unified"),
    pytest.param({"enable_unified_step": False}, id="two-call"),
]
POOLS = ["bf16", "int8"]


@pytest.fixture(scope="module")
def small():
    cfg = get_reduced("qwen2-1.5b", num_layers=2)
    params = T.init_params(cfg, KEY)
    return cfg, params


def _mk(small, **kw):
    cfg, params = small
    kw.setdefault("max_slots", 2)
    kw.setdefault("num_blocks", 64)
    kw.setdefault("max_blocks_per_seq", 8)
    kw.setdefault("max_num_batched_tokens", 8)
    return ServingEngine(cfg, params, **kw)


def _prompts(n, seed=0, lo=3, hi=15):
    rng = np.random.default_rng(seed)
    return [list(rng.integers(1, 200, int(rng.integers(lo, hi))))
            for _ in range(n)]


def _drain(eng, prompts, max_tokens=5, max_steps=500):
    for p in prompts:
        eng.add(p, SamplingParams(max_tokens=max_tokens))
    eng.run_until_done(max_steps=max_steps)
    assert not eng.scheduler.has_work(), "engine failed to drain"
    return {r.rid: r for r in eng.finished}


# --------------------------------------------------------------- injector
def test_fault_spec_validates_site():
    with pytest.raises(ValueError):
        FaultSpec("gamma-ray", step=0)


def test_injector_arming_counts_and_forgive():
    fi = FaultInjector([FaultSpec("dispatch", step=1, count=2),
                        FaultSpec("dispatch", step=0, rid=7),
                        FaultSpec("alloc", step=0)])
    fi.step_begin()                                  # step 0
    assert fi.alloc_blocked() and not fi.alloc_blocked()  # count=1 clears
    with pytest.raises(TransientDeviceError):
        fi.check_dispatch([7, 8])                    # rid-targeted fires
    fi.check_dispatch([8])                           # rid 7 absent: clean
    with pytest.raises(TransientDeviceError):
        fi.check_dispatch([7])                       # persistent until forgive
    fi.forgive(7)
    fi.check_dispatch([7])                           # quarantined: clean
    fi.step_begin()                                  # step 1: transient arms
    for _ in range(2):
        with pytest.raises(TransientDeviceError):
            fi.check_dispatch([1])
    fi.check_dispatch([1])                           # count=2 exhausted
    assert [f["site"] for f in fi.fired] == \
        ["alloc", "dispatch", "dispatch", "dispatch", "dispatch"]


def test_injector_nan_waits_for_target():
    """A nan spec must not burn itself on a batch without its victim."""
    fi = FaultInjector([FaultSpec("nan", step=0, rid=3)])
    fi.step_begin()
    assert fi.nan_rids([0, 1]) == set()              # victim absent: armed
    assert fi.nan_rids([1, 3]) == {3}                # fires
    assert fi.nan_rids([1, 3]) == set()              # count=1: cleared


def test_random_schedule_is_deterministic():
    a = random_schedule(5, 40, p_dispatch=0.3, p_nan=0.2, p_alloc=0.2,
                        rids=[1, 2, 3])
    b = random_schedule(5, 40, p_dispatch=0.3, p_nan=0.2, p_alloc=0.2,
                        rids=[1, 2, 3])
    assert a == b and len(a) > 0
    assert a != random_schedule(6, 40, p_dispatch=0.3, p_nan=0.2,
                                p_alloc=0.2, rids=[1, 2, 3])


# ------------------------------------------------------- lifecycle control
@pytest.mark.parametrize("pool", POOLS)
def test_abort_releases_blocks_at_every_stage(small, pool):
    """Abort while waiting / mid-prefill / decoding: blocks, slots and
    hash registrations are all released the same step (refcount audit),
    in both KV pools."""
    eng = _mk(small, kv_cache_dtype=pool)
    rng = np.random.default_rng(3)
    long = list(rng.integers(1, 200, 30))            # chunks over many steps
    r_chunk = eng.add(long, SamplingParams(max_tokens=4))
    r_decode = eng.add(list(rng.integers(1, 200, 6)),
                       SamplingParams(max_tokens=32))
    r_wait = eng.add(list(rng.integers(1, 200, 6)),
                     SamplingParams(max_tokens=8))
    assert eng.abort(r_wait)                         # still waiting
    eng.step()                                       # r_chunk now mid-prefill
    assert any(s.prefilling for s in eng.running.values())
    assert eng.abort(r_chunk)                        # mid-prefill chunk walk
    for _ in range(2):
        eng.step()
    assert eng.abort(r_decode)                       # decoding
    eng.run_until_done()
    reasons = {r.rid: r.finish_reason for r in eng.finished}
    assert reasons == {r_wait: "aborted", r_chunk: "aborted",
                       r_decode: "aborted"}
    audit = eng.alloc.audit()                        # raises on leak
    assert audit["live_blocks"] == 0 and audit["hash_entries"] == 0
    assert not eng.abort(r_decode)                   # already finished
    assert not eng.abort(999)                        # unknown


@pytest.mark.parametrize("pool", POOLS)
def test_mid_prefill_finish_leaves_no_stale_prefix(small, pool):
    """Regression (register-on-write): killing a request mid-prefill must
    not leave hash entries over blocks whose device write never happened
    — a later identical prompt must produce the same tokens as a fresh
    engine, not read a junk 'cached' prefix."""
    cfg, params = small
    eng = _mk(small, kv_cache_dtype=pool, num_blocks=32)
    prompt = list(np.random.default_rng(9).integers(1, 200, 24))
    rid = eng.add(prompt, SamplingParams(max_tokens=3))
    eng.step()                                       # first chunk only
    assert any(s.prefilling for s in eng.running.values())
    assert eng.abort(rid)
    audit = eng.alloc.audit()
    assert audit["live_blocks"] == 0 and audit["hash_entries"] == 0
    # identical prompt through the SAME engine (pool may hold stale bytes)
    rid2 = eng.add(prompt, SamplingParams(max_tokens=3))
    eng.run_until_done()
    out = {r.rid: r for r in eng.finished}[rid2]
    fresh = list(_drain(_mk(small, kv_cache_dtype=pool, num_blocks=32),
                        [prompt], max_tokens=3).values())[0]
    assert list(out.output) == list(fresh.output)


def test_deadline_total_and_ttft(small):
    eng = _mk(small)
    rng = np.random.default_rng(4)
    r_dead = eng.add(list(rng.integers(1, 200, 6)),
                     SamplingParams(max_tokens=100000, deadline_ms=200))
    r_ok = eng.add(list(rng.integers(1, 200, 6)),
                   SamplingParams(max_tokens=4, ttft_deadline_ms=1e7,
                                  deadline_ms=1e7))
    eng.run_until_done(max_steps=5000)
    reasons = {r.rid: r.finish_reason for r in eng.finished}
    assert reasons[r_dead] == "deadline"
    assert reasons[r_ok] == "length"                 # deadlines off => normal
    dead = [r for r in eng.finished if r.rid == r_dead][0]
    assert (dead.done_t - dead.arrival) * 1e3 >= 200  # kept partial output
    assert eng.metrics["deadline_expired"] == 1
    assert eng.alloc.audit()["live_blocks"] == 0


def test_ttft_deadline_fires_before_first_token(small):
    eng = _mk(small)
    rid = eng.add(list(np.random.default_rng(5).integers(1, 200, 6)),
                  SamplingParams(max_tokens=4, ttft_deadline_ms=0.001))
    time.sleep(0.01)
    # the async engine's RequestOutput fan-out lags step() by one step
    # (detok worker slack), so drain both steps' events
    outs = eng.step() + eng.step()
    assert any(o.request_id == rid and o.finish_reason == "deadline"
               for o in outs)
    assert eng.alloc.audit()["live_blocks"] == 0


# ------------------------------------------------------ dispatch recovery
@pytest.mark.parametrize("kw", MODES)
def test_transient_dispatch_retry_is_token_exact(small, kw):
    prompts = _prompts(4, seed=1)
    base = _drain(_mk(small, **kw), prompts)
    fi = FaultInjector([FaultSpec("dispatch", step=1, count=1),
                        FaultSpec("dispatch", step=3, count=2)])
    eng = _mk(small, fault_injector=fi, **kw)
    got = _drain(eng, prompts)
    assert {r: list(v.output) for r, v in got.items()} == \
        {r: list(v.output) for r, v in base.items()}
    assert eng.metrics["dispatch_retries"] >= 3
    assert eng.metrics["quarantined"] == 0


@pytest.mark.parametrize("kw", MODES)
def test_poisoned_request_is_bisected_and_quarantined(small, kw):
    """A persistent rid-targeted dispatch fault: the offender is cornered
    via requeue-and-bisect and fails with "error"; everyone who shared
    its batches keeps decoding token-exactly."""
    prompts = _prompts(4, seed=1)
    base = _drain(_mk(small, **kw), prompts)
    fi = FaultInjector([FaultSpec("dispatch", step=0, rid=2)])
    eng = _mk(small, fault_injector=fi, **kw)
    got = _drain(eng, prompts)
    assert got[2].finish_reason == "error"
    assert all(list(got[r].output) == list(base[r].output)
               for r in got if r != 2)
    assert eng.metrics["quarantined"] == 1
    assert eng.alloc.audit()["live_blocks"] == 0
    assert eng.health()["probing_rids"] == 0         # probation lifted


@pytest.mark.parametrize("kw", MODES)
def test_nan_row_guard_fails_only_poisoned_row(small, kw):
    prompts = _prompts(4, seed=1)
    base = _drain(_mk(small, **kw), prompts)
    fi = FaultInjector([FaultSpec("nan", step=0, rid=1)])
    eng = _mk(small, fault_injector=fi, **kw)
    got = _drain(eng, prompts)
    assert got[1].finish_reason == "error"
    assert all(list(got[r].output) == list(base[r].output)
               for r in got if r != 1)
    assert all(t >= 0 for r in got.values() for t in r.output)
    assert eng.alloc.audit()["live_blocks"] == 0


def test_guards_off_matches_guards_on_when_healthy(small):
    prompts = _prompts(4, seed=2)
    on = _drain(_mk(small, enable_guards=True), prompts)
    off = _drain(_mk(small, enable_guards=False), prompts)
    assert {r: list(v.output) for r, v in on.items()} == \
        {r: list(v.output) for r, v in off.items()}


# ------------------------------------------------------------- shedding
def test_shed_policy_reject(small):
    eng = _mk(small, max_waiting=2, shed_policy="reject")
    eng.add([1, 2, 3])
    eng.add([4, 5, 6])
    with pytest.raises(EngineOverloadedError):
        eng.add([7, 8, 9])
    assert eng.metrics["shed"] == 1
    assert eng.health()["waiting"] == 2


def test_shed_policy_oldest(small):
    eng = _mk(small, max_waiting=2, shed_policy="shed-oldest")
    oldest = eng.add([1, 2, 3])
    eng.add([4, 5, 6])
    newest = eng.add([7, 8, 9])
    outs = eng.step()                     # shed event surfaces next step
    shed = [o for o in outs if o.finish_reason == "shed"]
    assert [o.request_id for o in shed] == [oldest]
    eng.run_until_done()
    reasons = {r.rid: r.finish_reason for r in eng.finished}
    assert reasons[oldest] == "shed" and reasons[newest] == "length"
    assert eng.alloc.audit()["live_blocks"] == 0


def test_bad_shed_policy_rejected(small):
    with pytest.raises(ValueError):
        _mk(small, shed_policy="coin-flip")


# ------------------------------------------------------------- watchdog
def test_stall_trips_straggler_watchdog(small):
    fi = FaultInjector([FaultSpec("stall", step=4, seconds=0.4)])
    eng = _mk(small, fault_injector=fi)
    _drain(eng, _prompts(2, seed=6), max_tokens=10)
    assert eng.metrics["slow_steps"] >= 1
    rep = eng.report()
    assert rep["slow_steps"] >= 1
    assert np.isfinite(rep["step_time_ema_ms"])
    h = eng.health()
    assert h["slow_steps"] >= 1 and np.isfinite(h["step_time_ema_ms"])


# ------------------------------------------------------------ chaos suite
@pytest.mark.parametrize("pool", POOLS)
@pytest.mark.parametrize("kw", MODES)
def test_chaos_schedule_drains_token_exact(small, kw, pool):
    """Seeded random fault soup (transient dispatches + NaN rows + alloc
    exhaustion): the engine drains every request, quarantined ones get
    "error", unaffected greedy requests are token-exact vs the fault-free
    run — in unified AND two-call modes, bf16 AND int8 pools."""
    prompts = _prompts(4, seed=7)
    base = _drain(_mk(small, kv_cache_dtype=pool, **kw), prompts)
    fi = FaultInjector(random_schedule(11, 25, p_dispatch=0.25,
                                       p_alloc=0.2, p_nan=0.15,
                                       rids=[0, 3]))
    eng = _mk(small, fault_injector=fi, kv_cache_dtype=pool, **kw)
    got = _drain(eng, prompts)
    assert len(got) == len(prompts)                  # everyone finished
    bad = {r for r, v in got.items() if v.finish_reason == "error"}
    assert all(list(got[r].output) == list(base[r].output)
               for r in got if r not in bad), (bad, kw, pool)
    assert len(fi.fired) > 0                         # the soup was real
    assert eng.alloc.audit()["live_blocks"] == 0
    assert eng.health()["probing_rids"] == 0
