"""repro.obs: span tracer, metrics registry, HTTP exposition, and the
engine's lifecycle-derived latency histograms + host/device attribution."""
import json
import urllib.request

import jax
import numpy as np
import pytest

from repro.configs.registry import get_reduced
from repro.models import transformer as T
from repro.obs import (Gauge, Histogram, MetricsDict, MetricsRegistry,
                       SpanTracer, attribute_steps, validate_chrome_trace)
from repro.obs.http import start_obs_server
from repro.runtime.fault import StragglerDetector
from repro.serving import SamplingParams, ServingEngine

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def small():
    cfg = get_reduced("qwen2-1.5b", num_layers=2)
    params = T.init_params(cfg, KEY)
    return cfg, params


@pytest.fixture(scope="module")
def served(small):
    """One engine run shared by the derivation/attribution/export tests."""
    cfg, params = small
    eng = ServingEngine(cfg, params, max_slots=4, num_blocks=128,
                        max_blocks_per_seq=8, prefill_bucket=16,
                        detokenizer=lambda ids: "".join(
                            chr(97 + i % 26) for i in ids))
    rng = np.random.default_rng(0)
    sp = SamplingParams(max_tokens=4)
    for _ in range(6):
        eng.add(list(rng.integers(1, 200, int(rng.integers(3, 15)))), sp)
    eng.run_until_done()
    return eng


# ------------------------------------------------------------------ tracer
def test_span_nesting_records_depth():
    tr = SpanTracer()
    with tr.span("outer"):
        with tr.span("inner", cat="device"):
            pass
    inner, outer = tr.spans()          # completion order: inner exits first
    assert (inner.name, inner.depth) == ("inner", 1)
    assert (outer.name, outer.depth) == ("outer", 0)
    assert inner.cat == "device"
    # containment: the inner span's window sits inside the outer's
    assert outer.ts <= inner.ts
    assert inner.ts + inner.dur <= outer.ts + outer.dur


def test_ring_truncation_counts_dropped():
    tr = SpanTracer(capacity=4)
    for i in range(10):
        tr.instant(f"e{i}")
    assert len(tr.spans()) == 4
    assert [s.name for s in tr.spans()] == ["e6", "e7", "e8", "e9"]
    assert tr.dropped == 6
    tr.clear()
    assert tr.spans() == [] and tr.dropped == 0


def test_disabled_tracer_is_zero_work():
    tr = SpanTracer(enabled=False)
    # the disabled path hands out ONE shared no-op object — no per-span
    # allocation on a telemetry-off hot loop
    assert tr.span("a") is tr.span("b")
    with tr.span("a", cat="device", args={"x": 1}) as sp:
        sp.set(y=2)                    # no-op, chains fine
    tr.instant("mark")
    assert tr.spans() == [] and tr.dropped == 0
    tr.enable()
    with tr.span("now-recorded"):
        pass
    assert [s.name for s in tr.spans()] == ["now-recorded"]


def test_chrome_trace_schema_valid():
    tr = SpanTracer()
    with tr.span("step", cat="step", args={"k": 1}):
        tr.instant("mark", cat="request")
    doc = tr.to_chrome_trace()
    assert validate_chrome_trace(doc) == []
    phs = {e["name"]: e["ph"] for e in doc["traceEvents"]}
    assert phs == {"mark": "i", "step": "X"}
    step = next(e for e in doc["traceEvents"] if e["ph"] == "X")
    assert step["dur"] >= 0 and step["args"] == {"k": 1}
    # validator actually catches malformed docs
    assert validate_chrome_trace({"traceEvents": [{"ph": "X"}]})


def test_attribution_host_plus_device_is_step():
    tr = SpanTracer()
    for _ in range(3):
        with tr.span("engine.step", cat="step"):
            with tr.span("plan", cat="host"):
                pass
            with tr.span("dispatch:unified", cat="device"):
                pass
            with tr.span("readback", cat="device"):
                pass
    attr = attribute_steps(tr.spans(), window=2)
    assert attr["steps"] == 2.0
    assert attr["host_ms"] + attr["device_ms"] == \
        pytest.approx(attr["step_ms"])
    assert 0.0 < attr["device_frac"] < 1.0
    assert attr["host_frac"] + attr["device_frac"] == pytest.approx(1.0)
    # no work steps (e.g. tracer disabled) -> NaN columns, not garbage
    empty = attribute_steps([])
    assert empty["steps"] == 0.0 and empty["host_ms"] != empty["host_ms"]


# ----------------------------------------------------------------- metrics
def test_histogram_bucket_edges_le_semantics():
    h = Histogram("h_ms", buckets=(1.0, 5.0, 10.0))
    for v in (0.5, 1.0, 1.001, 5.0, 99.0):   # 1.0 and 5.0 land ON an edge
        h.observe(v)
    assert h.counts == [2, 2, 0, 1]          # le=1: {0.5, 1.0}; +Inf: {99}
    assert h.cumulative() == [("1", 2), ("5", 4), ("10", 4), ("+Inf", 5)]
    assert h.count == 5 and h.sum == pytest.approx(106.501)
    with pytest.raises(ValueError):
        Histogram("bad", buckets=(5.0, 1.0))


def test_histogram_percentile_matches_numpy():
    h = Histogram("h", buckets=(1e9,), sample_maxlen=64)
    rng = np.random.default_rng(0)
    xs = rng.uniform(0, 100, 50)
    for v in xs:
        h.observe(v)
    for p in (0, 50, 99, 100):
        assert h.percentile(p) == pytest.approx(np.percentile(xs, p))
    h.clear_samples()
    assert h.percentile(50) != h.percentile(50)   # NaN on empty window
    assert h.count == 50                          # cumulative untouched


def test_prometheus_exposition_format():
    reg = MetricsRegistry()
    reg.counter("repro_gen_tokens", help="tokens").inc(7)
    reg.gauge("repro_waiting").set(3)
    h = reg.histogram("repro_itl_ms", buckets=(1.0, 10.0))
    h.observe(0.5)
    h.observe(4.0)
    text = reg.to_prometheus()
    assert "# TYPE repro_gen_tokens counter" in text
    assert "# HELP repro_gen_tokens tokens" in text
    assert "repro_gen_tokens 7" in text
    assert "# TYPE repro_waiting gauge" in text
    assert 'repro_itl_ms_bucket{le="1"} 1' in text
    assert 'repro_itl_ms_bucket{le="10"} 2' in text
    assert 'repro_itl_ms_bucket{le="+Inf"} 2' in text
    assert "repro_itl_ms_sum 4.5" in text
    assert "repro_itl_ms_count 2" in text
    with pytest.raises(ValueError):
        reg.counter("0bad name")


def test_registry_snapshot_json_and_type_guard():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.gauge("g").set(float("nan"))
    reg.histogram("h", buckets=(1.0,)).observe(2.0)
    snap = reg.snapshot()
    json.dumps(snap, allow_nan=False)            # NaN-free by contract
    assert snap["gauges"]["g"] is None
    assert snap["histograms"]["h"]["buckets"] == {"1": 0, "+Inf": 1}
    with pytest.raises(TypeError):
        reg.gauge("c")                           # name already a counter
    assert reg.counter("c").get() == 1.0         # get-or-create idempotent


def test_metrics_dict_facade_backed_by_registry():
    reg = MetricsRegistry()
    m = MetricsDict(reg, initial={"gen_tokens": 0})
    m["gen_tokens"] += 2                         # the engine's idiom
    m.setdefault("preemptions", 0)               # the scheduler's idiom
    m["preemptions"] += 1
    assert m["gen_tokens"] == 2.0
    assert reg.get("repro_gen_tokens").get() == 2.0
    assert dict(m) == {"gen_tokens": 2.0, "preemptions": 1.0}
    with pytest.raises(KeyError):
        m["never_created"]


# -------------------------------------------------------------------- http
def test_http_metrics_health_trace_smoke():
    reg = MetricsRegistry()
    reg.counter("repro_gen_tokens").inc(5)
    tr = SpanTracer()
    tr.instant("mark")
    srv = start_obs_server(0, registry=reg, tracer=tr,
                           health_fn=lambda: {"waiting": 1.0,
                                              "max_waiting": float("inf")})
    port = srv.server_address[1]
    try:
        def get(path):
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{port}{path}") as r:
                return r.status, r.read().decode()
        code, text = get("/metrics")
        assert code == 200 and "repro_gen_tokens 5" in text
        code, text = get("/health")
        assert code == 200
        assert json.loads(text) == {"waiting": 1.0, "max_waiting": None}
        code, text = get("/trace")
        assert code == 200
        assert validate_chrome_trace(json.loads(text)) == []
        with pytest.raises(urllib.error.HTTPError):
            get("/nope")
    finally:
        srv.shutdown()


# ------------------------------------------------------------------ engine
def test_engine_latency_histograms_match_lifecycle(served):
    eng = served
    fin = eng.finished
    assert fin
    want_ttft = sorted((r.first_token_t - r.arrival) * 1e3 for r in fin)
    assert sorted(eng._h_ttft.samples()) == pytest.approx(want_ttft)
    want_wait = sorted((r.admitted_t - r.arrival) * 1e3 for r in fin)
    assert sorted(eng._h_queue_wait.samples()) == pytest.approx(want_wait)
    assert all(w >= 0 for w in want_wait)
    # ITL window feeds report() in ms, no double unit conversion
    rep = eng.report()
    assert rep["itl_p50_ms"] == pytest.approx(
        float(np.percentile(eng._h_itl.samples(), 50)))
    assert rep["queue_wait_p50_ms"] == pytest.approx(
        float(np.percentile(want_wait, 50)))


def test_engine_attribution_and_trace_export(served, tmp_path):
    eng = served
    attr = eng.attribution()
    assert attr["steps"] > 0
    assert attr["host_ms"] + attr["device_ms"] == \
        pytest.approx(attr["step_ms"])
    assert 0.0 <= attr["host_frac"] <= 1.0
    names = {s.name for s in eng.tracer.spans()}
    assert {"engine.step", "plan", "detokenize", "req.arrival",
            "req.admitted", "req.first_token", "req.finish"} <= names
    assert any(n.startswith("dispatch:") for n in names)
    out = tmp_path / "trace.json"
    eng.tracer.save(str(out))
    doc = json.loads(out.read_text())
    assert validate_chrome_trace(doc) == []
    assert len(doc["traceEvents"]) == len(eng.tracer.spans())


def test_report_health_served_from_registry(served):
    eng = served
    rep, health = eng.report(), eng.health()
    # the deduped robustness block: one source, both views, same names
    for k in ("step_time_ema_ms", "slow_steps", "dispatch_retries",
              "quarantined", "shed", "aborted", "deadline_expired",
              "block_utilization"):
        assert rep[k] == health[k]
    for k in ("waiting", "running", "free_blocks", "watermark_blocks",
              "probing_rids", "max_waiting"):
        assert k in health
    # counters flow through to the Prometheus exposition
    text = eng.obs.to_prometheus()
    assert f'repro_gen_tokens {eng.metrics["gen_tokens"]:g}' in text
    assert "repro_request_ttft_ms_bucket" in text
    json.dumps(eng.obs.snapshot(), allow_nan=False)


def test_telemetry_off_engine_still_serves(small):
    cfg, params = small
    eng = ServingEngine(cfg, params, max_slots=2, num_blocks=64,
                        max_blocks_per_seq=8, prefill_bucket=16,
                        enable_telemetry=False)
    eng.add([5, 9, 13, 2, 7], SamplingParams(max_tokens=3))
    rep = eng.run_until_done()
    assert len(eng.finished) == 1
    assert eng.tracer.spans() == []              # traced nothing
    attr = eng.attribution()
    assert attr["steps"] == 0.0                  # NaN columns, no crash
    assert rep["itl_p50_ms"] == rep["itl_p50_ms"]  # histograms still on
    assert eng.metrics["gen_tokens"] == 3


def test_straggler_events_bounded():
    det = StragglerDetector(threshold=1.5, patience=10**9)
    det.observe(0, 1.0)                          # seeds the EMA
    for i in range(1, 1002):
        det.observe(i, 10.0)                     # every step flagged
    assert len(det.events) == 256                # bounded, not a leak
    assert det.events[-1]["step"] == 1001
