"""BlockAllocator + device pool ops."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.paged_cache import (BlockAllocator, OutOfBlocksError,
                                    gather_kv, make_kv_pool, write_decode_kv,
                                    write_prefill_kv)


def test_alloc_free_refcount():
    a = BlockAllocator(8, 4)
    ids, _ = a.allocate_prompt(list(range(9)))     # 2 full + 1 partial
    assert len(ids) == 3 and a.num_free == 5
    a.free_sequence(ids)
    assert a.num_free == 8


def test_prefix_reuse_and_cow():
    a = BlockAllocator(16, 4)
    p = list(range(8))
    ids1, r1 = a.allocate_prompt(p + [100])
    ids2, r2 = a.allocate_prompt(p + [200])
    assert r1 == 0 and r2 == 2                     # two full blocks shared
    assert ids1[:2] == ids2[:2] and ids1[2] != ids2[2]
    # exact-multiple prompt: shared tail is full; append allocates fresh blk
    ids3, r3 = a.allocate_prompt(p)
    assert r3 == 2 and len(ids3) == 2
    ids3b, copied = a.append_slot(ids3, 8)
    assert len(ids3b) == 3 and copied is None


def test_out_of_blocks():
    a = BlockAllocator(2, 4, watermark_frac=0.0)
    with pytest.raises(OutOfBlocksError):
        a.allocate_prompt(list(range(100)))


def test_watermark_admission():
    a = BlockAllocator(10, 4)
    assert a.can_allocate(9)
    assert not a.can_allocate(10)


def test_pool_roundtrip_nonsequential_blocks():
    kp, _ = make_kv_pool(1, 8, 4, 2, 8, dtype=jnp.float32)
    bt = jnp.array([[5, 1], [7, 0]], jnp.int32)
    k = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 2, 8))
    kp = write_prefill_kv(kp, 0, k, bt, jnp.array([8, 6]))
    g = gather_kv(kp, 0, bt, 8)
    np.testing.assert_allclose(g[0], k[0])
    np.testing.assert_allclose(g[1, :6], k[1, :6])
    np.testing.assert_allclose(g[1, 6:], 0)


def test_decode_write_targets_correct_slot():
    kp, _ = make_kv_pool(2, 4, 4, 1, 4, dtype=jnp.float32)
    bt = jnp.array([[2, 3]], jnp.int32)
    kn = jnp.ones((1, 1, 4))
    kp = write_decode_kv(kp, 1, kn, bt, jnp.array([5]))
    assert float(kp[1, 3, 1].sum()) == 4.0          # block 3, offset 1
    assert float(kp.sum()) == 4.0                   # nothing else written


def test_register_full_block_and_grow_prefill_reuse():
    """Register-on-write: a block content-addressed after allocation is
    discoverable by both ``allocate_prompt`` and the continuation-chunk
    ``grow_prefill``; freeing the last reference unregisters it."""
    a = BlockAllocator(16, 4)
    p = list(range(12))
    ids, _ = a.allocate_prompt(p[:5])              # 1 hashed full + tail
    # the chunk that fills blocks 1 and 2 registers them afterwards
    ids, reused = a.grow_prefill(ids, 5, 7, p)
    assert reused == 0 and len(ids) == 3
    a.register_full_block(ids[1], p[:8])
    a.register_full_block(ids[2], p[:12])
    # re-registering / hash collisions are no-ops
    a.register_full_block(ids[1], p[:8])
    b_ids, r = a.allocate_prompt(p)                # whole prompt: 3 shared
    assert r == 3 and b_ids == ids[:3]
    # continuation growth also finds them
    c_ids, _ = a.allocate_prompt(p[:4])
    c_ids, r = a.grow_prefill(c_ids, 4, 8, p)
    assert r == 2 and c_ids == ids[:3]
    # a partially-covered tail block is never shared
    d_ids, _ = a.allocate_prompt(p[:4])
    d_ids, r = a.grow_prefill(d_ids, 4, 6, p)      # covers block 1, half 2
    assert r == 1 and d_ids[1] == ids[1] and d_ids[2] != ids[2]
    a.free_sequence(b_ids)
    a.free_sequence(c_ids)
    a.free_sequence(d_ids)
    a.free_sequence(ids)                           # last ref: hashes popped
    e_ids, r = a.allocate_prompt(p)
    assert r == 0


def test_gather_kv_bounded_matches_full_gather_on_live_prefix():
    """The bounded gather returns the full gather's bytes on every live
    position and zeros past the walked pages (bf16 and int8 pools)."""
    from repro.core.kv_quant import (KVCache, kv_gather, kv_gather_bounded,
                                     make_kv_pool_quant)
    rng = np.random.default_rng(0)
    L, NB, BS, KV, D, MB = 2, 10, 4, 2, 8, 5
    bt = jnp.asarray(rng.permutation(NB)[:MB][None], jnp.int32)
    kp, vp = make_kv_pool(L, NB, BS, KV, D, jnp.float32)
    kp = jnp.asarray(rng.normal(size=kp.shape), jnp.float32)
    vp = jnp.asarray(rng.normal(size=vp.shape), jnp.float32)
    cache = KVCache(kp, vp)
    total = 9                                      # 3 live pages of 5
    live = -(-total // BS)
    for li in range(L):
        kb, vb = kv_gather_bounded(cache, li, bt, MB * BS, live,
                                   jnp.float32)
        kf, vf = kv_gather(cache, li, bt, MB * BS, jnp.float32)
        np.testing.assert_array_equal(np.asarray(kb[:, :live * BS]),
                                      np.asarray(kf[:, :live * BS]))
        assert not np.any(np.asarray(kb[:, live * BS:]))
        np.testing.assert_array_equal(np.asarray(vb[:, :live * BS]),
                                      np.asarray(vf[:, :live * BS]))
    kq, vq, ks, vs = make_kv_pool_quant(L, NB, BS, KV, D)
    kq = jnp.asarray(rng.integers(-127, 128, kq.shape), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.01, 0.1, ks.shape), jnp.float32)
    qcache = KVCache(kq, kq, ks, ks)
    kb, _ = kv_gather_bounded(qcache, 1, bt, MB * BS, live, jnp.float32)
    kf, _ = kv_gather(qcache, 1, bt, MB * BS, jnp.float32)
    np.testing.assert_array_equal(np.asarray(kb[:, :live * BS]),
                                  np.asarray(kf[:, :live * BS]))
    assert not np.any(np.asarray(kb[:, live * BS:]))
