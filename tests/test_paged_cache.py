"""BlockAllocator + device pool ops."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.paged_cache import (BlockAllocator, OutOfBlocksError,
                                    gather_kv, make_kv_pool, write_decode_kv,
                                    write_prefill_kv)


def test_alloc_free_refcount():
    a = BlockAllocator(8, 4)
    ids, _ = a.allocate_prompt(list(range(9)))     # 2 full + 1 partial
    assert len(ids) == 3 and a.num_free == 5
    a.free_sequence(ids)
    assert a.num_free == 8


def test_prefix_reuse_and_cow():
    a = BlockAllocator(16, 4)
    p = list(range(8))
    ids1, r1 = a.allocate_prompt(p + [100])
    ids2, r2 = a.allocate_prompt(p + [200])
    assert r1 == 0 and r2 == 2                     # two full blocks shared
    assert ids1[:2] == ids2[:2] and ids1[2] != ids2[2]
    st = a.stats["allocated"]
    # exact-multiple prompt: shared tail is full; append allocates fresh blk
    ids3, r3 = a.allocate_prompt(p)
    assert r3 == 2 and len(ids3) == 2
    ids3b, copied = a.append_slot(ids3, 8)
    assert len(ids3b) == 3 and copied is None


def test_out_of_blocks():
    a = BlockAllocator(2, 4, watermark_frac=0.0)
    with pytest.raises(OutOfBlocksError):
        a.allocate_prompt(list(range(100)))


def test_watermark_admission():
    a = BlockAllocator(10, 4)
    assert a.can_allocate(9)
    assert not a.can_allocate(10)


def test_pool_roundtrip_nonsequential_blocks():
    kp, _ = make_kv_pool(1, 8, 4, 2, 8, dtype=jnp.float32)
    bt = jnp.array([[5, 1], [7, 0]], jnp.int32)
    k = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 2, 8))
    kp = write_prefill_kv(kp, 0, k, bt, jnp.array([8, 6]))
    g = gather_kv(kp, 0, bt, 8)
    np.testing.assert_allclose(g[0], k[0])
    np.testing.assert_allclose(g[1, :6], k[1, :6])
    np.testing.assert_allclose(g[1, 6:], 0)


def test_decode_write_targets_correct_slot():
    kp, _ = make_kv_pool(2, 4, 4, 1, 4, dtype=jnp.float32)
    bt = jnp.array([[2, 3]], jnp.int32)
    kn = jnp.ones((1, 1, 4))
    kp = write_decode_kv(kp, 1, kn, bt, jnp.array([5]))
    assert float(kp[1, 3, 1].sum()) == 4.0          # block 3, offset 1
    assert float(kp.sum()) == 4.0                   # nothing else written
