"""Checkpoint/restart, straggler detection, elastic re-mesh, data resume."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import AsyncCheckpointer, Checkpointer
from repro.configs.base import ShapeConfig
from repro.configs.registry import get_reduced
from repro.data.pipeline import SyntheticLM
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.runtime.fault import (PreemptionError, StragglerDetector,
                                 Supervisor)

KEY = jax.random.PRNGKey(0)


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_reduced("qwen2-1.5b", num_layers=2)
    params = T.init_params(cfg, KEY)
    opt = init_opt_state(params, AdamWConfig())
    ck = Checkpointer(str(tmp_path))
    ck.save(7, {"params": params, "opt": opt}, extra={"data": {"step": 7}})
    trees, extra = ck.restore(7, {"params": params, "opt": opt})
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 params, trees["params"])
    assert extra["data"]["step"] == 7
    assert trees["opt"].step == opt.step


def test_checkpoint_gc_keeps_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    x = {"w": jnp.ones((3,))}
    for s in (1, 2, 3, 4):
        ck.save(s, {"t": x})
    assert ck.all_steps() == [3, 4]


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    x = {"w": jnp.arange(5.0)}
    ck.save_async(3, {"t": x})
    ck.wait()
    trees, _ = ck.restore(3, {"t": x})
    np.testing.assert_array_equal(trees["t"]["w"], x["w"])


def test_supervisor_recovers_from_injected_failure(tmp_path):
    """Training survives a mid-run preemption and reaches total_steps."""
    ck = Checkpointer(str(tmp_path))

    def step_fn(step, st):
        st = dict(st)
        st["trees"] = {"v": {"x": st["trees"]["v"]["x"] + 1.0}}
        return st

    def restore_fn(last):
        trees, extra = ck.restore(last, {"v": {"x": jnp.zeros(())}})
        return {"step": last, "trees": trees, "extra": extra}

    failed = {"done": False}

    def fail_hook(step):
        if step == 7 and not failed["done"]:
            failed["done"] = True
            raise PreemptionError("node lost")

    sup = Supervisor(checkpointer=ck, save_every=5)
    final = sup.run(total_steps=12, state={"step": 0,
                                           "trees": {"v": {"x": jnp.zeros(())}},
                                           "extra": {}},
                    step_fn=step_fn, restore_fn=restore_fn,
                    fail_hook=fail_hook)
    assert sup.restarts == 1
    assert float(final["trees"]["v"]["x"]) == 12.0   # no lost or doubled steps


def test_straggler_detector_flags_slow_steps():
    d = StragglerDetector(threshold=2.0, patience=2)
    verdicts = [d.observe(i, 0.1) for i in range(5)]
    assert set(verdicts[1:]) == {"ok"}
    assert d.observe(5, 0.5) == "straggler"
    assert d.observe(6, 0.5) == "reslot"
    assert d.observe(7, 0.1) == "ok"


def test_data_pipeline_resumable():
    cfg = get_reduced("qwen2-1.5b")
    sh = ShapeConfig("t", 16, 4, "train")
    d1 = SyntheticLM(cfg, sh)
    d1.next_batch(); d1.next_batch()
    st = d1.state()
    b1 = d1.next_batch()
    d2 = SyntheticLM(cfg, sh)
    d2.restore(st)
    b2 = d2.next_batch()
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_elastic_restore_different_topology(tmp_path):
    """Checkpoint saved ignorant of topology restores onto any mesh."""
    cfg = get_reduced("qwen2-1.5b", num_layers=2)
    params = T.init_params(cfg, KEY)
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"params": params})
    from repro.runtime.sharding import make_ctx, param_shardings
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = make_ctx(mesh)
    sh = param_shardings(ctx, params, cfg)
    trees, _ = ck.restore(1, {"params": params}, shardings={"params": sh})
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 params, trees["params"])
