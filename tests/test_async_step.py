"""Async pipelined step: scheduler speculation (deviceless) and engine
pipeline parity / lifecycle tests.

The deviceless half drives ``Scheduler.speculate``/``reconcile``
directly through every mid-flight hazard — finish, abort, deadline
expiry, preemption, the capacity wall, block reservation — with no
model and no device arrays (the same style as ``test_scheduler.py``).
The engine half proves the pipelined loop (``enable_async_step=True``)
is token-exact against the read-back-every-step oracle on both KV
pools, under seeded faults with a poisoned in-flight dispatch, compiles
nothing new in the steady state, and shuts down cleanly via
``close()`` / the context manager.
"""
import numpy as np
import pytest

from repro.core.paged_cache import BlockAllocator
from repro.serving.params import SamplingParams
from repro.serving.scheduler import RequestState, Scheduler

BS = 4


def _sched(num_blocks=32, max_slots=3, mb=4, watermark_frac=0.0, **kw):
    alloc = BlockAllocator(num_blocks, BS, watermark_frac=watermark_frac)
    return Scheduler(alloc, max_slots=max_slots, max_blocks_per_seq=mb, **kw)


def _req(rid, n_prompt, max_tokens=8, **sp_kw):
    r = RequestState(rid=rid, prompt=list(range(1, n_prompt + 1)),
                     sampling=SamplingParams(max_tokens=max_tokens, **sp_kw))
    r.arrival = float(rid + 1)
    r.prompt_len0 = n_prompt
    return r


def _admit_one(s, rid=0, n_prompt=6, **kw):
    s.add(_req(rid, n_prompt, **kw))
    [q] = s.try_admit()
    q.seq_len += 1                 # first sampled token absorbed
    q.req.output.append(7)
    return q


# --------------------------------------------------------- speculation
def test_speculate_reconcile_roundtrip():
    s = _sched()
    q = _admit_one(s)
    len0, spec0 = q.seq_len, q.speculated
    s.speculate(q)
    assert q.seq_len == len0 + 1 and q.speculated == spec0 + 1
    s.reconcile(q)
    assert q.seq_len == len0 and q.speculated == spec0


def test_decodable_excludes_exhausted_speculated_slot():
    s = _sched()
    q = _admit_one(s, max_tokens=2)          # 1 left after first token
    assert 0 in s.decodable()
    s.speculate(q)                           # the last token is in flight
    assert 0 not in s.decodable()            # planning it would overrun
    assert s.plan_horizon(8) == 0
    s.reconcile(q)
    # non-speculating callers see the historical behavior unchanged
    assert 0 in s.decodable()


def test_finish_at_capacity_defers_speculated_slot():
    s = _sched(mb=2)                         # cap = 8 tokens
    q = _admit_one(s, n_prompt=8, max_tokens=8)   # seq_len 9: wall hit
    s.speculate(q)                           # ...but its token is in flight
    assert s.finish_at_capacity() == []      # deferred: token kept
    assert 0 not in s.decodable()            # and not planned either
    s.reconcile(q)                           # readback: engine absorbs
    q.seq_len += 1
    q.req.output.append(9)
    [fin] = s.finish_at_capacity()           # one step later, same output
    assert fin.finish_reason == "capacity" and fin.rid == 0


def test_abort_during_flight_discards_speculated():
    s = _sched()
    q = _admit_one(s)
    s.speculate(q)
    assert s.abort(0, "aborted") is q.req
    # the engine's collect identity check: the Sequence left `running`,
    # so the in-flight token is discarded, and everything it held is
    # already free again
    assert s.running.get(q.slot) is not q
    assert s.alloc.audit()["live_blocks"] == 0


def test_deadline_expiry_mid_flight_discards_speculated():
    s = _sched()
    q = _admit_one(s, deadline_ms=0.001)     # arrival far past: expired
    s.speculate(q)
    [fin] = s.expire_deadlines()
    assert fin.finish_reason == "deadline"
    assert s.running.get(q.slot) is not q    # collect discards the token
    assert s.alloc.audit()["live_blocks"] == 0


def test_preemption_of_speculated_slot_folds_absorbed_only():
    s = _sched()
    q = _admit_one(s)                        # output [7], speculated next
    s.speculate(q)
    s.preempt_youngest()
    # recompute replay folds prompt + ABSORBED output; the in-flight
    # token is not part of the fold — re-decoding from counts ==
    # len(output) regenerates it token-exactly
    assert s.waiting and s.waiting[0] is q.req
    assert q.req.prompt == list(range(1, 7)) + [7]
    assert s.running.get(q.slot) is not q
    assert s.alloc.audit()["live_blocks"] == 0


def test_speculated_growth_never_exceeds_watermark_headroom():
    # pool: 8 blocks, watermark 2.  One running sequence whose NEXT
    # (speculated) write needs a fresh block, plus a waiting prompt.
    s = _sched(num_blocks=8, max_slots=2, mb=4, watermark_frac=0.25)
    q = _admit_one(s, n_prompt=8, max_tokens=16)    # 2 full blocks + 1 spare
    s.speculate(q)                            # in-flight token: seq_len 10
    free0 = s.alloc.num_free
    s.add(_req(1, 12))
    plan = s.plan_step(max_num_batched_tokens=16, max_horizon=1)
    # the speculated slot's growth is reserved FIRST (decode priority),
    # then admission fills what watermarked headroom remains — exactly
    # the accounting the synchronous post-absorb plan would do
    grown = free0 - s.alloc.num_free
    assert s.alloc.num_free >= 0
    admitted_tokens = sum(c.length for c in plan.prefill)
    assert admitted_tokens <= max(0, (free0 - s.alloc.watermark)) * BS
    assert s.alloc.audit()["free_blocks"] == s.alloc.num_free
    assert grown >= 0 and plan.used <= plan.budget


# --------------------------------------------------------- engine-level
@pytest.fixture(scope="module")
def tiny():
    import jax
    from repro.configs.registry import get_reduced
    from repro.models import transformer as T
    cfg = get_reduced("qwen1.5-0.5b", num_layers=2, num_heads=4,
                      num_kv_heads=2)
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


def _engine(tiny, **kw):
    from repro.serving.engine import ServingEngine
    cfg, params = tiny
    return ServingEngine(cfg, params, max_slots=4, num_blocks=128,
                         max_blocks_per_seq=16, prefill_bucket=32,
                         max_num_batched_tokens=64, **kw)


def _drain(eng, prompts, sps):
    rids = [eng.add(p, sp) for p, sp in zip(prompts, sps)]
    finals = {}
    for out in eng.stream():
        if out.finished:
            finals[out.request_id] = out
    return {r: (tuple(finals[r].token_ids), finals[r].finish_reason)
            for r in rids}


def _prompts(seed, n=6):
    rng = np.random.default_rng(seed)
    return [list(rng.integers(1, 200, int(k)))
            for k in rng.integers(4, 90, n)]


@pytest.mark.parametrize("kv", ["bf16", "int8"])
def test_async_token_exact_vs_sync_oracle(tiny, kv, recompile_sentinel):
    prompts = _prompts(0)
    sps = [SamplingParams(max_tokens=10)] * 3 \
        + [SamplingParams(max_tokens=10, temperature=0.8, top_k=20,
                          seed=i) for i in range(3)]
    with _engine(tiny, kv_cache_dtype=kv, enable_async_step=True) as a:
        # warm one pipelined step, then arm: the steady state must not
        # compile anything new in either unified executable
        it = iter(prompts)
        got = _drain(a, prompts, sps)
        rep = a.report()
        del it
        recompile_sentinel.arm(a.runner, "async")
        got2 = _drain(a, _prompts(7), sps)
        assert a.alloc.audit()["live_blocks"] == 0
    with _engine(tiny, kv_cache_dtype=kv, enable_async_step=False) as s:
        want = _drain(s, prompts, sps)
        want2 = _drain(s, _prompts(7), sps)
    assert got == want and got2 == want2
    assert rep["async_steps"] > 0           # the pipeline actually engaged


def test_async_parity_under_poisoned_in_flight_dispatch(tiny):
    from repro.serving.faults import FaultInjector, FaultSpec
    prompts = _prompts(2, n=8)
    sps = [SamplingParams(max_tokens=10)] * 8

    def specs():
        # steps chosen so every victim is still live when its spec arms
        # (rids 0-3 drain by ~step 4 on this workload, 4-7 by ~step 9)
        return [FaultSpec("dispatch", step=1, rid=2),    # poisoned early
                FaultSpec("dispatch", step=5, rid=5),    # poisoned mid-pipe
                FaultSpec("dispatch", step=7, count=1),  # transient
                FaultSpec("nan", step=2, rid=1),         # in-flight NaN row
                FaultSpec("nan", step=5, rid=4),
                FaultSpec("alloc", step=6, count=2)]

    results = {}
    for mode in (True, False):
        eng = _engine(tiny, enable_async_step=mode,
                      fault_injector=FaultInjector(specs()))
        results[mode] = _drain(eng, prompts, sps)
        assert eng.alloc.audit()["live_blocks"] == 0
        eng.close()
    assert results[True] == results[False]
    reasons = {r for _, r in results[True].values()}
    assert "error" in reasons               # the poison really fired


def test_async_abort_mid_flight_token_exact(tiny):
    # abort rid 1 while its next token is provably IN FLIGHT
    # (speculated): the speculated token is discarded, the final event
    # carries exactly the absorbed prefix, and nothing leaks
    # n=8 keeps prefill chunks interleaving with decode long enough for
    # rid 1 to be caught decoding in a pipelined (speculating) step
    prompts = _prompts(2, n=8)
    sp = SamplingParams(max_tokens=12)
    with _engine(tiny, enable_async_step=False) as s:
        want = _drain(s, prompts, [sp] * 8)

    eng = _engine(tiny, enable_async_step=True)
    rids = [eng.add(p, sp) for p in prompts]
    outs, aborted_len = [], None
    while eng._work_pending():
        outs.extend(eng.step())
        if aborted_len is None:
            seq = next((q for q in eng.scheduler.running.values()
                        if q.req.rid == rids[1]), None)
            if seq is not None and seq.speculated \
                    and len(seq.req.output) >= 1:
                aborted_len = len(seq.req.output)   # in-flight tok NOT here
                assert eng.abort(rids[1])
    finals = {o.request_id: o for o in outs if o.finished}
    assert aborted_len is not None, "never caught rid 1 mid-flight"
    assert finals[rids[1]].finish_reason == "aborted"
    # token-exact prefix: the speculated token was discarded, every
    # absorbed token matches the unaborted oracle run token-for-token
    assert tuple(finals[rids[1]].token_ids) == \
        want[rids[1]][0][:aborted_len]
    for r in rids:
        if r != rids[1]:
            assert (tuple(finals[r].token_ids),
                    finals[r].finish_reason) == want[r]
    assert eng.alloc.audit()["live_blocks"] == 0
    eng.close()


def test_close_is_idempotent_and_flushes(tiny):
    eng = _engine(tiny, enable_async_step=True)
    for p in _prompts(4, n=3):
        eng.add(p, SamplingParams(max_tokens=4))
    eng.step()
    eng.step()                               # leave work in flight
    outs = eng.close()
    assert eng._flight is None and eng._detok is None
    assert all(hasattr(o, "request_id") for o in outs)
    assert eng.close() == []                 # idempotent
    assert eng.alloc.audit()["free_blocks"] >= 0


# --------------------------------------------------------- detok worker
def test_detok_worker_fifo_and_collect_discipline():
    from repro.obs.trace import NULL_TRACER
    from repro.serving.detok import DetokWorker

    w = DetokWorker(lambda toks: "".join(chr(97 + t % 26) for t in toks),
                    NULL_TRACER)
    reqs = [RequestState(rid=i, prompt=[1]) for i in range(3)]
    for i, r in enumerate(reqs):
        r.output = [i, i + 1]
        w.submit(r, [i, i + 1], False, None)
    assert w.pending() == 3
    first = w.collect_upto(2)
    assert [o.request_id for o in first] == [0, 1]     # FIFO, exactly 2
    rest = w.collect_all()
    assert [o.request_id for o in rest] == [2]
    assert w.pending() == 0 and w.collect_upto(5) == []
    assert reqs[0].text == first[0].text != ""
    w.close()


def test_detok_worker_exception_propagates():
    from repro.obs.trace import NULL_TRACER
    from repro.serving.detok import DetokWorker

    def boom(_toks):
        raise ValueError("bad detokenizer")

    w = DetokWorker(boom, NULL_TRACER)
    r = RequestState(rid=0, prompt=[1])
    r.output = [5]
    w.submit(r, [5], False, None)
    with pytest.raises(ValueError, match="bad detokenizer"):
        w.collect_upto(1)
