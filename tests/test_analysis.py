"""repro.analysis static-checker tests: every rule fires on a seeded
bad fixture and stays quiet on the corrected twin; finding keys /
baseline diffing / the allow-comment escape hatch; the CLI gate's exit
codes; and the R4 regression — the real Pallas wrappers' clamped page
walks must pass the very check that flags the seed bug's unclamped
walk.  Pure AST analysis: nothing here imports jax or runs device code.
"""
from pathlib import Path

import pytest

from repro.analysis import analyze_project, analyze_source
from repro.analysis.__main__ import main
from repro.analysis.findings import (Baseline, Finding, finalize_occurrences,
                                     load_baseline, write_baseline)
from repro.analysis.project import Project

REPO = Path(__file__).resolve().parent.parent


def kinds(findings):
    return sorted(f.kind for f in findings)


# ------------------------------------------------------------------ R1

R1_BAD = """
import jax.numpy as jnp
import numpy as np

def hot(x):
    y = jnp.exp(x)
    return np.asarray(y)
"""

R1_OK = """
import jax.numpy as jnp
import numpy as np

def hot(x):
    y = jnp.exp(x)
    n = y.shape[0]          # metadata: no device sync
    h = np.arange(n)        # host array: np.asarray is free
    return np.asarray(h), int(n)
"""


def test_r1_flags_device_readback():
    found = analyze_source(R1_BAD, rules=("R1",))
    assert len(found) == 1 and found[0].rule == "R1"
    assert "np.asarray" in found[0].detail
    assert found[0].qualname == "hot"


def test_r1_quiet_on_host_values_and_metadata():
    assert analyze_source(R1_OK, rules=("R1",)) == []


def test_r1_item_readback_and_device_branch():
    src = """
import jax.numpy as jnp

def hot(x):
    s = jnp.sum(x)
    if s:                   # implicit bool() on a device array
        return s.item()     # explicit sync
    return 0
"""
    found = analyze_source(src, rules=("R1",))
    assert len(found) == 2


# ------------------------------------------------------------------ R2

R2_BAD = """
import jax

def _step(p, s):
    return s

def run(p, s0):
    fn = jax.jit(_step, donate_argnums=(1,))
    out = fn(p, s0)
    return out + s0
"""

R2_OK = """
import jax

def _step(p, s):
    return s

def run(p, s0):
    fn = jax.jit(_step, donate_argnums=(1,))
    s0 = fn(p, s0)          # consume-and-replace: donated ref rebound
    return s0
"""


def test_r2_flags_read_after_donation():
    found = analyze_source(R2_BAD, rules=("R2",))
    assert kinds(found) == ["donation.use-after"]
    assert "`s0`" in found[0].detail


def test_r2_quiet_on_same_statement_rebind():
    assert analyze_source(R2_OK, rules=("R2",)) == []


def test_r2_flags_aliased_donation():
    src = """
import jax

def _step(a, b):
    return a

def run(x):
    fn = jax.jit(_step, donate_argnums=(0, 1))
    return fn(x, x)
"""
    found = analyze_source(src, rules=("R2",))
    assert kinds(found) == ["donation.alias"]


# ------------------------------------------------------------------ R3

R3_BAD = """
import jax
import numpy as np

def _model(p, b):
    return b

class Runner:
    def __init__(self):
        self._fn = jax.jit(_model)

    def serve(self, p, items):
        n = len(items)
        batch = np.zeros((n, 4), np.int32)
        return self._fn(p, batch)
"""

R3_OK = """
import jax
import numpy as np

def _model(p, b):
    return b

class Runner:
    def __init__(self):
        self._fn = jax.jit(_model)

    def serve(self, p, items):
        batch = np.zeros((4, 8), np.int32)   # fixed shape: one trace
        return self._fn(p, batch)
"""


def test_r3_flags_varying_shape_argument():
    found = analyze_source(R3_BAD, rules=("R3",))
    assert kinds(found) == ["retrace.varying-shape.batch"]


def test_r3_quiet_on_fixed_shapes():
    assert analyze_source(R3_OK, rules=("R3",)) == []


def test_r3_flags_unstable_static_argument():
    src = """
import jax

def _f(x, k):
    return x

class R:
    def __init__(self):
        self._fn = jax.jit(_f, static_argnames=("k",))

    def go(self, xs):
        n = len(xs)
        return self._fn(xs, k=n)
"""
    found = analyze_source(src, rules=("R3",))
    assert kinds(found) == ["retrace.unstable-static.k"]


# ------------------------------------------------------------------ R4

_R4_WRAPPER = """
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

B = 2
KV = 1
MB = 5
BS = 4
H = 2
D = 4


def _clamp_live(i, live, bs):
    last = (live + bs - 1) // bs - 1
    last = max(last, 0)
    return min(i, last)


def _kernel(bt_ref, sl_ref, q_ref, k_ref, o_ref):
    o_ref[...] = q_ref[...].astype(o_ref.dtype)


def walk(bt, sl, q, kpages):
    grid = (B, KV, MB)
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, H, D), lambda b, kv, i, bt, sl: (b, 0, 0)),
                pl.BlockSpec((1, BS, D),
                             lambda b, kv, i, bt, sl: ({COL}, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, H, D),
                                   lambda b, kv, i, bt, sl: (b, 0, 0)),
            scratch_shapes=[],
        ),
        out_shape=jax.ShapeDtypeStruct((1, H, D), q.dtype),
    )(bt, sl, q, kpages)
"""

# the seed bug: the page walk strides the whole table width regardless
# of how many pages are actually live for the sequence
R4_BAD = _R4_WRAPPER.replace("{COL}", "bt[b, i]")
# the fix: clamp the walk to the live prefix
R4_OK = _R4_WRAPPER.replace("{COL}", "bt[b, _clamp_live(i, sl[b], BS)]")


def test_r4_flags_unclamped_page_walk():
    found = analyze_source(R4_BAD, rules=("R4",))
    assert kinds(found) == ["kernel.page-walk-unbounded.<lambda>"]
    assert "live" in found[0].detail


def test_r4_clamped_page_walk_passes():
    assert analyze_source(R4_OK, rules=("R4",)) == []


def test_r4_flags_index_map_arity():
    src = R4_OK.replace("lambda b, kv, i, bt, sl: (b, 0, 0)",
                        "lambda b, kv, i: (b, 0, 0)", 1)
    found = analyze_source(src, rules=("R4",))
    assert "kernel.index-map-arity.<lambda>" in kinds(found)


def test_r4_flags_kernel_body_arity():
    src = R4_OK.replace("def _kernel(bt_ref, sl_ref, q_ref, k_ref, o_ref):",
                        "def _kernel(bt_ref, sl_ref, q_ref, o_ref):")
    found = analyze_source(src, rules=("R4",))
    assert kinds(found) == ["kernel.body-arity._kernel"]


def test_r4_flags_operand_count():
    src = R4_OK.replace(")(bt, sl, q, kpages)", ")(bt, sl, q)")
    found = analyze_source(src, rules=("R4",))
    assert kinds(found) == ["kernel.operand-count"]


def test_r4_flags_missing_out_astype():
    src = """
import jax
from jax.experimental import pallas as pl

def _k(x_ref, o_ref):
    acc = x_ref[...] * 2
    o_ref[...] = acc

def mm(x):
    return pl.pallas_call(
        _k, grid=(1,),
        in_specs=[pl.BlockSpec((4,), lambda i: (0,))],
        out_specs=pl.BlockSpec((4,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((4,), x.dtype),
    )(x)
"""
    found = analyze_source(src, rules=("R4",))
    assert kinds(found) == ["kernel.out-dtype"]


def test_r4_real_kernels_pass_clean():
    """Regression: the repo's own Pallas wrappers (whose clamped page
    walks ARE the fix for the seed bug this rule encodes) produce zero
    kernel-contract findings."""
    project = Project.from_root(REPO, subdir="src/repro")
    assert analyze_project(project, rules=("R4",)) == []


# ------------------------------------------------------------------ R5

R5_BAD = """
import jax

@jax.jit
def f(x):
    if x > 0:
        return x
    return -x
"""

R5_OK = """
import functools

import jax

@functools.partial(jax.jit, static_argnames=("flag",))
def g(x, flag):
    if flag:                # static arg: python-level branch is fine
        x = x * 2
    if x.shape[0] > 2:      # shape metadata: trace-static
        x = x + 1
    return x
"""


def test_r5_flags_traced_branch():
    found = analyze_source(R5_BAD, rules=("R5",))
    assert kinds(found) == ["flow.traced-branch"]
    assert "x > 0" in found[0].detail


def test_r5_quiet_on_static_and_metadata_branches():
    assert analyze_source(R5_OK, rules=("R5",)) == []


# -------------------------------------------------- keys and baseline

def test_allow_comment_suppresses_finding():
    src = R1_BAD.replace(
        "return np.asarray(y)",
        "return np.asarray(y)  # repro: allow[R1] planned readback")
    assert analyze_source(src, rules=("R1",)) == []


def test_occurrence_numbering_disambiguates_identical_sites():
    src = """
import jax.numpy as jnp
import numpy as np

def hot(x):
    a = np.asarray(jnp.exp(x))
    b = np.asarray(jnp.exp(x))
    return a, b
"""
    found = analyze_source(src, rules=("R1",))
    assert [f.occurrence for f in found] == [0, 1]
    assert len({f.key for f in found}) == 2


def test_finding_key_excludes_line_numbers():
    a = Finding("R1", "m.py", "f", "sync.x", "detail", line=10)
    b = Finding("R1", "m.py", "f", "sync.x", "detail", line=99)
    assert a.key == b.key


def test_finalize_occurrences_orders_by_source_position():
    raw = [Finding("R1", "m.py", "f", "k", "d", line=30),
           Finding("R1", "m.py", "f", "k", "d", line=10)]
    out = finalize_occurrences(raw)
    assert [(f.line, f.occurrence) for f in out] == [(10, 0), (30, 1)]


def test_baseline_diff_and_validate():
    f_known = Finding("R1", "m.py", "f", "k", "d", line=1)
    f_new = Finding("R2", "m.py", "g", "k2", "d", line=2)
    base = Baseline(entries={f_known.key: {"justification": "planned"},
                             "R9:gone.py:h:k:0": {"justification": "x"}})
    new, known, stale = base.diff([f_known, f_new])
    assert [f.key for f in new] == [f_new.key]
    assert [f.key for f in known] == [f_known.key]
    assert stale == ["R9:gone.py:h:k:0"]
    assert base.validate() == []
    base.entries[f_known.key]["justification"] = "  "
    assert base.validate() == [f_known.key]


def test_baseline_io_round_trip(tmp_path):
    path = tmp_path / "baseline.json"
    f = Finding("R1", "m.py", "f", "k", "d", line=1)
    write_baseline(path, [f])
    base = load_baseline(path)
    assert base.justification(f.key) == ""          # must be filled in
    base.entries[f.key]["justification"] = "because"
    # regeneration carries the justification forward
    write_baseline(path, [f], previous=base)
    assert load_baseline(path).justification(f.key) == "because"


def test_baseline_rejects_unknown_version(tmp_path):
    path = tmp_path / "baseline.json"
    path.write_text('{"version": 99, "findings": {}}')
    with pytest.raises(ValueError, match="version"):
        load_baseline(path)


# --------------------------------------------------------------- CLI

def _cli(*extra):
    return main(["--repo", str(REPO), "--root", "src/repro", *extra])


def test_cli_exits_zero_against_checked_in_baseline(capsys):
    assert _cli("--baseline", str(REPO / "analysis" / "baseline.json")) == 0
    out = capsys.readouterr().out
    assert "0 new" in out and "0 unjustified" in out


def test_cli_fails_without_baseline(capsys):
    # the tree carries justified findings: with no baseline they are new
    assert _cli() == 1
    assert "[NEW]" in capsys.readouterr().out


def test_cli_rejects_unknown_rule():
    assert _cli("--rules", "R1,R9") == 2


def test_cli_rejects_missing_root():
    assert main(["--repo", str(REPO), "--root", "no/such/dir"]) == 2


def test_cli_update_baseline_then_gate(tmp_path, capsys):
    """--update-baseline writes every current finding with an empty
    justification, and the gate then fails until they are filled in —
    an unjustified suppression is itself a failure."""
    path = tmp_path / "baseline.json"
    assert _cli("--baseline", str(path), "--update-baseline") == 0
    assert _cli("--baseline", str(path)) == 1
    assert "unjustified" in capsys.readouterr().out
    base = load_baseline(path)
    for entry in base.entries.values():
        entry["justification"] = "test"
    import json
    path.write_text(json.dumps(
        {"version": 1, "findings": base.entries}))
    assert _cli("--baseline", str(path)) == 0
