"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (CI pins CPU jax only)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import QuantConfig
from repro.core.gqa import grouped_attention
from repro.core.gptq import gptq_quantize
from repro.core.paged_cache import BlockAllocator
from repro.core.quant import pack_int4, unpack_int4

SET = dict(max_examples=25, deadline=None)


@settings(**SET)
@given(st.integers(1, 3), st.integers(2, 24), st.integers(1, 4),
       st.integers(1, 4), st.data())
def test_attention_is_convex_combination(B, S, KV, G, data):
    """Every output lies in the convex hull of V rows -> bounded by V."""
    H = KV * G
    D = 8
    seed = data.draw(st.integers(0, 2**30))
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    o = grouped_attention(q, k, v, causal=True)
    assert float(o.max()) <= float(v.max()) + 1e-4
    assert float(o.min()) >= float(v.min()) - 1e-4


@settings(**SET)
@given(st.integers(0, 2**30), st.integers(1, 16), st.integers(1, 30))
def test_pack_roundtrip_property(seed, dout, din):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 16, size=(din, dout)).astype(np.uint8)
    got = np.asarray(unpack_int4(jnp.asarray(pack_int4(codes)), din))
    np.testing.assert_array_equal(got, codes)


@settings(**SET)
@given(st.integers(0, 2**30), st.lists(st.integers(1, 40), min_size=1,
                                       max_size=12))
def test_allocator_conservation(seed, lens):
    """free + live == total, always; free-all restores everything."""
    a = BlockAllocator(256, 4, watermark_frac=0.0)
    rng = np.random.default_rng(seed)
    live = []
    for n in lens:
        toks = rng.integers(0, 50, n).tolist()
        ids, _ = a.allocate_prompt(toks)
        live.append(ids)
    # physical-block conservation (shared blocks counted once)
    phys = {b for ids in live for b in ids}
    assert a.num_free + len(phys) == a.num_blocks
    for ids in live:
        a.free_sequence(ids)
    assert a.num_free == a.num_blocks


@settings(**SET)
@given(st.integers(0, 2**30))
def test_gptq_monotone_bits(seed):
    """More bits never increases quantization error."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(16, 8))
    errs = []
    for bits in (2, 4, 8):
        qt = gptq_quantize(w, None, QuantConfig(bits=bits, group_size=16,
                                                act_order=False))
        errs.append(np.abs(qt.dequant() - w).mean())
    assert errs[0] >= errs[1] >= errs[2]


@settings(**SET)
@given(st.integers(0, 2**30), st.integers(1, 64))
def test_prefix_reuse_shares_only_full_blocks(seed, n):
    a = BlockAllocator(128, 4)
    rng = np.random.default_rng(seed)
    p = rng.integers(0, 9, n).tolist()
    ids1, _ = a.allocate_prompt(p)
    ids2, reused = a.allocate_prompt(p)
    assert reused == n // 4                  # all full blocks shared
    full = n // 4
    assert ids1[:full] == ids2[:full]
    if n % 4:
        assert ids1[full] != ids2[full]      # partial tails never shared


@settings(**SET)
@given(st.integers(0, 2**30), st.integers(1, 16), st.integers(1, 4),
       st.integers(1, 32), st.floats(-4, 4))
def test_kv_quant_roundtrip_bounded(seed, BS, KV, D, log_mag):
    """int8 KV roundtrip: every live value within scale/2; dead slots and
    on-grid values exact."""
    from repro.core.kv_quant import dequantize_blocks, quantize_blocks
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, BS, KV, D)) * 10.0 ** log_mag,
                    jnp.float32)
    live = jnp.asarray(rng.random((2, BS)) < 0.7)
    q, scales = quantize_blocks(x, live)
    deq = dequantize_blocks(q, scales)
    err = jnp.abs(jnp.where(live[..., None, None], x, 0.0) - deq)
    assert bool(jnp.all(err <= (scales / 2 * (1 + 1e-5))[:, None, :, None]))
    assert bool(jnp.all(jnp.where(live[..., None, None], 0.0, deq) == 0))
    # a second pass over the dequantized values is a fixed point when the
    # scale is unchanged (round(int) == int) -- no drift without growth
    q2, scales2 = quantize_blocks(deq, live)
    np.testing.assert_array_equal(np.asarray(q2), np.asarray(q))
