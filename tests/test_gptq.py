"""GPTQ algorithm + packing + quantized linear."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import QuantConfig
from repro.core.gptq import (HessianAccumulator, gptq_quantize, quant_error,
                             rtn_quantize)
from repro.core.quant import (make_quant_params, pack_int4,
                              quant_matmul_ref, unpack_int4)


def _problem(rng, din=64, dout=32, n=512):
    x = rng.normal(size=(n, din)) * (1 + 3 * rng.random(din))
    w = rng.normal(size=(din, dout))
    h = 2 * x.T @ x / n
    return x, w, h


def test_gptq_beats_rtn_under_hessian_loss(rng):
    _, w, h = _problem(rng)
    cfg = QuantConfig(bits=4, group_size=32)
    e_gptq = quant_error(w, gptq_quantize(w, h, cfg), h)
    e_rtn = quant_error(w, rtn_quantize(w, cfg), h)
    assert e_gptq < e_rtn


def test_gptq_act_order_helps_or_ties(rng):
    _, w, h = _problem(rng)
    e_ao = quant_error(w, gptq_quantize(w, h, QuantConfig(group_size=32)), h)
    e_no = quant_error(w, gptq_quantize(
        w, h, QuantConfig(group_size=32, act_order=False)), h)
    assert e_ao <= e_no * 1.5


def test_dequant_within_scale_bound(rng):
    _, w, h = _problem(rng)
    qt = gptq_quantize(w, h, QuantConfig(group_size=32))
    err = np.abs(qt.dequant() - w)
    # per-element error bounded by its group scale (error feedback moves
    # error BETWEEN columns, so allow 4x slack)
    bound = qt.scales[qt.g_idx] * 4.0
    assert (err <= bound + 1e-6).mean() > 0.99


def test_hessian_accumulator_streaming(rng):
    x = rng.normal(size=(100, 16))
    h1 = HessianAccumulator(16)
    h1.update(x)
    h2 = HessianAccumulator(16)
    h2.update(x[:50]); h2.update(x[50:])
    np.testing.assert_allclose(h1.h, h2.h, rtol=1e-10)


@pytest.mark.parametrize("din,dout", [(8, 4), (64, 32), (120, 16)])
def test_pack_unpack_roundtrip(rng, din, dout):
    codes = rng.integers(0, 16, size=(din, dout)).astype(np.uint8)
    got = np.asarray(unpack_int4(jnp.asarray(pack_int4(codes)), din))
    np.testing.assert_array_equal(got, codes)


def test_quant_matmul_ref_matches_dequant(rng):
    _, w, h = _problem(rng, 32, 16)
    qt = gptq_quantize(w, h, QuantConfig(group_size=16))
    p = make_quant_params(qt)
    x = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
    y = quant_matmul_ref(x, p)
    yref = np.asarray(x) @ qt.dequant()
    np.testing.assert_allclose(y, yref, rtol=1e-4, atol=1e-4)
