"""Opt-GQA core semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.alibi import alibi_bias, alibi_slopes
from repro.core.gqa import (decode_attention, grouped_attention,
                            grouped_attention_chunked, mha_attention)


def _qkv(key, B, S, H, KV, D):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (B, S, H, D)),
            jax.random.normal(ks[1], (B, S, KV, D)),
            jax.random.normal(ks[2], (B, S, KV, D)))


def test_gqa_equals_mha_with_repeated_kv():
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 16, 8, 2, 16)
    o1 = grouped_attention(q, k, v)
    o2 = mha_attention(q, jnp.repeat(k, 4, 2), jnp.repeat(v, 4, 2))
    np.testing.assert_allclose(o1, o2, atol=1e-5)


def test_causality():
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 12, 4, 4, 8)
    o1 = grouped_attention(q, k, v, causal=True)
    k2 = k.at[:, 7:].set(99.0)     # future keys must not matter for pos<7
    v2 = v.at[:, 7:].set(-99.0)
    o2 = grouped_attention(q, k2, v2, causal=True)
    np.testing.assert_allclose(o1[:, :7], o2[:, :7], atol=1e-5)


def test_sliding_window_blinds_far_past():
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 32, 4, 2, 8)
    o1 = grouped_attention(q, k, v, sliding_window=4)
    k2 = k.at[:, :16].set(7.0)     # beyond window for positions >= 20
    v2 = v.at[:, :16].set(-7.0)
    o2 = grouped_attention(q, k2, v2, sliding_window=4)
    np.testing.assert_allclose(o1[:, 24:], o2[:, 24:], atol=1e-5)


def test_softmax_rows_normalized_uniform_v():
    # with all values equal, output must equal that value (weights sum to 1)
    q, k, _ = _qkv(jax.random.PRNGKey(3), 2, 8, 4, 2, 8)
    v = jnp.ones((2, 8, 2, 8)) * 3.0
    o = grouped_attention(q, k, v)
    np.testing.assert_allclose(o, jnp.full_like(o, 3.0), rtol=1e-5)


def test_alibi_slopes_power_of_two_and_not():
    s8 = alibi_slopes(8)
    assert s8.shape == (8,) and float(s8[0]) == pytest.approx(2 ** -1)
    s12 = alibi_slopes(12)
    assert s12.shape == (12,) and bool(jnp.all(s12 > 0))


def test_alibi_bias_never_materializes_positive():
    b = alibi_bias(alibi_slopes(4), jnp.arange(6), jnp.arange(6))
    assert float(b.max()) <= 0.0
    assert b.shape == (4, 6, 6)


def test_chunked_matches_exact():
    q, k, v = _qkv(jax.random.PRNGKey(4), 1, 700, 4, 2, 16)
    sl = alibi_slopes(4)
    a = grouped_attention(q, k, v, causal=True, alibi_slopes=sl)
    b = grouped_attention_chunked(q, k, v, causal=True, alibi_slopes=sl,
                                  block_q=256)
    np.testing.assert_allclose(a, b, atol=2e-5)


def test_decode_matches_full_last_position():
    B, S, H, KV, D = 2, 10, 4, 2, 8
    q, k, v = _qkv(jax.random.PRNGKey(5), B, S, H, KV, D)
    full = grouped_attention(q, k, v, causal=True)
    o = decode_attention(q[:, -1], k, v, jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(o, full[:, -1], atol=1e-5)
