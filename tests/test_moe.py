"""MoE: ragged-dot routed path vs dense oracle, shared experts, padding."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_reduced
from repro.models.moe import (moe_apply, moe_apply_dense_ref, moe_init,
                              padded_experts)

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    return get_reduced("qwen2-moe-a2.7b", **kw)


def test_ragged_matches_dense_oracle():
    cfg = _cfg()
    p = moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model))
    np.testing.assert_allclose(
        np.asarray(moe_apply(cfg, p, x, None)),
        np.asarray(moe_apply_dense_ref(cfg, p, x)), atol=2e-5)


def test_shared_expert_contributes():
    cfg = _cfg()
    p = moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 4, cfg.d_model))
    y1 = moe_apply(cfg, p, x, None)
    p2 = dict(p)
    p2["ws_down"] = jnp.zeros_like(p["ws_down"])
    y2 = moe_apply(cfg, p2, x, None)
    assert float(jnp.abs(y1 - y2).max()) > 1e-4


def test_expert_padding():
    cfg = _cfg(num_experts=6)
    assert padded_experts(cfg, 4) == 8
    p = moe_init(KEY, cfg, ep=4)
    assert p["we_gate"].shape[0] == 8
    assert p["router"].shape[1] == 6           # router never routes to pads
    x = jax.random.normal(KEY, (1, 4, cfg.d_model))
    y = moe_apply(cfg, p, x, None)
    assert bool(jnp.isfinite(y).all())


def test_top1_routing_selects_argmax_expert():
    cfg = _cfg(moe_top_k=1, num_shared_experts=0)
    p = moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 6, cfg.d_model))
    y = moe_apply(cfg, p, x, None)
    ref = moe_apply_dense_ref(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-5)
