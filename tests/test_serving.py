"""Continuous-batching engine end-to-end on a tiny model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_reduced
from repro.models import transformer as T
from repro.serving.engine import Request, ServingEngine

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def small():
    cfg = get_reduced("qwen2-1.5b", num_layers=2)
    params = T.init_params(cfg, KEY)
    return cfg, params


def _prompts(n, rng, prefix_len=0):
    prefix = list(rng.integers(1, 200, prefix_len)) if prefix_len else []
    return [prefix + list(rng.integers(1, 200, int(rng.integers(3, 15))))
            for _ in range(n)]


def test_engine_completes_all(small):
    cfg, params = small
    eng = ServingEngine(cfg, params, max_slots=4, num_blocks=128,
                        max_blocks_per_seq=8, prefill_bucket=16)
    rng = np.random.default_rng(0)
    for i, p in enumerate(_prompts(9, rng)):
        eng.add_request(Request(rid=i, prompt=p, max_new_tokens=6))
    rep = eng.run_until_done()
    assert len(eng.finished) == 9
    assert all(len(r.output) == 6 for r in eng.finished)
    assert rep["generate_tok_s"] > 0


def test_engine_greedy_matches_model(small):
    """Engine (paged, batched) greedy decode == direct model argmax."""
    cfg, params = small
    eng = ServingEngine(cfg, params, max_slots=2, num_blocks=64,
                        max_blocks_per_seq=8, prefill_bucket=8)
    prompt = [5, 9, 13, 2, 7]
    eng.add_request(Request(rid=0, prompt=prompt, max_new_tokens=4))
    eng.run_until_done()
    got = eng.finished[0].output
    toks = list(prompt)
    for _ in range(4):
        logits = T.forward(cfg, params, {"tokens": jnp.asarray([toks])})
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert got == toks[len(prompt):]


def test_prefix_reuse_across_requests(small):
    cfg, params = small
    eng = ServingEngine(cfg, params, max_slots=4, num_blocks=128,
                        max_blocks_per_seq=8, prefill_bucket=32)
    rng = np.random.default_rng(1)
    for i, p in enumerate(_prompts(6, rng, prefix_len=16)):
        eng.add_request(Request(rid=i, prompt=p, max_new_tokens=3))
    eng.run_until_done()
    assert eng.alloc.stats["reused"] > 0


def test_block_exhaustion_queues_requests(small):
    cfg, params = small
    eng = ServingEngine(cfg, params, max_slots=4, num_blocks=12,
                        max_blocks_per_seq=6, prefill_bucket=16)
    rng = np.random.default_rng(2)
    for i, p in enumerate(_prompts(8, rng)):
        eng.add_request(Request(rid=i, prompt=p, max_new_tokens=4))
    eng.run_until_done(max_steps=500)
    assert len(eng.finished) == 8          # everyone eventually served
    assert eng.alloc.num_free >= 0
