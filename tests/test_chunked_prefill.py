"""Token-budget chunked prefill vs the stop-the-world whole-prompt
oracle: greedy token-exactness (bf16 AND int8 KV pools), fused/legacy
bitwise parity within chunked mode, preemption mid-prefill, the
single-compile guarantee of the fixed-shape chunk executable, and the
direct transformer-level chunk-vs-prefill check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_reduced
from repro.core.kv_quant import cache_from_state, cache_to_state
from repro.models import transformer as T
from repro.serving import SamplingParams, ServingEngine

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def small():
    cfg = get_reduced("qwen1.5-0.5b", num_layers=2)
    params = T.init_params(cfg, KEY)
    return cfg, params


def _prompts(n, seed=0, lo=4, hi=20):
    rng = np.random.default_rng(seed)
    return [list(rng.integers(1, 200, int(rng.integers(lo, hi))))
            for _ in range(n)]


def _engine(cfg, params, **kw):
    kw.setdefault("max_slots", 3)
    kw.setdefault("num_blocks", 64)
    kw.setdefault("max_blocks_per_seq", 8)
    kw.setdefault("prefill_bucket", 16)
    return ServingEngine(cfg, params, **kw)


def _drain(eng, prompts, sps):
    for p, sp in zip(prompts, sps):
        eng.add(p, sp)
    eng.run_until_done()
    return {r.rid: list(r.output) for r in eng.finished}, \
        {r.rid: r.finish_reason for r in eng.finished}


# --------------------------------------------------- transformer-level parity

def test_prefill_chunk_executable_matches_whole_prompt():
    """The fixed-shape chunk executable reproduces T.prefill: identical
    pool contents (bf16 exactly — the chunk overlays its raw K/V like
    the whole-prompt path writes them) and matching last-token argmax,
    from ONE compile across chunk offsets and live lengths."""
    cfg = get_reduced("qwen1.5-0.5b", num_layers=2)
    params = T.init_params(cfg, KEY)
    mb, nb = 8, 32
    S, W = 23, 8
    toks = np.asarray(jax.random.randint(jax.random.fold_in(KEY, 1),
                                         (1, S), 1, cfg.vocab_size))
    bt = np.zeros((1, mb), np.int32)
    bt[0, :4] = [3, 5, 1, 7]
    st = T.make_decode_state(cfg, 1, nb, mb, dtype=jnp.float32)
    st["block_table"] = jnp.asarray(bt)
    pad = np.zeros((1, 32), np.int32)
    pad[0, :S] = toks[0]
    l_ref, s_ref = T.prefill(cfg, params, dict(st),
                             {"tokens": jnp.asarray(pad),
                              "ctx_lens": jnp.asarray([S])})
    fn = jax.jit(lambda p, c, t, b, o, tl: T.prefill_chunk(
        cfg, p, c, t, b, o, tl))
    cache = cache_from_state(st)
    for off in range(0, S, W):
        n = min(W, S - off)
        tc = np.zeros((1, W), np.int32)
        tc[0, :n] = toks[0, off:off + n]
        logits, cache = fn(params, cache, jnp.asarray(tc), jnp.asarray(bt),
                           jnp.int32(off), jnp.int32(off + n))
    s_chk = cache_to_state(cache)
    np.testing.assert_array_equal(np.asarray(s_ref["k_pool"]),
                                  np.asarray(s_chk["k_pool"]))
    np.testing.assert_array_equal(np.asarray(s_ref["v_pool"]),
                                  np.asarray(s_chk["v_pool"]))
    np.testing.assert_allclose(np.asarray(l_ref), np.asarray(logits),
                               atol=2e-2)
    assert int(jnp.argmax(l_ref[0])) == int(jnp.argmax(logits[0]))
    assert fn._cache_size() == 1          # every chunk hit one executable


def test_prefill_chunk_rejects_non_full_attention_archs():
    assert not T.supports_chunked_prefill(get_reduced("falcon-mamba-7b"))
    assert not T.supports_chunked_prefill(get_reduced("h2o-danube-3-4b"))
    assert not T.supports_chunked_prefill(get_reduced("recurrentgemma-2b"))
    # encoders are full-attention-homogeneous but bidirectional: no
    # causal chunk decomposition, no KV cache — must not claim support
    assert not T.supports_chunked_prefill(get_reduced("hubert-xlarge"))
    assert T.supports_chunked_prefill(get_reduced("qwen2-moe-a2.7b"))


# ------------------------------------------------------- engine-level parity

@pytest.mark.parametrize("kv_cache_dtype", ["bf16", "int8"])
def test_chunked_serving_token_exact_vs_oracle(small, kv_cache_dtype):
    """Acceptance: multi-chunk greedy serving (budget far below the
    prompt lengths) is token-exact against the whole-prompt oracle, for
    the dense AND the int8-quantized KV pool."""
    cfg, params = small
    prompts = _prompts(5, seed=21, lo=24, hi=60)      # several chunks each
    sps = [SamplingParams(max_tokens=10)] * 5
    o_ref, f_ref = _drain(
        _engine(cfg, params, enable_chunked_prefill=False,
                kv_cache_dtype=kv_cache_dtype), prompts, sps)
    eng = _engine(cfg, params, max_num_batched_tokens=16,
                  kv_cache_dtype=kv_cache_dtype)
    o_chk, f_chk = _drain(eng, prompts, sps)
    assert eng.metrics["prefill_chunks"] > len(prompts)   # really chunked
    assert o_ref == o_chk and f_ref == f_chk
    assert eng.runner.prefill_compiles() == 1


def test_chunked_fused_matches_chunked_legacy_bitwise(small):
    """Within chunked mode the fused megastep and the legacy loop stay
    bitwise-identical across mixed sampling modes (the decode halves are
    untouched by the prefill refactor)."""
    cfg, params = small
    prompts = _prompts(4, seed=31, lo=20, hi=40)
    sps = [SamplingParams(max_tokens=8),
           SamplingParams(temperature=0.9, max_tokens=8),
           SamplingParams(temperature=0.8, top_k=5, max_tokens=8),
           SamplingParams(temperature=0.7, top_p=0.9, seed=7, max_tokens=8)]
    o_leg, _ = _drain(_engine(cfg, params, use_fused=False,
                              max_num_batched_tokens=16), prompts, sps)
    o_fus, _ = _drain(_engine(cfg, params, use_fused=True,
                              max_num_batched_tokens=16), prompts, sps)
    assert o_leg == o_fus


def test_chunked_interleaves_decode_with_long_prefill(
        small, recompile_sentinel):
    """A long prompt arriving over a decoding batch no longer stalls it:
    decode tokens keep flowing between its chunks (the ITL bound) — and
    the warm executables compile nothing new while it chunks."""
    cfg, params = small
    eng = _engine(cfg, params, max_num_batched_tokens=12, max_slots=2,
                  num_blocks=128, max_blocks_per_seq=16)
    eng.add(_prompts(1, seed=41)[0], SamplingParams(max_tokens=40))
    for _ in range(3):                     # short prompt is decoding now
        eng.step()
    recompile_sentinel.arm(eng.runner, "interleaved")
    long_prompt = _prompts(1, seed=42, lo=60, hi=61)[0]
    rid = eng.add(long_prompt, SamplingParams(max_tokens=4))
    decoded_during_prefill = 0
    while any(s.prefilling for s in eng.running.values()) or \
            any(r.rid == rid for r in eng.waiting):
        before = eng.metrics["gen_tokens"]
        eng.step()
        if any(s.prefilling for s in eng.running.values()):
            decoded_during_prefill += eng.metrics["gen_tokens"] - before
    assert decoded_during_prefill > 0      # decode never stopped
    eng.run_until_done()
    assert {r.finish_reason for r in eng.finished} <= {"length", "stop"}


@pytest.mark.parametrize("kv_cache_dtype", ["bf16", "int8"])
def test_preemption_mid_prefill_parity(small, kv_cache_dtype):
    """A block-starved run that preempts a sequence *mid-prefill*
    (partially-computed KV freed, chunk walk restarted from zero on
    re-admission) still matches the roomy run token-for-token."""
    cfg, params = small
    rng = np.random.default_rng(51)
    # two decoders plus one long prompt whose chunk walk is still in
    # flight when decode growth exhausts the 9-block pool
    prompts = [list(rng.integers(1, 200, n)) for n in (28, 28, 64)]
    sps = [SamplingParams(max_tokens=24)] * 3
    roomy, _ = _drain(
        _engine(cfg, params, max_num_batched_tokens=8, num_blocks=256,
                kv_cache_dtype=kv_cache_dtype), prompts, sps)
    eng = _engine(cfg, params, max_num_batched_tokens=8, num_blocks=9,
                  kv_cache_dtype=kv_cache_dtype)
    tight, _ = _drain(eng, prompts, sps)
    assert eng.metrics["preemptions_mid_prefill"] > 0, \
        "scenario must preempt a sequence mid-prefill"
    assert roomy == tight


def test_one_compile_across_heterogeneous_prompts(
        small, recompile_sentinel):
    """Acceptance: the chunk-prefill executable compiles exactly once no
    matter how prompt lengths and wave compositions vary, while the
    oracle's padded wave path recompiles per (wave, bucket) shape."""
    cfg, params = small
    prompts = _prompts(7, seed=61, lo=4, hi=120)
    eng = _engine(cfg, params, max_num_batched_tokens=32,
                  max_blocks_per_seq=16, num_blocks=128)
    _drain(eng, prompts, [SamplingParams(max_tokens=4)] * 7)
    assert eng.runner.prefill_compiles() == 1
    recompile_sentinel.arm(eng.runner, "chunked")
    _drain(eng, _prompts(5, seed=62, lo=4, hi=90),
           [SamplingParams(max_tokens=4)] * 5)
    recompile_sentinel.check()
    oracle = _engine(cfg, params, enable_chunked_prefill=False,
                     max_blocks_per_seq=16, num_blocks=128)
    _drain(oracle, prompts, [SamplingParams(max_tokens=4)] * 7)
    assert oracle.runner.prefill_compiles() > 1


def test_budget_respected_and_reported(small):
    cfg, params = small
    eng = _engine(cfg, params, max_num_batched_tokens=16)
    _drain(eng, _prompts(4, seed=71, lo=20, hi=50),
           [SamplingParams(max_tokens=6)] * 4)
    rep = eng.report()
    assert 0 < rep["budget_utilization"] <= 1.0
    assert rep["prefill_chunks"] == eng.metrics["prefill_chunks"] > 4
    assert np.isfinite(rep["itl_p50_ms"]) and np.isfinite(rep["itl_p99_ms"])
    assert rep["itl_p50_ms"] <= rep["itl_p99_ms"]


def test_engine_rejects_budget_not_exceeding_slots(small):
    cfg, params = small
    with pytest.raises(ValueError, match="max_num_batched_tokens"):
        _engine(cfg, params, max_slots=8, max_num_batched_tokens=8)


def test_non_full_attention_arch_falls_back_to_oracle():
    """SSM archs serve through the whole-prompt path even when chunked
    prefill is requested — no crash, same outputs as oracle mode."""
    cfg = get_reduced("falcon-mamba-7b", num_layers=2)
    params = T.init_params(cfg, KEY)
    prompts = _prompts(2, seed=81)
    a, _ = _drain(_engine(cfg, params, enable_chunked_prefill=True),
                  prompts, [SamplingParams(max_tokens=4)] * 2)
    b, _ = _drain(_engine(cfg, params, enable_chunked_prefill=False),
                  prompts, [SamplingParams(max_tokens=4)] * 2)
    assert a == b
