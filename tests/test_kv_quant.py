"""Quantized paged KV cache: roundtrip error bounds, quantize-on-write
pool ops, CoW/fork scale carriage, the in-kernel-dequant paged-attention
kernel, and end-to-end int8-vs-bf16 serving parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_reduced
from repro.core.kv_quant import (copy_blocks_quant,
                                 dequantize_blocks, gather_kv_quant,
                                 make_kv_pool_quant, normalize_kv_cache_dtype,
                                 quantize_blocks, write_decode_kv_quant,
                                 write_prefill_kv_quant)
from repro.core.paged_cache import BlockAllocator
from repro.models import transformer as T
from repro.serving import LLM, SamplingParams

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------ roundtrip

def test_roundtrip_error_bounded_by_half_scale():
    """Property (random sweep): for any live value, |x - dq(q(x))| <=
    scale/2 with scale = amax/127 per (block, head)."""
    rng = np.random.default_rng(0)
    for trial in range(25):
        BS, KV, D = (int(rng.integers(1, 17)), int(rng.integers(1, 5)),
                     int(rng.integers(1, 33)))
        mag = 10.0 ** rng.uniform(-3, 3)
        x = jnp.asarray(rng.normal(size=(4, BS, KV, D)) * mag, jnp.float32)
        live = jnp.asarray(rng.random((4, BS)) < 0.8)
        q, scales = quantize_blocks(x, live)
        deq = dequantize_blocks(q, scales)
        err = jnp.abs(jnp.where(live[..., None, None], x, 0.0) - deq)
        # worst live element per (block, head) vs that head's scale bound
        bound = (scales / 2 * (1 + 1e-5))[:, None, :, None]
        assert bool(jnp.all(err <= bound)), f"trial {trial}"
        # dead slots quantize to exactly 0
        assert bool(jnp.all(jnp.where(live[..., None, None], 0, deq) == 0))


def test_roundtrip_exact_on_int8_grid():
    """Values already on the int8 grid (n * amax/127) survive exactly."""
    rng = np.random.default_rng(1)
    amax = 3.7
    n = rng.integers(-127, 128, size=(2, 8, 2, 16))
    n.flat[0] = 127                          # pin the amax so scale is known
    x = jnp.asarray(n * (amax / 127.0), jnp.float32)
    live = jnp.ones((2, 8), bool)
    q, scales = quantize_blocks(x, live)
    np.testing.assert_allclose(np.asarray(scales), amax / 127.0, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(q).astype(np.int64), n)
    np.testing.assert_allclose(np.asarray(dequantize_blocks(q, scales)),
                               np.asarray(x), rtol=1e-6)


# ------------------------------------------------------------ pool writes

def test_prefill_write_gather_roundtrip():
    """write_prefill_kv_quant + gather_kv_quant reproduces the prompt K
    within the per-block scale bound; junk beyond ctx_len never leaks."""
    L, NB, BS, KV, D = 1, 8, 4, 2, 8
    kq, vq, ks, vs = make_kv_pool_quant(L, NB, BS, KV, D)
    del vq, vs
    bt = jnp.asarray([[3, 5, 1], [2, 6, 0]], jnp.int32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 10, KV, D))
    ctx = jnp.asarray([10, 6])
    kq, ks = write_prefill_kv_quant(kq, ks, 0, k, bt, ctx)
    g = gather_kv_quant(kq, ks, 0, bt, 10)
    for b, n in enumerate([10, 6]):
        ref = np.asarray(k[b, :n], np.float32)
        err = np.abs(np.asarray(g[b, :n]) - ref)
        # bound: half the per-block scale of the block each token is in
        sc = np.asarray(ks[0])[np.asarray(bt[b])]          # [3, KV]
        bound = sc[np.arange(n) // BS] / 2 * (1 + 1e-5)    # [n, KV]
        assert (err <= bound[:, :, None]).all()
        # beyond ctx_len the masked write produced exact zeros
        assert (np.asarray(g[b, n:]) == 0).all()


def test_prefill_chunked_boundary_merge():
    """A pos_offset write into a half-filled block merges the existing
    live prefix instead of zeroing it (the chunked-prefill boundary)."""
    L, NB, BS, KV, D = 1, 4, 4, 1, 4
    kq, vq, ks, vs = make_kv_pool_quant(L, NB, BS, KV, D)
    del vq, vs
    bt = jnp.asarray([[1, 2]], jnp.int32)
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 6, KV, D))
    ctx = jnp.asarray([6])
    # chunk 1: positions 0..1 (half of block 0); chunk 2: positions 2..5
    kq, ks = write_prefill_kv_quant(kq, ks, 0, k[:, :2], bt, ctx)
    kq, ks = write_prefill_kv_quant(kq, ks, 0, k[:, 2:], bt, ctx,
                                    pos_offset=2)
    g = gather_kv_quant(kq, ks, 0, bt, 6)
    sc = float(np.asarray(ks[0]).max())
    # the merge requantizes the prefix once, so allow 2 half-steps
    np.testing.assert_allclose(np.asarray(g[0]), np.asarray(k[0], np.float32),
                               atol=sc * 1.01)


def test_decode_write_appends_and_rescales():
    """Token-by-token decode writes keep every earlier token in the block
    within the (possibly grown) scale bound; inactive slots are dropped."""
    L, NB, BS, KV, D = 1, 4, 4, 2, 8
    kq, vq, ks, vs = make_kv_pool_quant(L, NB, BS, KV, D)
    del vq, vs
    bt = jnp.asarray([[1, 3], [2, 0]], jnp.int32)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.normal(size=(6, 2, KV, D)) *
                       (1 + np.arange(6))[:, None, None, None], jnp.float32)
    for t in range(6):
        pos = jnp.asarray([t, -1])               # seq 1 inactive throughout
        kq, ks = write_decode_kv_quant(kq, ks, 0, toks[t], bt, pos)
    g = gather_kv_quant(kq, ks, 0, bt, 6)
    sc = np.asarray(ks[0])[np.asarray(bt[0])]                  # [2, KV]
    for t in range(6):
        err = np.abs(np.asarray(g[0, t]) - np.asarray(toks[t, 0], np.float32))
        # growth requantization: <= 1 full step of the block's final scale
        assert (err <= sc[t // BS][:, None] * 1.01).all(), t
    # the inactive sequence's blocks were never touched
    assert (np.asarray(kq[0])[np.asarray(bt[1])] == 0).all()


def test_cow_fork_carries_scales():
    """CoW after a fork copies the scale row with the value block — the
    fork dequantizes its shared prefix identically."""
    bs = 4
    a = BlockAllocator(16, bs)
    ids, _ = a.allocate_prompt(list(range(6)))      # 1 full + 1 partial
    L, NB, KV, D = 2, 16, 1, 8
    kq, vq, ks, vs = make_kv_pool_quant(L, NB, bs, KV, D)
    del vq, vs
    bt = jnp.asarray([ids + [0] * (4 - len(ids))], jnp.int32)
    k = jax.random.normal(jax.random.fold_in(KEY, 4), (1, 6, KV, D))
    for layer in range(L):
        kq, ks = write_prefill_kv_quant(kq, ks, layer, k, bt,
                                        jnp.asarray([6]))
    before = np.asarray(gather_kv_quant(kq, ks, 1, bt, 6))
    fork = a.fork_sequence(ids)
    grown, cow = a.grow(fork, 6, 1)
    src, dst = cow
    assert src == ids[-1] and dst == grown[-1]
    kq, ks = copy_blocks_quant(kq, ks, jnp.asarray([src], jnp.int32),
                               jnp.asarray([dst], jnp.int32))
    bt_fork = jnp.asarray([grown + [0] * (4 - len(grown))], jnp.int32)
    after = np.asarray(gather_kv_quant(kq, ks, 1, bt_fork, 6))
    np.testing.assert_array_equal(before, after)
    # scale rows really moved (the tail block's scale is non-trivial)
    np.testing.assert_array_equal(np.asarray(ks[:, dst]),
                                  np.asarray(ks[:, src]))
    assert float(np.abs(np.asarray(ks[:, dst])).max()) > 0


# ------------------------------------------------------------ kernel

@pytest.mark.parametrize("use_alibi", [False, True])
def test_paged_attention_quant_kernel_matches_ref(use_alibi):
    """Interpret-mode Pallas kernel (in-register dequant) == dequantizing
    XLA reference."""
    from repro.core.alibi import alibi_slopes
    from repro.kernels.paged_attention_quant import paged_attention_quant
    from repro.kernels.ref import paged_attention_quant_ref
    B, H, KV, D, NB, BS, MB = 3, 8, 2, 16, 16, 8, 4
    q = jax.random.normal(jax.random.fold_in(KEY, 5), (B, H, D), jnp.float32)
    kraw = jax.random.normal(jax.random.fold_in(KEY, 6), (NB, BS, KV, D))
    vraw = jax.random.normal(jax.random.fold_in(KEY, 7), (NB, BS, KV, D))
    full = jnp.ones((NB, BS), bool)
    kq, ks = quantize_blocks(kraw, full)
    vq, vs = quantize_blocks(vraw, full)
    bt = jnp.asarray(np.random.default_rng(0).permutation(NB)[:B * MB]
                     .reshape(B, MB), jnp.int32)
    sl = jnp.asarray([17, 8, 30], jnp.int32)
    slopes = alibi_slopes(H) if use_alibi else None
    out = paged_attention_quant(q, kq, ks, vq, vs, bt, sl, slopes,
                                interpret=True)
    ref = paged_attention_quant_ref(q, kq, ks, vq, vs, bt, sl,
                                    alibi_slopes=slopes)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ------------------------------------------------------------ end to end

def _generate(kv_cache_dtype, prompts, *, use_fused=True, temperature=0.0,
              max_tokens=12, num_blocks=64):
    llm = LLM.load("qwen1.5-0.5b", reduced=True,
                   kv_cache_dtype=kv_cache_dtype, use_fused=use_fused,
                   max_slots=3, num_blocks=num_blocks, max_blocks_per_seq=8,
                   prefill_bucket=16, overrides={"num_layers": 2})
    res = llm.generate(prompts, SamplingParams(temperature=temperature,
                                               max_tokens=max_tokens))
    return [o.token_ids for o in res], llm


def _prompts(n, seed=0, lo=4, hi=20):
    rng = np.random.default_rng(seed)
    return [list(rng.integers(1, 200, int(rng.integers(lo, hi))))
            for _ in range(n)]


def test_int8_greedy_parity_with_bf16():
    """Acceptance: greedy generations through the int8 KV cache match the
    bf16 oracle token-for-token on the reduced config (the quantization
    error is far below the reduced model's logit margins)."""
    prompts = _prompts(5, seed=11)
    o_bf16, llm_bf = _generate("bf16", prompts)
    o_int8, llm_i8 = _generate("int8", prompts)
    assert o_bf16 == o_int8
    # and the memory win is real: >= 1.8x fewer KV pool bytes
    ratio = (llm_bf.engine.runner.kv_pool_bytes()
             / llm_i8.engine.runner.kv_pool_bytes())
    assert ratio >= 1.8, ratio


def test_int8_fused_matches_legacy_bitwise():
    """Within int8 mode the fused megastep and the legacy loop remain
    bitwise-identical (same quantize-on-write ops, same sampling streams),
    including under temperature sampling."""
    prompts = _prompts(4, seed=7)
    for temp in (0.0, 0.9):
        leg, _ = _generate("int8", prompts, use_fused=False,
                           temperature=temp)
        fus, _ = _generate("int8", prompts, use_fused=True, temperature=temp)
        assert leg == fus, f"temperature={temp}"


def test_int8_preemption_recompute_parity():
    """Recompute-style preemption refills fresh blocks (overwritten
    scales) — a block-starved int8 run matches a roomy one."""
    prompts = _prompts(4, seed=11, lo=17, hi=30)
    roomy, _ = _generate("int8", prompts, max_tokens=32, num_blocks=256)
    tight, llm = _generate("int8", prompts, max_tokens=32, num_blocks=9)
    assert llm.engine.metrics["preemptions"] > 0
    assert roomy == tight


def test_int8_rejects_sliding_window_archs():
    with pytest.raises(ValueError, match="sliding"):
        T.make_decode_state(get_reduced("h2o-danube-3-4b"), 2, 8, 2,
                            kv_cache_dtype="int8")


def test_int8_rejects_attention_free_archs():
    """No silent no-op: an SSM model has no paged KV cache, so asking for
    int8 KV must fail loudly instead of quietly quantizing nothing."""
    with pytest.raises(ValueError, match="no attention KV cache"):
        T.make_decode_state(get_reduced("falcon-mamba-7b"), 2, 8, 2,
                            kv_cache_dtype="int8")


def test_kv_cache_dtype_validation():
    assert normalize_kv_cache_dtype(None) == "bf16"
    assert normalize_kv_cache_dtype("bfloat16") == "bf16"
    assert normalize_kv_cache_dtype("int8") == "int8"
    with pytest.raises(ValueError, match="kv_cache_dtype"):
        LLM.load("qwen1.5-0.5b", reduced=True, kv_cache_dtype="int4")
