"""Fused decode megastep vs legacy per-token loop, CoW device copy,
preemption-requeue determinism, gather_kv partial-tail."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_reduced
from repro.core.paged_cache import (BlockAllocator, OutOfBlocksError,
                                    copy_blocks, gather_kv, make_kv_pool,
                                    write_prefill_kv)
from repro.models import transformer as T
from repro.serving.engine import Request, ServingEngine

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def small():
    cfg = get_reduced("qwen1.5-0.5b", num_layers=2)
    params = T.init_params(cfg, KEY)
    return cfg, params


def _run(cfg, params, prompts, *, use_fused, temperature=0.0,
         max_new_tokens=10, **kw):
    eng = ServingEngine(cfg, params, use_fused=use_fused, **kw)
    for i, p in enumerate(prompts):
        eng.add_request(Request(rid=i, prompt=p, temperature=temperature,
                                max_new_tokens=max_new_tokens))
    rep = eng.run_until_done()
    return {r.rid: list(r.output) for r in eng.finished}, rep, eng


def _prompts(n, seed=0, lo=4, hi=20):
    rng = np.random.default_rng(seed)
    return [list(rng.integers(1, 200, int(rng.integers(lo, hi))))
            for _ in range(n)]


# ------------------------------------------------------------ fused == legacy

def test_fused_matches_legacy_greedy(small):
    """Acceptance: fused-path outputs bitwise-identical (greedy) to the
    step-by-step loop on the reduced qwen1.5-0.5b config."""
    cfg, params = small
    kw = dict(max_slots=3, num_blocks=64, max_blocks_per_seq=8,
              prefill_bucket=16)
    o_leg, _, _ = _run(cfg, params, _prompts(6), use_fused=False, **kw)
    o_fus, rep, _ = _run(cfg, params, _prompts(6), use_fused=True, **kw)
    assert len(o_leg) == len(o_fus) == 6
    assert o_leg == o_fus
    # the fast path actually fused: fewer dispatches than decode steps
    assert rep["decode_dispatches"] < rep["decode_steps"]


def test_fused_matches_legacy_temperature(small):
    """The megastep splits the PRNG key once per step exactly like the host
    loop, so even temperature sampling matches token for token."""
    cfg, params = small
    kw = dict(max_slots=2, num_blocks=64, max_blocks_per_seq=8,
              prefill_bucket=16)
    o_leg, _, _ = _run(cfg, params, _prompts(3, seed=7), use_fused=False,
                       temperature=0.9, **kw)
    o_fus, _, _ = _run(cfg, params, _prompts(3, seed=7), use_fused=True,
                       temperature=0.9, **kw)
    assert o_leg == o_fus


def test_fused_single_sync_per_horizon(small):
    """Acceptance: steady-state decode performs at most one host<->device
    round trip per dispatched horizon."""
    cfg, params = small
    _, rep, _ = _run(cfg, params, _prompts(3, seed=3), use_fused=True,
                     max_slots=4, num_blocks=64, max_blocks_per_seq=8,
                     prefill_bucket=16)
    # all admitted in one wave: total syncs = 1 prefill + 1 per dispatch
    assert rep["host_syncs"] == rep["decode_dispatches"] + 1
    assert rep["syncs_per_decode_step"] < 1.0


def test_fused_greedy_matches_direct_forward(small):
    """Fused engine greedy decode == teacher-forced model argmax."""
    cfg, params = small
    prompt = [5, 9, 13, 2, 7, 11]
    outs, _, _ = _run(cfg, params, [prompt], use_fused=True,
                      max_new_tokens=6, max_slots=2, num_blocks=64,
                      max_blocks_per_seq=8, prefill_bucket=8)
    toks = list(prompt)
    for _ in range(6):
        logits = T.forward(cfg, params, {"tokens": jnp.asarray([toks])})
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert outs[0] == toks[len(prompt):]


# ------------------------------------------------------------ CoW device copy

def test_fork_append_triggers_cow_with_device_copy():
    """Forked sequence sharing a partial tail: the next append must CoW the
    tail and the device block-copy must preserve its contents."""
    bs = 4
    a = BlockAllocator(16, bs)
    ids, _ = a.allocate_prompt(list(range(6)))      # 1 full + 1 partial
    fork = a.fork_sequence(ids)
    assert a._blocks[ids[-1]].ref == 2
    # device pool with recognizable contents in the shared tail
    kp, _ = make_kv_pool(2, 16, bs, 1, 8, dtype=jnp.float32)
    bt = jnp.asarray([ids + [0] * (4 - len(ids))], jnp.int32)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 1, 8))
    kp = write_prefill_kv(kp, 0, k, bt, jnp.asarray([6]))
    kp = write_prefill_kv(kp, 1, k, bt, jnp.asarray([6]))
    # fork appends token at position 6 (partial shared tail) -> CoW
    grown, cow = a.grow(fork, 6, 1)
    src, dst = cow
    assert src == ids[-1] and dst == grown[-1] != ids[-1]
    assert a.stats["cow"] == 1
    assert a._blocks[ids[-1]].ref == 1              # original keeps its tail
    kp = copy_blocks(kp, jnp.asarray([src], jnp.int32),
                     jnp.asarray([dst], jnp.int32))
    # every layer's tail contents survived the copy; original untouched
    np.testing.assert_allclose(np.asarray(kp[:, dst, :2, 0]),
                               np.asarray(kp[:, src, :2, 0]))
    np.testing.assert_allclose(np.asarray(kp[0, src, :2, 0]),
                               np.asarray(k[0, 4:6, 0], np.float32))


def test_cow_with_horizon_growth_targets_replacement_block():
    """CoW + multi-token growth in one grow() call: the device-copy dst is
    the *replacement* tail, not the last freshly appended growth block."""
    bs = 4
    a = BlockAllocator(16, bs)
    ids, _ = a.allocate_prompt(list(range(6)))      # 1 full + 1 partial
    fork = a.fork_sequence(ids)
    grown, cow = a.grow(fork, 6, 6)                 # CoW + spills 1 block
    src, dst = cow
    assert src == ids[-1]
    assert dst == grown[1] != grown[-1]             # replacement, not growth
    assert len(grown) == 3
    assert a._blocks[grown[-1]].ref == 1


def test_blocks_needed_accounts_for_cow_and_horizon():
    bs = 4
    a = BlockAllocator(16, bs)
    ids, _ = a.allocate_prompt(list(range(6)))      # capacity 8, len 6
    assert a.blocks_needed(ids, 6, 2) == 0          # fits the partial tail
    assert a.blocks_needed(ids, 6, 3) == 1          # spills into one block
    assert a.blocks_needed(ids, 6, 7) == 2
    fork = a.fork_sequence(ids)
    assert a.blocks_needed(fork, 6, 1) == 1         # CoW replacement block
    grown, cow = a.grow(fork, 6, 7)                 # CoW + 2 growth blocks
    assert cow[0] == ids[-1] and len(grown) == 4


def test_grow_is_atomic_on_exhaustion():
    """A grow that cannot fit must not leak blocks or touch refcounts."""
    bs = 4
    a = BlockAllocator(4, bs)
    ids, _ = a.allocate_prompt(list(range(6)))      # 2 blocks, 2 free
    free_before = a.num_free
    with pytest.raises(OutOfBlocksError):
        a.grow(ids, 6, 16)                          # needs 4 blocks > 2 free
    assert a.num_free == free_before                # nothing leaked
    fork = a.fork_sequence(ids)
    a._free = []                                    # exhaust the pool
    with pytest.raises(OutOfBlocksError):
        a.grow(fork, 6, 1)                          # CoW needs 1 block
    assert a._blocks[ids[-1]].ref == 2              # tail ref untouched


# ------------------------------------------------------ preemption determinism

def test_preemption_requeue_identical_outputs(small):
    """Recompute-style preemption must not change greedy outputs: a run
    forced through preemption matches an unconstrained run request-for-
    request."""
    cfg, params = small
    prompts = _prompts(4, seed=11, lo=17, hi=30)
    roomy, _, _ = _run(cfg, params, prompts, use_fused=True,
                       max_new_tokens=32, max_slots=3, num_blocks=256,
                       max_blocks_per_seq=8, prefill_bucket=16)
    tight, rep, eng = _run(cfg, params, prompts, use_fused=True,
                           max_new_tokens=32, max_slots=3, num_blocks=9,
                           max_blocks_per_seq=8, prefill_bucket=16)
    assert eng.metrics["preemptions"] > 0, "scenario must exercise preemption"
    assert tight == roomy


def test_preemption_identical_legacy_vs_fused(small):
    cfg, params = small
    prompts = _prompts(4, seed=11, lo=17, hi=30)
    kw = dict(max_new_tokens=32, max_slots=3, num_blocks=9,
              max_blocks_per_seq=8, prefill_bucket=16)
    o_leg, _, eng_l = _run(cfg, params, prompts, use_fused=False, **kw)
    o_fus, _, eng_f = _run(cfg, params, prompts, use_fused=True, **kw)
    assert eng_l.metrics["preemptions"] > 0
    assert eng_f.metrics["preemptions"] > 0
    assert o_leg == o_fus


@pytest.mark.parametrize("use_fused", [False, True])
def test_sequence_truncated_at_block_table_capacity(small, use_fused):
    """A generation that would overflow the mb-wide block table is
    truncated (force-finished), not crashed in _sync_tables."""
    cfg, params = small
    prompt = list(range(1, 18))                     # 17 tokens, cap 2*16=32
    outs, _, eng = _run(cfg, params, [prompt], use_fused=use_fused,
                        max_new_tokens=48, max_slots=2, num_blocks=8,
                        max_blocks_per_seq=2, prefill_bucket=32)
    assert len(eng.finished) == 1
    assert 0 < len(outs[0]) < 48                    # truncated at capacity
    # never grew past the table width
    assert all(len(s.block_ids) <= 2 for s in eng.running.values())


def test_overlong_prompt_clamped_at_admission(small):
    """A prompt that would overflow the block table is clamped at admission
    (leaving room to generate) instead of crashing the prefill scatter."""
    cfg, params = small
    prompt = list(range(1, 40))                     # 39 tokens > cap 2*16=32
    outs, _, eng = _run(cfg, params, [prompt], use_fused=True,
                        max_new_tokens=4, max_slots=2, num_blocks=8,
                        max_blocks_per_seq=2, prefill_bucket=32)
    assert eng.metrics["truncated_prompts"] == 1
    assert len(eng.finished) == 1 and len(outs[0]) >= 1


# ------------------------------------------------------------ gather_kv tail

def test_gather_kv_partial_tail_not_truncated():
    bs = 4
    kp, _ = make_kv_pool(1, 8, bs, 2, 8, dtype=jnp.float32)
    bt = jnp.asarray([[3, 5, 1]], jnp.int32)
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 10, 2, 8))
    kp = write_prefill_kv(kp, 0, k, bt, jnp.asarray([10]))
    g = gather_kv(kp, 0, bt, 10)                    # 2.5 blocks
    assert g.shape == (1, 10, 2, 8)
    np.testing.assert_allclose(np.asarray(g), np.asarray(k, np.float32))
    # block-multiple path unchanged
    g8 = gather_kv(kp, 0, bt, 8)
    assert g8.shape == (1, 8, 2, 8)
    np.testing.assert_allclose(np.asarray(g8), np.asarray(k[:, :8],
                                                          np.float32))
