"""Dynamic grouping / MHA->GQA conversion (paper's Opt-GQA recipe)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.grouping import (cluster_heads, convert_mha_to_gqa,
                                 grouping_quality, head_similarity)


def _clustered_acts(H=8, N=64, D=16, groups=2, noise=0.05, seed=0):
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(groups, D))
    acts, truth = [], []
    for h in range(H):
        g = h % groups
        truth.append(g)
        acts.append(protos[g] + noise * rng.normal(size=(N, D)))
    return jnp.asarray(np.stack(acts)), truth


def test_similarity_clusters_recover_truth():
    acts, truth = _clustered_acts()
    sim = head_similarity(acts)
    groups = cluster_heads(sim, 2)
    for g in groups:
        assert len({truth[h] for h in g}) == 1   # pure clusters
    intra, inter = grouping_quality(sim, groups)
    assert intra > inter


def test_cluster_sizes_equal():
    acts, _ = _clustered_acts(H=12, groups=3)
    groups = cluster_heads(head_similarity(acts), 4)
    assert sorted(len(g) for g in groups) == [3, 3, 3, 3]


def test_conversion_shapes_and_perm():
    H, D, d = 8, 16, 32
    key = jax.random.PRNGKey(0)
    wq, wk, wv = (jax.random.normal(k, (d, H, D)) for k in jax.random.split(key, 3))
    acts, _ = _clustered_acts(H=H, D=D)
    conv = convert_mha_to_gqa(wq, wk, wv, acts, num_kv_heads=2)
    assert conv.wk.shape == (d, 2, D) and conv.wv.shape == (d, 2, D)
    assert sorted(conv.q_perm.tolist()) == list(range(H))
    assert conv.intra_sim > conv.inter_sim


def test_identical_heads_merge_losslessly():
    """If all heads in a group share identical K weights, merging is exact."""
    H, D, d = 4, 8, 16
    key = jax.random.PRNGKey(1)
    wk1 = jax.random.normal(key, (d, 1, D))
    wk = jnp.concatenate([wk1, wk1, wk1, wk1], axis=1)
    acts = jnp.tile(jax.random.normal(key, (1, 32, D)), (H, 1, 1))
    conv = convert_mha_to_gqa(wk, wk, wk, acts, num_kv_heads=1)
    np.testing.assert_allclose(conv.wk[:, 0], wk1[:, 0], rtol=1e-5)
