"""Model-level quantization: RTN transform + quantized forward/serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_reduced
from repro.models import transformer as T
from repro.models.quantize import quantize_params_rtn

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "falcon-mamba-7b",
                                  "recurrentgemma-2b"])
def test_quantized_forward_close_to_fp(arch):
    cfg = get_reduced(arch)
    params = T.init_params(cfg, KEY)
    qparams = quantize_params_rtn(params, cfg, group_size=32)
    batch = {"tokens": jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)}
    lf = T.forward(cfg, params, batch)
    lq = T.forward(cfg, qparams, batch)
    # int4 weights: logits drift bounded, ranking mostly preserved
    assert bool(jnp.isfinite(lq).all())
    agree = (jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).mean()
    assert float(agree) > 0.5


def test_quantized_decode_runs(arch="qwen2-1.5b"):
    cfg = get_reduced(arch)
    params = quantize_params_rtn(T.init_params(cfg, KEY), cfg, group_size=32)
    from repro.models.registry import decode_geometry
    from repro.configs.base import ShapeConfig
    g = decode_geometry(cfg, ShapeConfig("t", 32, 2, "decode"))
    state = T.make_decode_state(cfg, 2, g["num_blocks"],
                                g["max_blocks_per_seq"], dtype=jnp.float32)
    state["block_table"] = jnp.arange(2 * g["max_blocks_per_seq"],
                                      dtype=jnp.int32).reshape(2, -1)
    lg, state = T.prefill(cfg, params, state,
                          {"tokens": jnp.ones((2, 8), jnp.int32),
                           "ctx_lens": jnp.array([8, 8], jnp.int32)})
    state["seq_lens"] = jnp.array([9, 9], jnp.int32)
    lg2, _ = T.decode_step(cfg, params, state, jnp.array([1, 2]))
    assert bool(jnp.isfinite(lg2).all())


def test_gptq_model_quantization_quality():
    """True GPTQ (Hessian) beats RTN on calibration-distribution logits."""
    from repro.models.quantize import gptq_quantize_model
    from repro.configs.base import QuantConfig
    cfg = get_reduced("qwen2-1.5b", num_layers=2)
    params = T.init_params(cfg, KEY)
    calib = [{"tokens": jax.random.randint(jax.random.fold_in(KEY, i),
                                           (2, 16), 0, cfg.vocab_size)}
             for i in range(2)]
    qcfg = QuantConfig(bits=4, group_size=32)
    qg = gptq_quantize_model(cfg, params, calib, qcfg)
    qr = quantize_params_rtn(params, cfg, group_size=32)
    test_b = calib[0]
    lf = np.asarray(T.forward(cfg, params, test_b), np.float64)
    eg = np.abs(np.asarray(T.forward(cfg, qg, test_b), np.float64) - lf).mean()
    er = np.abs(np.asarray(T.forward(cfg, qr, test_b), np.float64) - lf).mean()
    assert np.isfinite(eg) and np.isfinite(er)
    assert eg < er * 1.25      # GPTQ at least comparable, typically better
