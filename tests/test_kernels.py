"""Pallas kernels vs ref.py oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import QuantConfig
from repro.core.alibi import alibi_slopes
from repro.core.gptq import gptq_quantize
from repro.core.quant import make_quant_params
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gptq_matmul import gptq_matmul
from repro.kernels.paged_attention import paged_attention

TOL = {jnp.float32: 5e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("B,S,H,KV,D", [
    (2, 64, 8, 2, 32), (1, 96, 4, 4, 16), (2, 128, 12, 2, 64),
    (1, 64, 16, 1, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("alibi,win", [(False, 0), (True, 0), (True, 24)])
def test_flash_attention_sweep(B, S, H, KV, D, dtype, alibi, win):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), dtype)
    sl = alibi_slopes(H) if alibi else None
    o = flash_attention(q, k, v, sl, causal=True, sliding_window=win,
                        block_q=32, block_k=32, interpret=True)
    r = ref.flash_attention_ref(q, k, v, causal=True, sliding_window=win,
                                alibi_slopes=sl)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=TOL[dtype])


@pytest.mark.parametrize("B,H,KV,D,BS,MB", [
    (3, 8, 2, 32, 8, 4), (2, 4, 4, 16, 16, 3), (2, 12, 2, 64, 8, 6),
    (1, 8, 1, 128, 16, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(B, H, KV, D, BS, MB, dtype):
    NB = B * MB + 2
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    kp = jax.random.normal(ks[1], (NB, BS, KV, D), dtype)
    vp = jax.random.normal(ks[2], (NB, BS, KV, D), dtype)
    bt = jax.random.permutation(ks[3], NB)[:B * MB].reshape(B, MB)
    bt = bt.astype(jnp.int32)
    sl = jnp.asarray(np.random.default_rng(0).integers(1, MB * BS + 1, B),
                     jnp.int32)
    o = paged_attention(q, kp, vp, bt, sl, interpret=True)
    r = ref.paged_attention_ref(q, kp, vp, bt, sl)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=TOL[dtype])


def test_paged_attention_alibi_and_window():
    B, H, KV, D, BS, MB = 2, 8, 2, 32, 8, 5
    NB = B * MB
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (NB, BS, KV, D))
    vp = jax.random.normal(ks[2], (NB, BS, KV, D))
    bt = jnp.arange(NB, dtype=jnp.int32).reshape(B, MB)
    sl = jnp.array([37, 12], jnp.int32)
    slo = alibi_slopes(H)
    o = paged_attention(q, kp, vp, bt, sl, slo, sliding_window=16,
                        interpret=True)
    r = ref.paged_attention_ref(q, kp, vp, bt, sl, alibi_slopes=slo,
                                sliding_window=16)
    np.testing.assert_allclose(o, r, atol=5e-5)


@pytest.mark.parametrize("q_off", [0, 8, 5, 11])   # 0 / block-aligned /
@pytest.mark.parametrize("alibi,win", [(False, 0), (True, 0),  # unaligned
                                       (False, 12)])
@pytest.mark.parametrize("quant", [False, True])
def test_flash_attention_chunk_dynamic_offset(q_off, alibi, win, quant):
    """The dynamic-offset chunk kernel (scalar-prefetch q_offset /
    total_len, paged-pool page walk + raw chunk overlay, in-register int8
    dequant) matches the bounded-gather XLA oracle across chunk offsets,
    ALiBi, sliding window, and both pool formats — interpret mode, so the
    Pallas path is exercised without TPU hardware."""
    from repro.kernels.flash_attention import flash_attention_chunk
    rng = np.random.default_rng(3 + q_off)
    L, NB, BS, KV, D, H, MB, W = 1, 12, 8, 2, 16, 4, 6, 16
    total = q_off + int(rng.integers(1, W + 1))
    q = jnp.asarray(rng.normal(size=(1, W, H, D)), jnp.float32)
    kr = jnp.asarray(rng.normal(size=(1, W, KV, D)), jnp.float32)
    vr = jnp.asarray(rng.normal(size=(1, W, KV, D)), jnp.float32)
    bt = jnp.asarray(rng.permutation(NB)[:MB][None], jnp.int32)
    if quant:
        kp = jnp.asarray(rng.integers(-127, 128, (L, NB, BS, KV, D)),
                         jnp.int8)
        vp = jnp.asarray(rng.integers(-127, 128, (L, NB, BS, KV, D)),
                         jnp.int8)
        ks = jnp.asarray(rng.uniform(0.01, 0.1, (L, NB, KV)), jnp.float32)
        vs = jnp.asarray(rng.uniform(0.01, 0.1, (L, NB, KV)), jnp.float32)
    else:
        kp = jnp.asarray(rng.normal(size=(L, NB, BS, KV, D)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(L, NB, BS, KV, D)), jnp.float32)
        ks = vs = None
    sl = alibi_slopes(H) if alibi else None
    o = flash_attention_chunk(
        q, kp[0], vp[0], bt, jnp.int32(q_off), jnp.int32(total), kr, vr,
        sl, k_scales=None if ks is None else ks[0],
        v_scales=None if vs is None else vs[0], sliding_window=win,
        block_q=8, interpret=True)
    r = ref.chunk_prefill_attention_ref(
        q, kp, vp, ks, vs, 0, bt, jnp.int32(q_off), jnp.int32(total),
        kr, vr, alibi_slopes=sl, sliding_window=win)
    live = total - q_off            # padded q rows are garbage on both
    np.testing.assert_allclose(np.asarray(o[:, :live], np.float32),
                               np.asarray(r[:, :live], np.float32),
                               atol=5e-5)


def test_flash_attention_chunk_one_compile_across_offsets():
    """q_offset / total_len are traced operands: every chunk shape of a
    serving run hits one executable (the whole point of the variant)."""
    from repro.kernels.flash_attention import flash_attention_chunk
    rng = np.random.default_rng(7)
    NB, BS, KV, D, H, MB, W = 8, 8, 2, 16, 4, 4, 8
    q = jnp.asarray(rng.normal(size=(1, W, H, D)), jnp.float32)
    kr = jnp.asarray(rng.normal(size=(1, W, KV, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(NB, BS, KV, D)), jnp.float32)
    bt = jnp.arange(MB, dtype=jnp.int32)[None]
    before = flash_attention_chunk._cache_size()
    for off in (0, 3, 8, 17):
        flash_attention_chunk(q, kp, kp, bt, jnp.int32(off),
                              jnp.int32(off + 5), kr, kr, None,
                              block_q=8, interpret=True)
    assert flash_attention_chunk._cache_size() - before == 1


@pytest.mark.parametrize("M,K,N,gs", [(16, 64, 32, 32), (8, 128, 48, 128),
                                      (32, 256, 128, 64), (5, 64, 17, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gptq_matmul_sweep(rng, M, K, N, gs, dtype):
    w = rng.normal(size=(K, N))
    qt = gptq_quantize(w, None, QuantConfig(group_size=gs, act_order=False))
    p = make_quant_params(qt)
    x = jnp.asarray(rng.normal(size=(M, K)), dtype)
    y = gptq_matmul(x, p["qweight"], p["scales"], p["zeros"], interpret=True)
    r = ref.quant_matmul_ref(x, p)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(r, np.float32),
                               rtol=2e-2, atol=TOL[dtype] * np.abs(np.asarray(r)).max())
