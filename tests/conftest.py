"""Shared fixtures: the deterministic rng and the recompile sentinel
that steady-state serving tests use to prove no shapes leak into a
jitted executable after warmup."""
import math

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


class RecompileSentinel:
    """Snapshots a ModelRunner's jit compile-cache sizes after warmup;
    ``check()`` (also run at fixture teardown) asserts the steady-state
    window that followed compiled nothing new.

    Executables whose cache size reads as NaN (the private jax
    ``_cache_size`` API drifted, or the path is disabled) are skipped,
    so a jax bump degrades this gate to a no-op instead of a fake
    regression — matching ``ModelRunner._cache_size``.
    """

    _EXECUTABLES = ("_prefill_chunk", "_unified", "_unified_chained",
                    "_megastep", "_decode", "_sample")

    def __init__(self):
        self._armed = []

    def arm(self, runner, label="runner"):
        """Snapshot ``runner`` post-warmup; returns the snapshot."""
        snap = self._snapshot(runner)
        self._armed.append((runner, label, snap))
        return snap

    @staticmethod
    def _snapshot(runner):
        from repro.serving.model_runner import ModelRunner
        snap = {}
        for name in RecompileSentinel._EXECUTABLES:
            fn = getattr(runner, name, None)
            if fn is None:
                continue
            n = ModelRunner._cache_size(fn)
            if not math.isnan(n):
                snap[name] = n
        return snap

    def check(self):
        grew = []
        for runner, label, before in self._armed:
            after = self._snapshot(runner)
            for name, n0 in sorted(before.items()):
                n1 = after.get(name, n0)
                if n1 > n0:
                    grew.append(f"{label}.{name}: {n0:g} -> {n1:g}")
        self._armed.clear()
        assert not grew, (
            "steady-state recompilation detected (a shape leaked into a "
            "jitted executable after warmup): " + "; ".join(grew))


@pytest.fixture
def recompile_sentinel():
    s = RecompileSentinel()
    yield s
    s.check()
