"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting shapes and finiteness. Serving-path consistency
(prefill + paged decode == full forward) for every decoder arch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import ARCHS, get_reduced
from repro.models import transformer as T
from repro.models.registry import decode_geometry
from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state

KEY = jax.random.PRNGKey(0)
ALL = sorted(ARCHS)


def _batch(cfg, B=2, S=16):
    if cfg.is_encoder:
        return {"frames": jax.random.normal(KEY, (B, S, cfg.d_model)),
                "labels": jnp.zeros((B, S), jnp.int32)}
    b = {"tokens": jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)}
    if cfg.frontend == "vision_patches":
        b["vision_embeds"] = jax.random.normal(
            KEY, (B, cfg.num_prefix_embeds, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ALL)
def test_forward_shapes_finite(arch):
    cfg = get_reduced(arch)
    params = T.init_params(cfg, KEY)
    batch = _batch(cfg)
    fwd_in = {k: (v[:, :-1] if k == "tokens" else v)
              for k, v in batch.items() if k != "labels"}
    logits = T.forward(cfg, params, fwd_in)
    S_out = 16 + (cfg.num_prefix_embeds if cfg.frontend == "vision_patches"
                  else 0)
    assert logits.shape == (2, S_out, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ALL)
def test_train_step_reduces_loss_direction(arch):
    cfg = get_reduced(arch)
    params = T.init_params(cfg, KEY)
    opt_cfg = AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=10)
    opt = init_opt_state(params, opt_cfg)
    batch = _batch(cfg)

    @jax.jit
    def step(p, o, b):
        loss, g = jax.value_and_grad(lambda pp: T.loss_fn(cfg, pp, b))(p)
        p2, o2, m = apply_updates(p, g, o, opt_cfg)
        return p2, o2, loss

    losses = []
    for _ in range(3):
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0]        # same batch -> must descend


@pytest.mark.parametrize("arch", [a for a in ALL
                                  if not ARCHS[a].is_encoder])
def test_serving_consistency(arch):
    """prefill + paged/ring/state decode == teacher-forced full forward."""
    cfg = get_reduced(arch)
    if cfg.num_experts:
        # MoE routing amplifies bf16 accumulation noise far past the 6e-2
        # tolerance (the same comparison lands at ~2e-6 in f32, so the
        # serving path itself is consistent): compare the two paths in
        # f32 so the test checks path equivalence, not bf16 rounding.
        cfg = cfg.replace(dtype="float32")
    if cfg.sliding_window:
        cfg = cfg.replace(sliding_window=12)   # smaller than prompt: ring hit
    params = T.init_params(cfg, KEY)
    B, S_total, S_prompt = 2, 28, 19
    toks = jax.random.randint(KEY, (B, S_total), 0, cfg.vocab_size)
    full_b = {"tokens": toks}
    off = 0
    if cfg.frontend == "vision_patches":
        ve = jax.random.normal(KEY, (B, cfg.num_prefix_embeds, cfg.d_model))
        full_b["vision_embeds"] = ve
        off = cfg.num_prefix_embeds
    logits_full = T.forward(cfg, params, full_b, rt={"scan_layers": False})

    g = decode_geometry(cfg, ShapeConfig("t", off + S_total + 8, B, "decode"))
    state = T.make_decode_state(cfg, B, g["num_blocks"],
                                g["max_blocks_per_seq"], dtype=jnp.float32)
    if "block_table" in state:
        state["block_table"] = jnp.arange(
            B * g["max_blocks_per_seq"], dtype=jnp.int32).reshape(B, -1)
    ctx_lens = jnp.array([S_prompt, S_prompt - 6], jnp.int32)
    pb = {"tokens": toks[:, :S_prompt], "ctx_lens": ctx_lens}
    if off:
        pb["vision_embeds"] = ve
    lg, state = T.prefill(cfg, params, state, pb)
    for b in range(B):
        ref = logits_full[b, off + int(ctx_lens[b]) - 1]
        np.testing.assert_allclose(lg[b], ref, atol=6e-2, rtol=1e-3)
    for step_i in range(3):
        pos = ctx_lens + step_i
        tok = jnp.take_along_axis(toks, pos[:, None], 1)[:, 0]
        state = dict(state)
        state["seq_lens"] = off + pos + 1
        lg, state = T.decode_step(cfg, params, state, tok)
        for b in range(B):
            ref = logits_full[b, off + int(pos[b])]
            np.testing.assert_allclose(lg[b], ref, atol=6e-2, rtol=1e-3)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "falcon-mamba-7b"])
def test_scan_vs_loop_forward_agree(arch):
    cfg = get_reduced(arch)
    params = T.init_params(cfg, KEY)
    batch = _batch(cfg)
    fwd_in = {k: v[:, :-1] if k == "tokens" else v for k, v in batch.items()}
    a = T.forward(cfg, params, fwd_in, rt={"scan_layers": True})
    b = T.forward(cfg, params, fwd_in, rt={"scan_layers": False})
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-2, rtol=1e-3)
