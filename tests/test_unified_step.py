"""Unified single-dispatch serving step vs the two-call oracle
(``enable_unified_step=False``): greedy token-exactness on both KV pool
formats, bitwise-identical fused sampling, preemption mid-prefill, the
single-compile guarantee, and the dispatch-count accounting."""
import jax
import numpy as np
import pytest

from repro.configs.registry import get_reduced
from repro.models import transformer as T
from repro.serving import SamplingParams, ServingEngine

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def small():
    cfg = get_reduced("qwen1.5-0.5b", num_layers=2)
    params = T.init_params(cfg, KEY)
    return cfg, params


def _prompts(n, seed=0, lo=4, hi=20):
    rng = np.random.default_rng(seed)
    return [list(rng.integers(1, 200, int(rng.integers(lo, hi))))
            for _ in range(n)]


def _engine(cfg, params, **kw):
    kw.setdefault("max_slots", 3)
    kw.setdefault("num_blocks", 64)
    kw.setdefault("max_blocks_per_seq", 8)
    kw.setdefault("max_num_batched_tokens", 16)
    return ServingEngine(cfg, params, **kw)


def _drain(eng, prompts, sps):
    for p, sp in zip(prompts, sps):
        eng.add(p, sp)
    eng.run_until_done()
    return {r.rid: list(r.output) for r in eng.finished}, \
        {r.rid: r.finish_reason for r in eng.finished}


@pytest.mark.parametrize("kv_cache_dtype", ["bf16", "int8"])
def test_unified_token_exact_vs_two_call(small, kv_cache_dtype):
    """Acceptance: multi-chunk greedy serving through the unified
    single-dispatch step is token-exact against the two-call oracle on
    the dense AND int8 pools, from exactly one unified-step compile."""
    cfg, params = small
    prompts = _prompts(5, seed=21, lo=24, hi=60)
    sps = [SamplingParams(max_tokens=10)] * 5
    o_ref, f_ref = _drain(
        _engine(cfg, params, enable_unified_step=False,
                kv_cache_dtype=kv_cache_dtype), prompts, sps)
    eng = _engine(cfg, params, kv_cache_dtype=kv_cache_dtype)
    o_chk, f_chk = _drain(eng, prompts, sps)
    assert eng.metrics["prefill_chunks"] > len(prompts)   # really chunked
    assert o_ref == o_chk and f_ref == f_chk
    assert eng.runner.unified_compiles() == 1
    assert eng.runner.prefill_compiles() == 1


def test_unified_sampling_bitwise_vs_two_call(small):
    """Fused sampling inside the unified dispatch (decode rows + the
    chunk's first token, one sample kernel over max_slots + 1 rows) is
    bitwise-identical to the two-call path's megastep + batched-sample
    pair across mixed sampling modes, including seeded requests."""
    cfg, params = small
    prompts = _prompts(4, seed=31, lo=20, hi=40)
    sps = [SamplingParams(max_tokens=8),
           SamplingParams(temperature=0.9, max_tokens=8),
           SamplingParams(temperature=0.8, top_k=5, max_tokens=8),
           SamplingParams(temperature=0.7, top_p=0.9, seed=7, max_tokens=8)]
    o_ref, _ = _drain(_engine(cfg, params, enable_unified_step=False),
                      prompts, sps)
    o_chk, _ = _drain(_engine(cfg, params), prompts, sps)
    assert o_ref == o_chk


@pytest.mark.parametrize("kv_cache_dtype", ["bf16", "int8"])
def test_unified_preemption_mid_prefill_parity(small, kv_cache_dtype):
    """A block-starved unified run that preempts a sequence mid-prefill
    still matches the roomy unified run token-for-token."""
    cfg, params = small
    rng = np.random.default_rng(51)
    prompts = [list(rng.integers(1, 200, n)) for n in (28, 28, 64)]
    sps = [SamplingParams(max_tokens=24)] * 3
    roomy, _ = _drain(
        _engine(cfg, params, max_num_batched_tokens=8, num_blocks=256,
                kv_cache_dtype=kv_cache_dtype), prompts, sps)
    eng = _engine(cfg, params, max_num_batched_tokens=8, num_blocks=9,
                  kv_cache_dtype=kv_cache_dtype)
    tight, _ = _drain(eng, prompts, sps)
    assert eng.metrics["preemptions_mid_prefill"] > 0, \
        "scenario must preempt a sequence mid-prefill"
    assert roomy == tight


def test_unified_one_compile_across_heterogeneous_prompts(
        small, recompile_sentinel):
    """Acceptance: the unified step compiles exactly once no matter how
    prompt lengths, chunk offsets and decode compositions vary — and a
    second heterogeneous wave through the warm engine compiles nothing."""
    cfg, params = small
    prompts = _prompts(7, seed=61, lo=4, hi=120)
    eng = _engine(cfg, params, max_num_batched_tokens=32,
                  max_blocks_per_seq=16, num_blocks=128)
    _drain(eng, prompts, [SamplingParams(max_tokens=4)] * 7)
    assert eng.runner.unified_compiles() == 1
    assert eng.runner.prefill_compiles() == 1
    recompile_sentinel.arm(eng.runner, "unified")
    _drain(eng, _prompts(5, seed=62, lo=4, hi=90),
           [SamplingParams(max_tokens=4)] * 5)
    recompile_sentinel.check()


def test_unified_single_dispatch_in_steady_mixed_state(
        small, recompile_sentinel):
    """One long prompt chunking over a warm decoding batch: every engine
    iteration in the steady mixed window is exactly ONE device dispatch
    (the two-call path pays a decode + a chunk + a sample dispatch) —
    and compiles nothing new."""
    cfg, params = small
    eng = _engine(cfg, params, max_num_batched_tokens=12, max_slots=2,
                  num_blocks=128, max_blocks_per_seq=16)
    eng.add(_prompts(1, seed=41)[0], SamplingParams(max_tokens=40))
    for _ in range(3):                     # short prompt is decoding now
        eng.step()
    recompile_sentinel.arm(eng.runner, "steady-mixed")
    rid = eng.add(_prompts(1, seed=42, lo=60, hi=61)[0],
                  SamplingParams(max_tokens=4))
    eng.reset_dispatch_window()
    while any(r.rid == rid for r in eng.waiting) \
            or any(s.prefilling for s in eng.running.values()):
        eng.step()
    rep = eng.report()
    assert rep["device_dispatches_per_step"] == 1.0
    eng.run_until_done()


def test_unified_requires_chunked_and_fused(small):
    """enable_unified_step quietly degrades to the two-call paths when
    its prerequisites (chunked prefill + fused decode) are off."""
    cfg, params = small
    eng = _engine(cfg, params, enable_chunked_prefill=False)
    assert not eng.unified
    eng = _engine(cfg, params, use_fused=False)
    assert not eng.unified
    prompts = _prompts(2, seed=71)
    a, _ = _drain(eng, prompts, [SamplingParams(max_tokens=4)] * 2)
    b, _ = _drain(_engine(cfg, params, use_fused=False,
                          enable_unified_step=False),
                  prompts, [SamplingParams(max_tokens=4)] * 2)
    assert a == b
