"""Benchmark harness: one table per paper figure/claim.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.py).
  fig2/fig3        -> paper Fig.2 / Fig.3  (bench_serving)
  attn_*           -> §II.C GQA compute/memory claims (bench_attention)
  paging_*         -> §III.A paged memory management (bench_paging)
  gptq_*, w4a16_*  -> GPTQ quantization quality + W4A16 (bench_gptq)
  paged_attn_*     -> custom-kernel microbench (bench_kernels)
"""
from __future__ import annotations

from benchmarks import (bench_attention, bench_gptq, bench_kernels,
                        bench_paging, bench_serving)


def main() -> None:
    print("name,us_per_call,derived")
    for mod in (bench_attention, bench_paging, bench_gptq, bench_kernels,
                bench_serving):
        mod.run()


if __name__ == "__main__":
    main()
