"""Paged vs contiguous KV cache: fragmentation / utilization (paper §III.A).

Simulates a serving trace with mixed prompt lengths. Contiguous allocation
must reserve max_seq_len per sequence; paging allocates blocks on demand
and shares full prefix blocks."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.paged_cache import BlockAllocator


def run() -> None:
    rng = np.random.default_rng(0)
    bs, max_len, n_seqs = 16, 512, 64
    total_blocks = n_seqs * max_len // bs
    shared_prefix = list(rng.integers(0, 1000, 64))

    # paged
    a = BlockAllocator(total_blocks, bs)
    used_tokens = 0
    for _ in range(n_seqs):
        n = int(rng.integers(20, 300))
        a.allocate_prompt(shared_prefix + list(rng.integers(0, 1000, n)))
        used_tokens += 64 + n
    paged_blocks = a.num_blocks - a.num_free
    contiguous_blocks = n_seqs * (max_len // bs)     # reservation-based
    ideal_blocks = int(np.ceil(used_tokens / bs))
    emit("paging_utilization", 0.0,
         f"paged={paged_blocks};contiguous={contiguous_blocks};"
         f"ideal={ideal_blocks};"
         f"paged_over_ideal={paged_blocks/ideal_blocks:.3f};"
         f"contig_over_ideal={contiguous_blocks/ideal_blocks:.3f};"
         f"reused={a.stats['reused']}")
