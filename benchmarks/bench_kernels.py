"""Kernel microbench: Pallas (interpret) vs XLA ref correctness+cost note.

Wall times in interpret mode are NOT TPU times; the emitted 'derived'
column carries the analytic VMEM/MXU utilization figures instead."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core.alibi import alibi_slopes
from repro.kernels import ref


def run() -> None:
    key = jax.random.PRNGKey(0)
    # paged decode: the paper'score serving kernel
    B, H, KV, D, BS, MB = 8, 8, 2, 64, 16, 16
    NB = B * MB
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    kp = jax.random.normal(ks[1], (NB, BS, KV, D), jnp.float32)
    vp = jax.random.normal(ks[2], (NB, BS, KV, D), jnp.float32)
    bt = jnp.arange(NB, dtype=jnp.int32).reshape(B, MB)
    sl = jnp.full((B,), MB * BS, jnp.int32)
    slo = alibi_slopes(H)
    f_ref = jax.jit(lambda *a: ref.paged_attention_ref(*a, alibi_slopes=slo))
    us_ref = timeit(f_ref, q, kp, vp, bt, sl)
    kv_bytes = 2 * NB * BS * KV * D * 4
    ai = (4 * B * H * MB * BS * D) / kv_bytes
    emit("paged_attn_ref", us_ref,
         f"kv_bytes={kv_bytes};arith_intensity={ai:.2f};"
         f"opt_gqa_reuse=G{H//KV}")
