"""Shared benchmark utilities: timed jit calls, CSV emission."""
from __future__ import annotations

import time
from typing import Callable, List

import jax

ROWS: List[str] = []


def timeit(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time (us) of a jitted call, post-warmup."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row)
