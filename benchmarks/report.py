"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from
experiments/dryrun/*.json, plus the benchmark-row JSON emitter used by CI
to track the serving perf trajectory (BENCH_serving.json).

    PYTHONPATH=src python -m benchmarks.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import platform
from typing import Dict, List


def load(d: str) -> List[Dict]:
    rows = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            rows.append(json.load(f))
    return rows


def fmt_si(x) -> str:
    if x is None:
        return "-"
    for u, m in (("P", 1e15), ("T", 1e12), ("G", 1e9), ("M", 1e6), ("k", 1e3)):
        if abs(x) >= m:
            return f"{x/m:.2f}{u}"
    return f"{x:.2f}"


def dryrun_table(rows: List[Dict]) -> str:
    out = ["| arch | shape | mesh | status | µbatch | GiB/dev | fits 16G | "
           "collective schedule (scan-body bytes/dev) |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") == "ok":
            m = r.get("memory", {})
            cs = r.get("coll_schedule_scanbody", {})
            sched = " ".join(f"{k.replace('collective-','c-')}:{fmt_si(v)}B"
                             for k, v in sorted(cs.items()))
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                f"{r.get('num_microbatches','-')} | "
                f"{m.get('bytes_per_device_gib','-')} | "
                f"{'✓' if m.get('fits_hbm') else '✗'} | {sched} |")
        else:
            out.append(f"| {r['arch']} | {r['shape']} | "
                       f"{r.get('mesh','-')} | {r.get('status')} | - | - | "
                       f"- | {r.get('error','')[:60]} |")
    return "\n".join(out)


def roofline_table(rows: List[Dict]) -> str:
    out = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
           "bottleneck | model GF/chip | useful-flop | roofline frac | "
           "what moves the dominant term |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        if r.get("mesh") != "16x16":
            continue
        x = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {x['t_compute']:.3f} | "
            f"{x['t_memory']:.3f} | {x['t_collective']:.3f} | "
            f"{x['bottleneck']} | {fmt_si(x['model_flops_per_chip'])} | "
            f"{(x['useful_flop_frac'] or 0):.3f} | "
            f"{(x['roofline_frac'] or 0):.4f} | {hint(r)} |")
    return "\n".join(out)


def hint(r: Dict) -> str:
    x = r["roofline"]
    b = x["bottleneck"]
    kind = r["shape"].split("_")[0]
    if kind in ("decode", "long"):
        return ("int4/int8 KV cache + weights (GPTQ) cuts the dominant "
                "HBM stream" if b == "memory" else
                "batch more sequences per chip")
    if b == "collective":
        return "sequence-sharded (SP) resharding: all-reduce -> RS+AG halves bytes"
    if b == "memory":
        return "fewer f32 intermediates (bf16 norms/rope), larger fused regions"
    return "near roofline: tile/layout tuning only"


def write_bench_json(rows: List[str], path: str, **meta) -> None:
    """Persist ``name,us_per_call,derived`` CSV rows as structured JSON.

    Each row becomes {"name", "us_per_call", derived keys...}; ``meta``
    (e.g. smoke=True) is stored alongside so trajectories stay comparable
    across CI runs.
    """
    out: Dict = {"meta": {"backend": _backend(), "python":
                          platform.python_version(), **meta},
                 "rows": []}
    for row in rows:
        name, us, derived = row.split(",", 2)
        entry: Dict = {"name": name, "us_per_call": float(us)}
        for kv in filter(None, derived.split(";")):
            k, _, v = kv.partition("=")
            try:
                entry[k] = float(v)
            except ValueError:
                entry[k] = v
        out["rows"].append(entry)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)


def _backend() -> str:
    try:
        import jax
        return jax.default_backend()
    except Exception:
        return "unknown"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    rows = load(args.dir)
    print("## §Dry-run\n")
    print(dryrun_table(rows))
    print("\n## §Roofline (single pod, 16x16)\n")
    print(roofline_table(rows))
    n_ok = sum(r.get("status") == "ok" for r in rows)
    n_skip = sum(str(r.get("status", "")).startswith("skip") for r in rows)
    print(f"\ncells: {len(rows)} files, {n_ok} ok, {n_skip} documented skips")


if __name__ == "__main__":
    main()
