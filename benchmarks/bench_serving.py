"""Paper Fig.2 + Fig.3: MHA vs Opt-GQA serving metrics, and run stability.

Small same-shape models on CPU: 'mha' (kv=H, contiguous-style oversized
blocks, no reuse) vs 'opt-gqa' (kv=H/4, paged, prefix reuse, ALiBi-ready).
Reported: latency, all-throughput (req/s, tok/s), generate throughput —
exactly the paper's three numbers (ratios are the transferable signal) —
plus streamed time-to-first-token (``ttft_ms``), measured at the moment
the engine emits a request's first ``RequestOutput`` delta.

``table_fastpath`` quantifies the fused decode megastep against the legacy
per-token loop on the same workload: per-engine-step decode latency,
host↔device syncs per decode step, TTFT and generate throughput.
``table_kv_memory`` records the quantized-KV trade: pool bytes and KV
bytes per cached token for the dense vs int8 pool (``kvmem_bf16`` /
``kvmem_int8`` rows), with the warm fused decode-step latency as the
cost axis. ``table_guards`` measures the robustness guards' warm-step
cost (``guards_on`` / ``guards_off`` rows; ``--assert-guard-overhead
1.02`` is the <2% acceptance gate). ``table_telemetry`` measures the
obs span tracer the same way (``telemetry_on`` / ``telemetry_off`` rows,
``--assert-telemetry-overhead 1.02``), and ``unified_*`` rows carry the
span-derived ``host_ms`` / ``device_ms`` per-step attribution (ROADMAP
item 1, measured). ``table_async`` compares the async pipelined step
(``async_on``: enqueue N+1 while N executes, readback deferred one
step) against the two-call synchronous path (``async_off``) on the
mixed workload; ``--assert-async-itl 1.0`` is the hard gate that the
pipelined ITL p50 stays at or under the two-call path's in the same
run.  Noisy latency tables (``fastpath``/``kvmem``/``guards``/
``telemetry``/``async``) share the interleaved paired-rep design
(``_paired_best``). Run as a module for smoke mode + JSON trajectory
tracking::

    PYTHONPATH=src python -m benchmarks.bench_serving --smoke \
        --json BENCH_serving.json \
        [--assert-baseline BENCH_serving.json --regress-factor 1.10]

``--assert-baseline`` fails the run if the fused warm decode-step latency
regressed past ``--regress-factor`` × the committed baseline row.
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.registry import get_reduced
from repro.models import transformer as T
from repro.serving import SamplingParams, ServingEngine


def _run_engine(cfg, params, seed=0, *, n_requests=12, max_tokens=8,
                use_fused=True, max_horizon=8, kv_cache_dtype="bf16"):
    # enable_async_step=False everywhere except table_async: the legacy
    # tables measure sync-path dimensions (fused vs loop, pool dtype,
    # guard/tracer overhead) and their windows must not absorb the
    # chained async executable's one-time compile — the async dimension
    # has its own paired table and gate
    eng = ServingEngine(cfg, params, max_slots=4, num_blocks=256,
                        max_blocks_per_seq=16, prefill_bucket=32,
                        max_num_batched_tokens=64,
                        use_fused=use_fused, max_horizon=max_horizon,
                        kv_cache_dtype=kv_cache_dtype,
                        enable_async_step=False)
    rng = np.random.default_rng(seed)
    prefix = list(rng.integers(1, 200, 24))
    sp = SamplingParams(max_tokens=max_tokens)
    for _ in range(n_requests):
        eng.add(prefix + list(rng.integers(1, 200,
                                           int(rng.integers(4, 24)))), sp)
    return eng.run_until_done()


def _paired_best(reps, variants, key="decode_step_latency_us"):
    """Interleaved paired-rep de-noising (``table_guards``' design,
    factored out): each rep runs every variant back to back, so machine
    drift and load spikes hit all variants alike; the per-variant row
    keeps the BEST (minimum-``key``) rep — min, not mean, because
    scheduler noise only ever adds time.  For two-variant tables the
    returned ratio list holds each rep's second/first ``key`` ratio —
    overhead gates read its minimum (a busy runner inflates pairs, never
    deflates them, so the best pair is the honest intrinsic cost)."""
    best, ratios = {}, []
    for _ in range(reps):
        pair = []
        for name, fn in variants:
            r = fn()
            pair.append(r[key])
            if name not in best or r[key] < best[name][key]:
                best[name] = r
        if len(pair) == 2:
            ratios.append(pair[1] / pair[0])
    return best, ratios


def table_fig2(smoke: bool = False) -> None:
    key = jax.random.PRNGKey(0)
    for name, kv in (("mha", 8), ("opt-gqa", 2)):
        cfg = get_reduced("qwen1.5-0.5b", num_layers=4, num_heads=8,
                          num_kv_heads=kv)
        if name == "mha":
            cfg = cfg.replace(paging=cfg.paging.__class__(
                block_size=16, enable_prefix_reuse=False))
        params = T.init_params(cfg, key)
        r = _run_engine(cfg, params, n_requests=4 if smoke else 12)
        emit(f"fig2_{name}", r["latency_s"] * 1e6,
             f"req_s={r['throughput_req_s']:.3f};"
             f"tok_s={r['throughput_tok_s']:.1f};"
             f"gen_tok_s={r['generate_tok_s']:.1f};"
             f"ttft_ms={r['ttft_s'] * 1e3:.1f};"
             f"reused={r['blocks_reused']}")


def table_fig3(smoke: bool = False) -> None:
    key = jax.random.PRNGKey(0)
    cfg = get_reduced("qwen1.5-0.5b", num_layers=4, num_heads=8,
                      num_kv_heads=2)
    params = T.init_params(cfg, key)
    gen, lat = [], []
    for run_i in range(2 if smoke else 3):
        r = _run_engine(cfg, params, seed=run_i,
                        n_requests=4 if smoke else 12)
        gen.append(r["generate_tok_s"])
        lat.append(r["latency_s"] * 1e6)
        emit(f"fig3_run{run_i}", r["latency_s"] * 1e6,
             f"tok_s={r['throughput_tok_s']:.1f};"
             f"gen_tok_s={r['generate_tok_s']:.1f}")
    # the aggregate row's us_per_call is the mean per-request latency
    # across runs (it used to emit a literal 0.0 placeholder)
    emit("fig3_stability", float(np.mean(lat)),
         f"gen_mean={np.mean(gen):.1f};gen_cv={np.std(gen)/np.mean(gen):.3f}")


def table_fastpath(smoke: bool = False) -> None:
    """Decode fast path: legacy per-token loop vs fused megastep on the
    same workload. The win shows up as fewer host syncs per decode step
    (1.0 -> ~1/horizon) and lower per-step decode latency; ``ttft_ms`` is
    the streamed time-to-first-token (prefill wave -> first emitted
    RequestOutput), which the fused path leaves untouched.  Interleaved
    paired reps (``_paired_best``) de-noise both rows."""
    key = jax.random.PRNGKey(0)
    cfg = get_reduced("qwen1.5-0.5b", num_layers=4, num_heads=8,
                      num_kv_heads=2)
    params = T.init_params(cfg, key)
    # smoke keeps CI fast (horizon 4 still guarantees >= 2 fused dispatches,
    # so per-step latency is warm / post-compile); the full run is long
    # enough that the one-off megastep compile also amortizes in gen_tok_s.
    n_req = 4 if smoke else 12
    mnt = 12 if smoke else 64
    horizon = 4 if smoke else 8
    reps = 2 if smoke else 3

    def one(fused):
        return _run_engine(cfg, params, n_requests=n_req, max_tokens=mnt,
                           use_fused=fused, max_horizon=horizon)

    one(False)                       # warm both jit caches before timing
    one(True)
    best, ratios = _paired_best(reps, [("legacy", lambda: one(False)),
                                       ("fused", lambda: one(True))])
    for name, r in best.items():
        emit(f"fastpath_{name}", r["decode_step_latency_us"],
             f"gen_tok_s={r['generate_tok_s']:.1f};"
             f"ttft_ms={r['ttft_s'] * 1e3:.1f};"
             f"syncs_per_step={r['syncs_per_decode_step']:.3f};"
             f"decode_steps={r['decode_steps']};"
             f"dispatches={r['decode_dispatches']};"
             f"host_syncs={r['host_syncs']};"
             + (f"pair_ratio_min={min(ratios):.4f};" if name == "fused"
                else "")
             + f"reps={reps}")


def table_kv_memory(smoke: bool = False) -> None:
    """KV-cache memory: the same fused workload through the dense pool and
    the int8 quantized pool. ``us_per_call`` is the warm fused decode-step
    latency (the int8 path must stay close to the dense one); the derived
    columns record the memory win — ``kv_pool_bytes`` / ``kv_bytes_per_tok``
    drop ~2x vs bf16 pools and ~4x vs these f32 CPU pools, which is the
    admissible-batch/context headroom the quantization buys.
    Interleaved paired reps (``_paired_best``) de-noise the latency
    axis; the memory columns are deterministic."""
    key = jax.random.PRNGKey(0)
    cfg = get_reduced("qwen1.5-0.5b", num_layers=4, num_heads=8,
                      num_kv_heads=2)
    params = T.init_params(cfg, key)
    n_req = 4 if smoke else 12
    mnt = 12 if smoke else 64
    reps = 2 if smoke else 3

    def one(name):
        return _run_engine(cfg, params, n_requests=n_req, max_tokens=mnt,
                           kv_cache_dtype=name)

    one("bf16")                      # warm both jit caches before timing
    one("int8")
    best, ratios = _paired_best(reps, [("bf16", lambda: one("bf16")),
                                       ("int8", lambda: one("int8"))])
    for name, r in best.items():
        emit(f"kvmem_{name}", r["decode_step_latency_us"],
             f"kv_pool_bytes={int(r['kv_pool_bytes'])};"
             f"kv_bytes_per_tok={r['kv_bytes_per_token']:.1f};"
             f"gen_tok_s={r['generate_tok_s']:.1f};"
             f"ttft_ms={r['ttft_s'] * 1e3:.1f};"
             + (f"pair_ratio_min={min(ratios):.4f};" if name == "int8"
                else "")
             + f"reps={reps}")


def table_guards(smoke: bool = False) -> None:
    """Robustness-guard overhead: the same fused decode workload with the
    non-finite sampling guard compiled in (``enable_guards=True``, the
    default) vs compiled out.  The guard is a trace-static flag — guards
    off re-traces to the exact pre-guard program — so the warm fused
    decode-step latency must be indistinguishable; each row is the min
    over ``reps`` runs (min, not mean: scheduler noise only ever adds
    time)."""
    key = jax.random.PRNGKey(0)
    cfg = get_reduced("qwen1.5-0.5b", num_layers=4, num_heads=8,
                      num_kv_heads=2)
    params = T.init_params(cfg, key)
    n_req = 4 if smoke else 12
    mnt = 12 if smoke else 64
    reps = 3 if smoke else 5

    def one(guards):
        eng = ServingEngine(cfg, params, max_slots=4, num_blocks=256,
                            max_blocks_per_seq=16,
                            max_num_batched_tokens=64, max_horizon=4,
                            enable_guards=guards, enable_async_step=False)
        rng = np.random.default_rng(0)
        prefix = list(rng.integers(1, 200, 24))
        sp = SamplingParams(max_tokens=mnt)
        for _ in range(n_req):
            eng.add(prefix + list(rng.integers(
                1, 200, int(rng.integers(4, 24)))), sp)
        return eng.run_until_done()

    one(True)                        # warm both jit caches before timing
    one(False)
    # paired design: each rep times off then on back-to-back, and the
    # gate reads the BEST pair's ratio — load spikes only ever inflate a
    # pair, so one clean pair suffices to show the guard costs nothing
    best, ratios = _paired_best(reps, [("off", lambda: one(False)),
                                       ("on", lambda: one(True))])
    for name, r in best.items():
        emit(f"guards_{name}", r["decode_step_latency_us"],
             f"gen_tok_s={r['generate_tok_s']:.1f};"
             f"dispatches_per_step={r['device_dispatches_per_step']:.2f};"
             + (f"pair_ratio_min={min(ratios):.4f};" if name == "on" else "")
             + f"reps={reps}")


def table_chunked_prefill(smoke: bool = False) -> None:
    """Mixed workload: one long prompt arrives over a warm decoding
    batch.  Stop-the-world prefill (``chunked_prefill_off``) stalls every
    running request for the whole-prompt duration — the stall lands in
    ``itl_p99`` (the ``us_per_call`` column) — and pays a fresh prefill
    compile per (wave, bucket) shape.  The token-budget planner
    (``chunked_prefill_on``) interleaves the prompt's chunks between
    decode steps: ITL p99 drops to O(chunk), TTFT of the long request is
    reported as ``ttft_long_ms``, and the chunk executable compiles
    exactly once (asserted here — the recompile-explosion acceptance
    gate)."""
    import time as _time
    key = jax.random.PRNGKey(0)
    cfg = get_reduced("qwen1.5-0.5b", num_layers=4, num_heads=8,
                      num_kv_heads=2)
    params = T.init_params(cfg, key)
    long_len = 256 if smoke else 1024
    bs = cfg.paging.block_size
    mb = long_len // bs + 4
    itl = {}
    for name, chunked in (("off", False), ("on", True)):
        eng = ServingEngine(cfg, params, max_slots=4, num_blocks=mb + 32,
                            max_blocks_per_seq=mb, prefill_bucket=64,
                            enable_chunked_prefill=chunked,
                            max_num_batched_tokens=128, max_horizon=4,
                            enable_async_step=False)
        rng = np.random.default_rng(0)
        sp = SamplingParams(max_tokens=32 if smoke else 64)
        for _ in range(3):
            eng.add(list(rng.integers(1, 200, int(rng.integers(8, 24)))), sp)
        for _ in range(4):
            eng.step()                      # the short batch is decoding
        eng.reset_itl_window()              # ITL window: steady state only
        rid = eng.add(list(rng.integers(1, 200, long_len)),
                      SamplingParams(max_tokens=8))
        t_arr = _time.perf_counter()
        eng.run_until_done()
        rep = eng.report()
        rec = next(r for r in eng.finished if r.rid == rid)
        ttft_long = (rec.first_token_t - t_arr) * 1e3
        itl[name] = rep["itl_p99_ms"]
        # budget_util only exists in chunked mode, and prefill_compiles
        # is NaN if the private jax cache API drifted; never emit NaN
        # (it would make the committed BENCH_serving.json invalid JSON)
        util = (f"budget_util={rep['budget_utilization']:.2f};"
                if np.isfinite(rep["budget_utilization"]) else "")
        compiles = rep["prefill_compiles"]
        emit(f"chunked_prefill_{name}", rep["itl_p99_ms"] * 1e3,
             f"itl_p50_ms={rep['itl_p50_ms']:.2f};"
             f"ttft_long_ms={ttft_long:.1f};"
             f"prefill_chunks={int(rep['prefill_chunks'])};"
             + (f"prefill_compiles={int(compiles)};"
                if np.isfinite(compiles) else "")
             + f"{util}"
             f"gen_tok_s={rep['generate_tok_s']:.1f}")
        if chunked:
            if not np.isfinite(compiles):
                print("skipping compile-count gate: jax jit _cache_size "
                      "API unavailable (drift, not a regression)")
            else:
                assert compiles == 1, \
                    f"chunk executable compiled {compiles:.0f}x"
    assert itl["on"] < itl["off"], \
        f"chunked ITL p99 {itl['on']:.1f}ms not under " \
        f"stop-the-world {itl['off']:.1f}ms"


def table_unified(smoke: bool = False) -> None:
    """Unified single-dispatch step vs the two-call mixed execute on the
    PR 4 mixed workload (one long prompt chunking over a warm decoding
    batch).  ``unified_on`` must show EXACTLY 1.0 device dispatches per
    engine iteration across the steady mixed window (the two-call path
    pays a decode dispatch + a chunk dispatch + a first-token sample
    dispatch, ~2-3), with mixed-workload ITL p99 at or under the
    two-call baseline and the unified executable compiled once."""
    import time as _time
    key = jax.random.PRNGKey(0)
    cfg = get_reduced("qwen1.5-0.5b", num_layers=4, num_heads=8,
                      num_kv_heads=2)
    params = T.init_params(cfg, key)
    long_len = 256 if smoke else 1024
    bs = cfg.paging.block_size
    mb = long_len // bs + 4
    itl = {}
    disp = {}
    for name, unified in (("off", False), ("on", True)):
        eng = ServingEngine(cfg, params, max_slots=4, num_blocks=mb + 32,
                            max_blocks_per_seq=mb,
                            enable_unified_step=unified,
                            max_num_batched_tokens=128, max_horizon=4,
                            enable_async_step=False)
        rng = np.random.default_rng(0)
        sp = SamplingParams(max_tokens=32 if smoke else 64)
        for _ in range(3):
            eng.add(list(rng.integers(1, 200, int(rng.integers(8, 24)))), sp)
        # warm-up prompt longer than the budget: compiles every mixed-
        # phase executable (chunk / unified / sample) BEFORE the measured
        # window, so the ITL comparison is steady-state on both paths
        eng.add(list(rng.integers(1, 200, 160)), SamplingParams(max_tokens=2))
        while any(s.prefilling for s in eng.running.values()) or \
                len(eng.finished) < 1:
            eng.step()                      # warm-up prompt in and out
        for _ in range(4):
            eng.step()                      # the short batch is decoding
        eng.reset_itl_window()              # steady state only: compiles
        eng.reset_dispatch_window()         # and warm-up CoW excluded
        rid = eng.add(list(rng.integers(1, 200, long_len)),
                      SamplingParams(max_tokens=8))
        t_arr = _time.perf_counter()
        # measure the dispatch window over the mixed phase only (the
        # all-decode drain after the prompt lands is megastep territory
        # on both paths)
        mixed_steps = 0
        while any(s.prefilling for s in eng.running.values()) or \
                any(r.rid == rid for r in eng.waiting):
            eng.step()
            mixed_steps += 1
        rep_mixed = eng.report()
        disp[name] = rep_mixed["device_dispatches_per_step"]
        # ROADMAP item 1, measured: host-vs-device wall-time split per
        # mixed-phase step (obs span attribution) — the host share is
        # the serialization the async engine direction would overlap
        attr = eng.attribution(window=mixed_steps)
        eng.run_until_done()
        rep = eng.report()
        rec = next(r for r in eng.finished if r.rid == rid)
        ttft_long = (rec.first_token_t - t_arr) * 1e3
        itl[name] = rep["itl_p99_ms"]
        compiles = rep["prefill_compiles"]
        emit(f"unified_{name}", rep["itl_p99_ms"] * 1e3,
             f"itl_p50_ms={rep['itl_p50_ms']:.2f};"
             f"dispatches_per_step={disp[name]:.2f};"
             f"ttft_long_ms={ttft_long:.1f};"
             + (f"host_ms={attr['host_ms']:.3f};"
                f"device_ms={attr['device_ms']:.3f};"
                if np.isfinite(attr["host_ms"]) else "")
             + (f"prefill_compiles={int(compiles)};"
                if np.isfinite(compiles) else "")
             + f"gen_tok_s={rep['generate_tok_s']:.1f}")
        if unified:
            assert disp["on"] == 1.0, \
                f"unified mixed step dispatched {disp['on']:.2f}x/step"
            if np.isfinite(compiles):
                assert compiles == 1, \
                    f"unified executable compiled {compiles:.0f}x"
    assert disp["off"] >= 1.5, \
        f"two-call path reads {disp['off']:.2f} dispatches/step — the " \
        "comparison lost its baseline"
    # acceptance: unified ITL p99 at or under the two-call baseline
    # (1.05 slack absorbs CI timer noise; the dispatch assert above is
    # the deterministic gate)
    assert itl["on"] <= itl["off"] * 1.05, \
        f"unified ITL p99 {itl['on']:.2f}ms above two-call " \
        f"{itl['off']:.2f}ms"


def table_async(smoke: bool = False) -> None:
    """Async pipelined step vs the synchronous two-call mixed execute on
    a SUSTAINED mixed workload: a queue of long prompts chunks over a
    warm decoding batch for the whole measured window, so the steady
    state being timed is the mixed phase the pipeline optimizes (a
    single long prompt's 2-3 chunk steps drown in the all-decode drain).
    ``async_on`` plans and enqueues dispatch N+1 while N executes on
    device — token readback deferred exactly one step
    (``enable_async_step=True``, the default); ``async_off`` is the
    two-call path (``enable_unified_step=False``) that reads back every
    step.  Interleaved paired reps; the ``--assert-async-itl`` gate
    reads the best back-to-back pair's ITL p50 ratio.  The async row
    must keep EXACTLY 1.0 device dispatches per mixed step, actually
    pipeline (``async_steps > 0``), and compile the chained unified
    executable exactly once (zero steady-state recompiles)."""
    import time as _time
    key = jax.random.PRNGKey(0)
    cfg = get_reduced("qwen1.5-0.5b", num_layers=4, num_heads=8,
                      num_kv_heads=2)
    params = T.init_params(cfg, key)
    long_len = 256 if smoke else 512
    bs = cfg.paging.block_size
    mb = long_len // bs + 4
    n_long = 3 if smoke else 5
    reps = 2 if smoke else 3

    def one(name):
        kw = dict(enable_async_step=True) if name == "on" else \
            dict(enable_unified_step=False, enable_async_step=False)
        eng = ServingEngine(cfg, params, max_slots=4,
                            num_blocks=4 * mb + 32, max_blocks_per_seq=mb,
                            max_num_batched_tokens=128, max_horizon=4,
                            **kw)
        rng = np.random.default_rng(0)
        # the short batch must keep decoding through the whole mixed
        # window (finished slots would thin the decode rows both paths
        # share and admit longs in bursts, adding admission noise)
        sp = SamplingParams(max_tokens=64)
        for _ in range(3):
            eng.add(list(rng.integers(1, 200, int(rng.integers(8, 24)))),
                    sp)
        # warm-up prompt longer than the budget compiles every mixed-
        # phase executable before the measured window (see table_unified)
        eng.add(list(rng.integers(1, 200, 160)),
                SamplingParams(max_tokens=2))
        while any(s.prefilling for s in eng.running.values()) or \
                len(eng.finished) < 1:
            eng.step()
        for _ in range(4):
            eng.step()                      # the short batch is decoding
        eng.reset_itl_window()              # steady state only
        eng.reset_dispatch_window()
        longs = {eng.add(list(rng.integers(1, 200, long_len)),
                         SamplingParams(max_tokens=8))
                 for _ in range(n_long)}
        t_arr = _time.perf_counter()
        mixed_steps = 0
        while sum(1 for r in eng.finished if r.rid in longs) < n_long:
            eng.step()
            mixed_steps += 1
        # percentiles read HERE cover exactly the mixed window (the
        # all-decode drain that follows is identical megastep territory
        # on both paths and would only dilute the comparison)
        rep_mixed = eng.report()
        attr = eng.attribution(window=mixed_steps)
        eng.run_until_done()
        rep = eng.report()
        rec = next(r for r in eng.finished if r.rid == min(longs))
        eng.close()
        return {"itl_p50_ms": rep_mixed["itl_p50_ms"],
                "itl_p99_ms": rep_mixed["itl_p99_ms"],
                "dispatches": rep_mixed["device_dispatches_per_step"],
                "async_steps": rep["async_steps"],
                "compiles": rep["prefill_compiles"],
                "host_ms": attr["host_ms"], "device_ms": attr["device_ms"],
                "ttft_long_ms": (rec.first_token_t - t_arr) * 1e3,
                "gen_tok_s": rep["generate_tok_s"]}

    one("off")                       # warm both jit caches before timing
    one("on")
    best, ratios = _paired_best(reps, [("off", lambda: one("off")),
                                       ("on", lambda: one("on"))],
                                key="itl_p50_ms")
    for name, r in best.items():
        emit(f"async_{name}", r["itl_p50_ms"] * 1e3,
             f"itl_p99_ms={r['itl_p99_ms']:.2f};"
             f"dispatches_per_step={r['dispatches']:.2f};"
             f"async_steps={int(r['async_steps'])};"
             f"ttft_long_ms={r['ttft_long_ms']:.1f};"
             + (f"host_ms={r['host_ms']:.3f};"
                f"device_ms={r['device_ms']:.3f};"
                if np.isfinite(r["host_ms"]) else "")
             + (f"prefill_compiles={int(r['compiles'])};"
                if np.isfinite(r["compiles"]) else "")
             + (f"pair_ratio_min={min(ratios):.4f};" if name == "on"
                else "")
             + f"gen_tok_s={r['gen_tok_s']:.1f}")
    on, off = best["on"], best["off"]
    assert on["dispatches"] == 1.0, \
        f"async mixed step dispatched {on['dispatches']:.2f}x/step"
    assert on["async_steps"] > 0, "the pipeline never engaged"
    assert off["async_steps"] == 0, "the sync oracle speculated"
    if np.isfinite(on["compiles"]):
        assert on["compiles"] == 1, \
            f"chained unified executable compiled {on['compiles']:.0f}x"


def assert_async_itl(rows, max_ratio: float) -> None:
    """Acceptance gate (hard): the async pipelined step's steady-state
    ITL p50 must not exceed ``max_ratio`` x the two-call synchronous
    path's in the same run (1.0 = at or under it).  Reads the best
    back-to-back (off, on) pair ratio from ``table_async`` — load
    spikes inflate pairs, never deflate them, so the minimum pair ratio
    is the honest estimate."""
    ratio = None
    for row in rows:
        name, _, derived = row.split(",", 2)
        if name == "async_on":
            for field in derived.split(";"):
                if field.startswith("pair_ratio_min="):
                    ratio = float(field.split("=", 1)[1])
    assert ratio is not None, "async_on row (pair_ratio_min) missing"
    if ratio > max_ratio:
        print(f"REGRESSION: async/two-call ITL p50 pair ratio "
              f"{ratio:.4f} > {max_ratio:.2f}", file=sys.stderr)
        sys.exit(1)
    print(f"async/two-call ITL p50 pair ratio {ratio:.4f} "
          f"(allowed {max_ratio:.2f}): OK")


def table_telemetry(smoke: bool = False) -> None:
    """Span-tracer overhead: the same fused decode workload with the obs
    tracer recording every step (``enable_telemetry=True``, the default)
    vs handing out the no-op singleton.  The hot-path cost is two
    ``perf_counter_ns`` calls and a deque append per span, so the warm
    fused decode step must be indistinguishable; same paired design as
    ``table_guards`` (best back-to-back pair ratio, min over reps)."""
    key = jax.random.PRNGKey(0)
    cfg = get_reduced("qwen1.5-0.5b", num_layers=4, num_heads=8,
                      num_kv_heads=2)
    params = T.init_params(cfg, key)
    n_req = 4 if smoke else 12
    mnt = 12 if smoke else 64
    reps = 3 if smoke else 5

    def one(telemetry):
        eng = ServingEngine(cfg, params, max_slots=4, num_blocks=256,
                            max_blocks_per_seq=16,
                            max_num_batched_tokens=64, max_horizon=4,
                            enable_telemetry=telemetry,
                            enable_async_step=False)
        rng = np.random.default_rng(0)
        prefix = list(rng.integers(1, 200, 24))
        sp = SamplingParams(max_tokens=mnt)
        for _ in range(n_req):
            eng.add(prefix + list(rng.integers(
                1, 200, int(rng.integers(4, 24)))), sp)
        return eng.run_until_done()

    one(True)                        # warm both jit caches before timing
    one(False)
    best, ratios = _paired_best(reps, [("off", lambda: one(False)),
                                       ("on", lambda: one(True))])
    for name, r in best.items():
        emit(f"telemetry_{name}", r["decode_step_latency_us"],
             f"gen_tok_s={r['generate_tok_s']:.1f};"
             f"itl_p50_ms={r['itl_p50_ms']:.2f};"
             + (f"pair_ratio_min={min(ratios):.4f};" if name == "on" else "")
             + f"reps={reps}")


def assert_telemetry_overhead(rows, max_ratio: float) -> None:
    """Acceptance gate: recording spans must not change the warm fused
    decode step by more than ``max_ratio`` (1.02 = 2%).  Reads the best
    back-to-back (off, on) pair ratio from ``table_telemetry`` — load
    spikes inflate pairs, never deflate them, so the minimum pair ratio
    is the honest estimate of the tracer's intrinsic cost."""
    ratio = None
    for row in rows:
        name, _, derived = row.split(",", 2)
        if name == "telemetry_on":
            for field in derived.split(";"):
                if field.startswith("pair_ratio_min="):
                    ratio = float(field.split("=", 1)[1])
    assert ratio is not None, "telemetry_on row (pair_ratio_min) missing"
    if ratio > max_ratio:
        print(f"REGRESSION: telemetry-on/off warm-step pair ratio "
              f"{ratio:.4f} > {max_ratio:.2f}", file=sys.stderr)
        sys.exit(1)
    print(f"telemetry-on/off warm-step pair ratio {ratio:.4f} "
          f"(allowed {max_ratio:.2f}): OK")


def assert_no_regression(rows, baseline_path: str, factor: float,
                         smoke: bool = False) -> None:
    """Warm fused decode-step latency must stay within ``factor`` x the
    committed baseline (acceptance: no warm-decode-step regression).
    Only like-for-like comparisons are meaningful: if the baseline was
    recorded in a different mode (smoke vs full workload), the gate is
    skipped with a notice instead of comparing incomparable numbers."""
    with open(baseline_path) as f:
        doc = json.load(f)
    base_smoke = bool(doc.get("meta", {}).get("smoke"))
    if base_smoke != smoke:
        print(f"skipping regression gate: baseline {baseline_path} was "
              f"recorded with smoke={base_smoke}, this run is "
              f"smoke={smoke} (different workloads)")
        return
    base_rows = {r["name"]: r for r in doc["rows"]}
    if "fastpath_fused" not in base_rows:
        print(f"skipping regression gate: {baseline_path} has no "
              f"fastpath_fused row")
        return
    base = base_rows["fastpath_fused"]["us_per_call"]
    cur = None
    for row in rows:
        name, us, _ = row.split(",", 2)
        if name == "fastpath_fused":
            cur = float(us)
    assert cur is not None, "fastpath_fused row missing from this run"
    if cur > base * factor:
        print(f"REGRESSION: fused warm decode step {cur:.1f}us > "
              f"{factor:.2f} x baseline {base:.1f}us", file=sys.stderr)
        sys.exit(1)
    print(f"fused warm decode step {cur:.1f}us vs baseline {base:.1f}us "
          f"(allowed {factor:.2f}x): OK")


def assert_fastpath_ratio(rows, max_ratio: float) -> None:
    """Machine-independent gate: within THIS run, the fused megastep's
    warm decode step must stay under ``max_ratio`` x the legacy loop's.
    Catches the fast path breaking (ratio -> ~1.0) regardless of how
    slow the host is, so it is safe on shared CI runners."""
    us = {}
    for row in rows:
        name, v, _ = row.split(",", 2)
        if name in ("fastpath_legacy", "fastpath_fused"):
            us[name] = float(v)
    ratio = us["fastpath_fused"] / us["fastpath_legacy"]
    if ratio > max_ratio:
        print(f"REGRESSION: fused/legacy warm-step ratio {ratio:.3f} > "
              f"{max_ratio:.2f} ({us['fastpath_fused']:.1f}us vs "
              f"{us['fastpath_legacy']:.1f}us)", file=sys.stderr)
        sys.exit(1)
    print(f"fused/legacy warm-step ratio {ratio:.3f} "
          f"(allowed {max_ratio:.2f}): OK")


def assert_guard_overhead(rows, max_ratio: float) -> None:
    """Acceptance gate: the compiled-in non-finite guard must not change
    the warm fused decode step by more than ``max_ratio`` (e.g. 1.02 =
    2%).  Uses the best back-to-back (off, on) pair's ratio from
    ``table_guards`` — machine-independent AND load-spike-tolerant: a
    busy runner inflates pairs, never deflates them, so the minimum pair
    ratio is the honest estimate of the guard's intrinsic cost."""
    ratio = None
    for row in rows:
        name, _, derived = row.split(",", 2)
        if name == "guards_on":
            for field in derived.split(";"):
                if field.startswith("pair_ratio_min="):
                    ratio = float(field.split("=", 1)[1])
    assert ratio is not None, "guards_on row (pair_ratio_min) missing"
    if ratio > max_ratio:
        print(f"REGRESSION: guards-on/guards-off warm-step pair ratio "
              f"{ratio:.4f} > {max_ratio:.2f}", file=sys.stderr)
        sys.exit(1)
    print(f"guards-on/guards-off warm-step pair ratio {ratio:.4f} "
          f"(allowed {max_ratio:.2f}): OK")


def run(smoke: bool = False) -> None:
    table_fig2(smoke)
    table_fig3(smoke)
    table_fastpath(smoke)
    table_kv_memory(smoke)
    table_guards(smoke)
    table_telemetry(smoke)
    table_chunked_prefill(smoke)
    table_unified(smoke)
    table_async(smoke)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced request counts (CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (e.g. BENCH_serving.json)")
    ap.add_argument("--assert-baseline", default=None, metavar="PATH",
                    help="fail if fused warm decode-step latency regressed "
                         "vs this BENCH_serving.json")
    ap.add_argument("--regress-factor", type=float, default=1.10,
                    help="allowed slowdown factor for --assert-baseline")
    ap.add_argument("--assert-fastpath-ratio", type=float, default=None,
                    metavar="R", help="fail if fused/legacy warm-step "
                    "ratio within this run exceeds R (machine-independent)")
    ap.add_argument("--assert-guard-overhead", type=float, default=None,
                    metavar="R", help="fail if guards_on/guards_off warm-"
                    "step ratio exceeds R (acceptance: 1.02)")
    ap.add_argument("--assert-telemetry-overhead", type=float, default=None,
                    metavar="R", help="fail if telemetry_on/telemetry_off "
                    "warm-step ratio exceeds R (acceptance: 1.02)")
    ap.add_argument("--assert-async-itl", type=float, default=None,
                    metavar="R", help="fail if async_on/async_off ITL p50 "
                    "pair ratio exceeds R (acceptance: 1.0 — the pipelined "
                    "step must be at or under the two-call path)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)
    from benchmarks.common import ROWS
    if args.json:
        from benchmarks.report import write_bench_json
        write_bench_json(ROWS, args.json, smoke=args.smoke)
        print(f"wrote {args.json}")
    if args.assert_baseline:
        assert_no_regression(ROWS, args.assert_baseline,
                             args.regress_factor, smoke=args.smoke)
    if args.assert_fastpath_ratio is not None:
        assert_fastpath_ratio(ROWS, args.assert_fastpath_ratio)
    if args.assert_guard_overhead is not None:
        assert_guard_overhead(ROWS, args.assert_guard_overhead)
    if args.assert_telemetry_overhead is not None:
        assert_telemetry_overhead(ROWS, args.assert_telemetry_overhead)
    if args.assert_async_itl is not None:
        assert_async_itl(ROWS, args.assert_async_itl)


if __name__ == "__main__":
    main()
