"""Paper Fig.2 + Fig.3: MHA vs Opt-GQA serving metrics, and run stability.

Small same-shape models on CPU: 'mha' (kv=H, contiguous-style oversized
blocks, no reuse) vs 'opt-gqa' (kv=H/4, paged, prefix reuse, ALiBi-ready).
Reported: latency, all-throughput (req/s, tok/s), generate throughput —
exactly the paper's three numbers (ratios are the transferable signal)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.registry import get_reduced
from repro.models import transformer as T
from repro.serving.engine import Request, ServingEngine


def _run_engine(cfg, params, seed=0):
    eng = ServingEngine(cfg, params, max_slots=4, num_blocks=256,
                        max_blocks_per_seq=16, prefill_bucket=32)
    rng = np.random.default_rng(seed)
    prefix = list(rng.integers(1, 200, 24))
    for i in range(12):
        eng.add_request(Request(
            rid=i, prompt=prefix + list(rng.integers(1, 200,
                                                     int(rng.integers(4, 24)))),
            max_new_tokens=8))
    return eng.run_until_done()


def table_fig2() -> None:
    key = jax.random.PRNGKey(0)
    for name, kv in (("mha", 8), ("opt-gqa", 2)):
        cfg = get_reduced("qwen1.5-0.5b", num_layers=4, num_heads=8,
                          num_kv_heads=kv)
        if name == "mha":
            cfg = cfg.replace(paging=cfg.paging.__class__(
                block_size=16, enable_prefix_reuse=False))
        params = T.init_params(cfg, key)
        r = _run_engine(cfg, params)
        emit(f"fig2_{name}", r["latency_s"] * 1e6,
             f"req_s={r['throughput_req_s']:.3f};"
             f"tok_s={r['throughput_tok_s']:.1f};"
             f"gen_tok_s={r['generate_tok_s']:.1f};"
             f"reused={r['blocks_reused']}")


def table_fig3() -> None:
    key = jax.random.PRNGKey(0)
    cfg = get_reduced("qwen1.5-0.5b", num_layers=4, num_heads=8,
                      num_kv_heads=2)
    params = T.init_params(cfg, key)
    gen = []
    for run_i in range(3):
        r = _run_engine(cfg, params, seed=run_i)
        gen.append(r["generate_tok_s"])
        emit(f"fig3_run{run_i}", r["latency_s"] * 1e6,
             f"tok_s={r['throughput_tok_s']:.1f};"
             f"gen_tok_s={r['generate_tok_s']:.1f}")
    emit("fig3_stability", 0.0,
         f"gen_mean={np.mean(gen):.1f};gen_cv={np.std(gen)/np.mean(gen):.3f}")


def run() -> None:
    table_fig2()
    table_fig3()
