"""Paper §II.C claims: MHA vs GQA vs Opt-GQA compute/memory reduction.

Verifies the '8 heads -> 2 groups => 50% computation / 50% KV memory'
arithmetic and measures actual CPU wall-time ratios of the XLA lowering
(relative ratios are hardware-portable; absolute numbers are not)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core.gqa import grouped_attention


def run() -> None:
    B, S, H, D = 4, 512, 8, 64
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)

    base_us = None
    for kv in (8, 4, 2, 1):            # MHA -> GQA group sizes
        k = jax.random.normal(key, (B, S, kv, D))
        v = jax.random.normal(key, (B, S, kv, D))
        fn = jax.jit(lambda q, k, v: grouped_attention(q, k, v, causal=True))
        us = timeit(fn, q, k, v)
        base_us = base_us or us
        emit(f"attn_kv{kv}", us,
             f"kv_mem_frac={kv/H:.2f};time_frac={us/base_us:.2f}")
    # paper's example: 8 heads, 2 groups -> KV memory 25% (kv=2), and the
    # K/V-side compute shrinks with the same factor.
