"""GPTQ vs RTN quantization quality + W4A16 matmul (paper title claim)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.configs.base import QuantConfig
from repro.core.gptq import gptq_quantize, quant_error, rtn_quantize
from repro.core.quant import make_quant_params
from repro.kernels.ref import quant_matmul_ref


def run() -> None:
    rng = np.random.default_rng(0)
    din, dout, n = 256, 128, 2048
    x = rng.normal(size=(n, din)) * (1 + 3 * rng.random(din))
    w = rng.normal(size=(din, dout))
    h = 2 * x.T @ x / n
    for gs in (128, 64, 32):
        cfg = QuantConfig(bits=4, group_size=gs)
        e_g = quant_error(w, gptq_quantize(w, h, cfg), h)
        e_r = quant_error(w, rtn_quantize(w, cfg), h)
        emit(f"gptq_vs_rtn_g{gs}", 0.0,
             f"gptq_err={e_g:.5f};rtn_err={e_r:.5f};"
             f"improvement={(e_r-e_g)/e_r*100:.1f}%")
    # matmul: int4 weight bytes = 1/4 of bf16 -> decode-bound speedup bound
    qt = gptq_quantize(w, h, QuantConfig())
    p = make_quant_params(qt)
    xj = jnp.asarray(x[:64], jnp.float32)
    us = timeit(lambda a: quant_matmul_ref(a, p), xj)
    emit("w4a16_matmul_ref", us,
         f"weight_bytes={qt.q.size//2};bf16_bytes={w.size*2};ratio=0.25")
