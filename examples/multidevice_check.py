"""Distributed-correctness check on 8 virtual devices (CPU).

Verifies, with real shardings active:
  1. sharded (DP×TP, FSDP) train step == single-device step (loss/grads),
  2. MoE expert-parallel shard_map path == local ragged path,
  3. paged-decode shard_map island == unsharded decode,
  4. int8 error-feedback compressed gradients ≈ exact gradients, and the
     error buffer absorbs the residual.

    PYTHONPATH=src python examples/multidevice_check.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.configs.registry import get_reduced
from repro.models import transformer as T
from repro.models.moe import moe_apply, moe_init
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.runtime.sharding import make_ctx, param_shardings
from repro.runtime.train_loop import (init_error_buffer,
                                      make_compressed_grad_fn,
                                      make_train_step)


def check(name, a, b, tol=3e-2):
    err = max(float(jnp.abs(x - y).max()) for x, y in
              zip(jax.tree.leaves(a), jax.tree.leaves(b)))
    status = "OK " if err <= tol else "FAIL"
    print(f"  [{status}] {name}: max_err={err:.2e}")
    assert err <= tol, name
    return err


def main():
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    ctx = make_ctx(mesh)
    key = jax.random.PRNGKey(0)

    print("== 1. sharded train step vs single device ==")
    cfg = get_reduced("qwen2-1.5b", num_layers=2, num_heads=4, num_kv_heads=2)
    params = T.init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (8, 33), 0, cfg.vocab_size)}
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = init_opt_state(params, opt_cfg)
    step1 = jax.jit(make_train_step(cfg, opt_cfg, None, {"scan_layers": True}))
    p1, o1, m1 = step1(params, opt, batch)
    ps = jax.device_put(params, param_shardings(ctx, params, cfg))
    step2 = jax.jit(make_train_step(cfg, opt_cfg, ctx, {"scan_layers": True}))
    p2, o2, m2 = step2(ps, init_opt_state(ps, opt_cfg), batch)
    check("loss", m1["loss"], m2["loss"], 1e-2)
    check("updated params", p1, p2)

    print("== 2. MoE EP shard_map vs local ==")
    mcfg = get_reduced("qwen2-moe-a2.7b", num_experts=8, moe_top_k=2)
    mp = moe_init(key, mcfg, ep=2)
    x = jax.random.normal(key, (8, 16, mcfg.d_model))
    y_local = moe_apply(mcfg, mp, x, None)
    y_ep = moe_apply(mcfg, mp, x, ctx)
    check("moe outputs", y_local, y_ep)

    print("== 3. paged-decode island vs unsharded ==")
    dcfg = get_reduced("qwen2-1.5b", num_layers=2, num_heads=4, num_kv_heads=2)
    dparams = T.init_params(dcfg, key)
    B, MB = 8, 4
    st = T.make_decode_state(dcfg, B, B * MB, MB, dtype=jnp.float32)
    # island semantics (DESIGN.md §4): block ids are LOCAL per dp shard;
    # the unsharded reference uses the equivalent GLOBAL numbering (local
    # id + shard * pool_shard_size) so both address the same physical
    # blocks of the same pool.
    st["seq_lens"] = jnp.full((B,), 9, jnp.int32)
    toks = jax.random.randint(key, (B,), 0, dcfg.vocab_size)
    bt_global = jnp.arange(B * MB, dtype=jnp.int32).reshape(B, MB)
    bt_local = jnp.tile(jnp.arange(2 * MB, dtype=jnp.int32).reshape(2, MB),
                        (4, 1))
    l1, s1 = T.decode_step(dcfg, dparams, {**st, "block_table": bt_global},
                           toks, None)
    l2, s2 = T.decode_step(dcfg, dparams, {**st, "block_table": bt_local},
                           toks, ctx)
    check("decode logits", l1, l2)
    check("decode pools", s1["k_pool"], s2["k_pool"])

    print("== 4. int8-EF compressed gradients ==")
    ctx_nofsdp = make_ctx(mesh).__class__(mesh=mesh, dp_axes=("data",),
                                          tp_axis="model", fsdp=False)
    gfn = jax.jit(make_compressed_grad_fn(cfg, ctx_nofsdp,
                                          {"scan_layers": True}))
    err0 = init_error_buffer(ctx_nofsdp, params)
    loss_c, g_c, err1 = gfn(params, batch, err0)
    loss_e, g_e = jax.value_and_grad(
        lambda p: T.loss_fn(cfg, p, batch, None, {"scan_layers": True}))(params)
    check("compressed loss", loss_c, loss_e, 1e-2)
    gnorm = float(jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(g_e))))
    diff = float(jnp.sqrt(sum(jnp.sum((a - b)**2) for a, b in
                              zip(jax.tree.leaves(g_c), jax.tree.leaves(g_e)))))
    enorm = float(jnp.abs(err1).max())
    print(f"  [INFO] |g_c - g_e|/|g_e| = {diff/gnorm:.4f} "
          f"(int8 quantization noise), err-buffer max {enorm:.2e}")
    assert diff / gnorm < 0.25
    assert enorm > 0           # residual captured for next step
    print("\nall distributed-correctness checks passed")


if __name__ == "__main__":
    main()
