"""Offline GPTQ quantization walkthrough (the 'GPTQ' in Opt-GPTQ).

Quantizes one linear layer with the full OBQ loop and compares against
round-to-nearest under the calibration Hessian, then quantizes a whole
reduced model and reports logit drift.

    PYTHONPATH=src python examples/quantize_model.py
"""
import jax
import numpy as np

from repro.configs.base import QuantConfig
from repro.configs.registry import get_reduced
from repro.core.gptq import gptq_quantize, quant_error, rtn_quantize
from repro.models import transformer as T
from repro.models.quantize import gptq_quantize_model, quantize_params_rtn


def main():
    rng = np.random.default_rng(0)
    print("== single layer: GPTQ vs RTN under the calibration Hessian ==")
    din, dout, n = 256, 128, 4096
    x = rng.normal(size=(n, din)) * (1 + 4 * rng.random(din))
    w = rng.normal(size=(din, dout))
    h = 2 * x.T @ x / n
    for bits in (4, 3):
        cfg = QuantConfig(bits=bits, group_size=64)
        eg = quant_error(w, gptq_quantize(w, h, cfg), h)
        er = quant_error(w, rtn_quantize(w, cfg), h)
        print(f"  int{bits}: gptq={eg:.5f}  rtn={er:.5f}  "
              f"(GPTQ {100*(er-eg)/er:.1f}% better)")

    print("\n== whole model: logit drift after int4 quantization ==")
    key = jax.random.PRNGKey(0)
    cfg = get_reduced("qwen2-1.5b", num_layers=2)
    params = T.init_params(cfg, key)
    calib = [{"tokens": jax.random.randint(jax.random.fold_in(key, i),
                                           (2, 32), 0, cfg.vocab_size)}
             for i in range(4)]
    qg = gptq_quantize_model(cfg, params, calib, QuantConfig(group_size=32))
    qr = quantize_params_rtn(params, cfg, group_size=32)
    test = calib[0]
    lf = np.asarray(T.forward(cfg, params, test), np.float64)
    for name, q in (("gptq", qg), ("rtn", qr)):
        lq = np.asarray(T.forward(cfg, q, test), np.float64)
        drift = np.abs(lq - lf).mean()
        agree = (lq.argmax(-1) == lf.argmax(-1)).mean()
        print(f"  {name}: mean|Δlogit|={drift:.4f}  top1-agree={agree:.3f}")
    print("\nweight bytes: int4+scales ≈ 0.28x of fp16 "
          "(4.0b codes + per-group scale/zero)")


if __name__ == "__main__":
    main()
