"""Train a ~100M-param model for a few hundred steps on CPU with the full
production stack (scan layers, remat, AdamW, checkpointing, fault
supervision). This is the end-to-end training driver of deliverable (b):

    PYTHONPATH=src python examples/train_small.py [--steps 300]

Equivalent CLI form (also supports --resume and failure injection):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
        --d-model 256 --steps 300 --batch 8 --seq 128
"""
import argparse
import sys

from repro.launch import train as train_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    sys.argv = ["train", "--arch", "qwen2-1.5b", "--reduced",
                "--d-model", "384", "--layers", "6",
                "--steps", str(args.steps), "--batch", "8", "--seq", "128",
                "--ckpt-dir", "/tmp/repro_train_small"]
    train_cli.main()


if __name__ == "__main__":
    main()
