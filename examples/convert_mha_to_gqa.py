"""Opt-GQA dynamic grouping demo (paper §II.B): convert an MHA checkpoint
(qwen1.5-0.5b-style, kv == heads) to grouped-query attention by
activation-similarity clustering, and measure the quality of the grouping.

    PYTHONPATH=src python examples/convert_mha_to_gqa.py
"""
import jax
import jax.numpy as jnp

from repro.configs.registry import get_reduced
from repro.core.grouping import convert_mha_to_gqa
from repro.models import transformer as T


def main():
    key = jax.random.PRNGKey(0)
    cfg = get_reduced("qwen1.5-0.5b", num_layers=2, num_kv_heads=4,
                      num_heads=8)
    # an "MHA checkpoint": kv == heads
    mha_cfg = cfg.replace(num_kv_heads=cfg.num_heads)
    params = T.init_params(mha_cfg, key)

    # calibration: collect key activations per head from layer 0
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    toks = jax.random.randint(key, (4, 64), 0, cfg.vocab_size)
    x = params["embed"][toks].astype(jnp.float32)
    H, Dh = mha_cfg.num_heads, mha_cfg.resolved_head_dim
    k_acts = jnp.einsum("bsd,dhk->hbsk", x, lp["attn"]["wk"]).reshape(H, -1, Dh)

    conv = convert_mha_to_gqa(lp["attn"]["wq"], lp["attn"]["wk"],
                              lp["attn"]["wv"], k_acts,
                              num_kv_heads=cfg.num_kv_heads)
    print(f"groups (by activation similarity): {conv.groups}")
    print(f"intra-group sim {conv.intra_sim:.3f} vs inter-group "
          f"{conv.inter_sim:.3f}")
    print(f"merged K/V shapes: {conv.wk.shape} {conv.wv.shape} "
          f"(was {lp['attn']['wk'].shape})")
    print(f"KV cache memory after conversion: "
          f"{cfg.num_kv_heads / mha_cfg.num_heads:.0%} of MHA")


if __name__ == "__main__":
    main()
