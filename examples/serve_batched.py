"""End-to-end streamed serving: continuous request intake under preemption
pressure, consuming ``RequestOutput`` deltas as horizons complete.

Requests are added *while* the stream is being consumed (Poisson-ish
arrivals), each with its own ``SamplingParams`` — greedy, temperature and
top-p requests share every batch. Ends with the paper's metric report.

    PYTHONPATH=src python examples/serve_batched.py [--requests 24] \
        [--max-waiting 8 --shed-policy shed-oldest] [--deadline-ms 5000]
"""
import argparse

import numpy as np

from repro.serving import EngineOverloadedError, LLM, SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--blocks", type=int, default=96,
                    help="small pool => exercises preemption")
    ap.add_argument("--max-waiting", type=int, default=None,
                    help="bound the waiting queue (load shedding)")
    ap.add_argument("--shed-policy", choices=("reject", "shed-oldest"),
                    default="reject",
                    help="what to do when the waiting queue is full")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request end-to-end deadline (finish_reason"
                         "='deadline' on expiry)")
    args = ap.parse_args()

    llm = LLM.load(args.arch, reduced=True, overrides=dict(num_layers=4),
                   max_slots=6, num_blocks=args.blocks,
                   max_blocks_per_seq=12, prefill_bucket=32,
                   max_waiting=args.max_waiting,
                   shed_policy=args.shed_policy)
    eng = llm.engine

    rng = np.random.default_rng(0)
    prefix = list(rng.integers(1, 200, 24))

    def make_request(i):
        prompt = prefix + list(rng.integers(1, 200, int(rng.integers(4, 40))))
        sp = SamplingParams(
            temperature=0.7 if i % 3 == 0 else 0.0,
            top_p=0.9 if i % 3 == 0 else 1.0,
            max_tokens=int(rng.integers(4, 16)),
            deadline_ms=args.deadline_ms)
        return prompt, sp

    rejected = 0

    def submit(req):
        nonlocal rejected
        try:
            eng.add(*req)
        except EngineOverloadedError:
            rejected += 1     # --shed-policy reject with a full queue

    # seed the engine with a couple of requests, then keep adding while
    # consuming the stream — continuous intake, no drain barrier.
    pending = [make_request(i) for i in range(args.requests)]
    for _ in range(2):
        if pending:
            submit(pending.pop(0))

    events = finished = 0
    first_tokens_seen = 0
    for out in eng.stream():
        events += 1
        if len(out.token_ids) == len(out.new_token_ids):
            first_tokens_seen += 1
        if out.finished:
            finished += 1
        # Poisson-ish arrivals: ~1 new request per streamed event
        if pending:
            submit(pending.pop(0))
        if events % 20 == 0:
            print(f"event {events}: running={len(eng.running)} "
                  f"waiting={len(eng.waiting)} done={finished} "
                  f"pool_util={eng.alloc.utilization():.2f}")

    print(f"\n{events} streamed events, {finished} finished "
          f"({first_tokens_seen} first-token events before any drain, "
          f"{rejected} rejected at intake)")
    rep = eng.report()
    print("final report:")
    for k, v in rep.items():
        print(f"  {k:22s} {v}")


if __name__ == "__main__":
    main()
