"""End-to-end serving driver: a small model under continuous batching with
Poisson arrivals, preemption pressure, and the paper's metric report.

    PYTHONPATH=src python examples/serve_batched.py [--requests 24]
"""
import argparse

import jax
import numpy as np

from repro.configs.registry import get_reduced
from repro.models import transformer as T
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--blocks", type=int, default=96,
                    help="small pool => exercises preemption")
    args = ap.parse_args()

    cfg = get_reduced(args.arch, num_layers=4)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_slots=6, num_blocks=args.blocks,
                        max_blocks_per_seq=12, prefill_bucket=32)
    rng = np.random.default_rng(0)
    prefix = list(rng.integers(1, 200, 24))
    pending = [Request(rid=i,
                       prompt=prefix + list(rng.integers(
                           1, 200, int(rng.integers(4, 40)))),
                       max_new_tokens=int(rng.integers(4, 16)),
                       temperature=0.7 if i % 3 == 0 else 0.0)
               for i in range(args.requests)]
    # Poisson-ish arrivals: 2 per engine step
    step = 0
    while pending or eng.waiting or eng.running:
        for _ in range(2):
            if pending:
                eng.add_request(pending.pop(0))
        eng.step()
        step += 1
        if step % 20 == 0:
            print(f"step {step}: running={len(eng.running)} "
                  f"waiting={len(eng.waiting)} done={len(eng.finished)} "
                  f"pool_util={eng.alloc.utilization():.2f}")
    rep = eng.report()
    print("\nfinal report:")
    for k, v in rep.items():
        print(f"  {k:20s} {v}")


if __name__ == "__main__":
    main()
