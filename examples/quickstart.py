"""Quickstart: build a reduced Opt-GPTQ stack end to end on CPU.

1. init a small GQA model, 2. quantize it with GPTQ (int4, Hessian-based),
3. serve a batch of prompts through the paged continuous-batching engine,
4. print the paper's three metrics.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import QuantConfig
from repro.configs.registry import get_reduced
from repro.models import transformer as T
from repro.models.quantize import gptq_quantize_model
from repro.serving.engine import Request, ServingEngine


def main():
    key = jax.random.PRNGKey(0)
    cfg = get_reduced("qwen2-1.5b", num_layers=4)
    print(f"model: {cfg.name} (reduced) — {cfg.num_heads} q-heads sharing "
          f"{cfg.num_kv_heads} kv-heads (Opt-GQA group size "
          f"{cfg.q_per_kv})")
    params = T.init_params(cfg, key)

    print("GPTQ-quantizing linears to int4 (Hessian from 2 calib batches)…")
    calib = [{"tokens": jax.random.randint(jax.random.fold_in(key, i),
                                           (2, 32), 0, cfg.vocab_size)}
             for i in range(2)]
    qparams = gptq_quantize_model(cfg, params, calib,
                                  QuantConfig(bits=4, group_size=32))

    eng = ServingEngine(cfg, qparams, max_slots=4, num_blocks=128,
                        max_blocks_per_seq=8, prefill_bucket=16)
    rng = np.random.default_rng(0)
    prefix = list(rng.integers(1, 200, 16))          # shared -> prefix reuse
    for i in range(8):
        eng.add_request(Request(
            rid=i, prompt=prefix + list(rng.integers(1, 200,
                                                     int(rng.integers(3, 12)))),
            max_new_tokens=8))
    rep = eng.run_until_done()
    print("\npaper metrics (Fig.2 format):")
    print(f"  latency:             {rep['latency_s']:.2f} s")
    print(f"  all throughput:      {rep['throughput_req_s']:.2f} req/s, "
          f"{rep['throughput_tok_s']:.1f} tok/s")
    print(f"  generate throughput: {rep['generate_tok_s']:.1f} tok/s")
    print(f"  prefix blocks reused: {rep['blocks_reused']}, "
          f"pool utilization {rep['block_utilization']:.2f}")


if __name__ == "__main__":
    main()
