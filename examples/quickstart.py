"""Quickstart: build a reduced Opt-GPTQ stack end to end on CPU.

One line constructs the whole stack — architecture from the registry,
GPTQ int4 weights (Hessian-based, synthetic calibration), and the paged
continuous-batching engine::

    llm = LLM.load("qwen2-1.5b", quant="gptq-int4", reduced=True, ...)

then ``generate`` serves a batch with per-request ``SamplingParams`` and
we print the paper's three metrics.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.serving import LLM, SamplingParams


def main():
    llm = LLM.load("qwen2-1.5b", quant="gptq-int4", reduced=True,
                   overrides=dict(num_layers=4), max_slots=4,
                   num_blocks=128, max_blocks_per_seq=8, prefill_bucket=16)
    cfg = llm.cfg
    print(f"model: {cfg.name} (reduced, GPTQ int4) — {cfg.num_heads} "
          f"q-heads sharing {cfg.num_kv_heads} kv-heads (Opt-GQA group "
          f"size {cfg.q_per_kv})")

    rng = np.random.default_rng(0)
    prefix = list(rng.integers(1, 200, 16))          # shared -> prefix reuse
    prompts = [prefix + list(rng.integers(1, 200, int(rng.integers(3, 12))))
               for _ in range(8)]
    # one batch mixes greedy and sampled requests
    sps = [SamplingParams(max_tokens=8) if i % 2 == 0 else
           SamplingParams(temperature=0.8, top_k=40, top_p=0.95,
                          max_tokens=8)
           for i in range(len(prompts))]
    outs = llm.generate(prompts, sps)
    for out in outs[:3]:
        print(f"  req {out.request_id}: {out.token_ids} "
              f"({out.finish_reason})")

    rep = llm.engine.report()
    print("\npaper metrics (Fig.2 format):")
    print(f"  latency:             {rep['latency_s']:.2f} s "
          f"(ttft {rep['ttft_s']:.2f} s)")
    print(f"  all throughput:      {rep['throughput_req_s']:.2f} req/s, "
          f"{rep['throughput_tok_s']:.1f} tok/s")
    print(f"  generate throughput: {rep['generate_tok_s']:.1f} tok/s")
    print(f"  prefix blocks reused: {rep['blocks_reused']}, "
          f"pool utilization {rep['block_utilization']:.2f}")


if __name__ == "__main__":
    main()
